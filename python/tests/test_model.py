"""L2 graph checks: analytic kernel gradients vs jax.grad autodiff, and the
eval-chunk reduction vs a brute-force oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

B, K, C = 256, 64, 512


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(42)
    r = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)  # noqa: E731
    return dict(
        x=r(B, K), wp=r(B, K), bp=r(B), wn=r(B, K), bn=r(B),
        lpn_p=r(B) - 3.0, lpn_n=r(B) - 3.0,
        wc=r(C, K), bc=r(C),
        y=jnp.asarray(rng.integers(0, C, size=B), jnp.int32),
    )


# ---------------------------------------------------------------------------
# analytic gradients == autodiff of the ref loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lam", [0.0, 0.01])
@pytest.mark.parametrize(
    "step,reflossfn",
    [(model.ns_step, ref.ns_loss), (model.nce_step, ref.nce_loss)],
)
def test_step_grads_match_autodiff(batch, step, reflossfn, lam):
    d = batch
    lam_arr = jnp.array([lam], jnp.float32)
    loss, gwp, gbp, gwn, gbn = step(
        d["x"], d["wp"], d["bp"], d["wn"], d["bn"], d["lpn_p"], d["lpn_n"], lam_arr
    )

    def total(wp, bp, wn, bn):
        return jnp.sum(reflossfn(d["x"], wp, bp, wn, bn,
                                 d["lpn_p"], d["lpn_n"], lam))

    agwp, agbp, agwn, agbn = jax.grad(total, argnums=(0, 1, 2, 3))(
        d["wp"], d["bp"], d["wn"], d["bn"]
    )
    for got, exp in [(gwp, agwp), (gbp, agbp), (gwn, agwn), (gbn, agbn)]:
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("scale", [1.0, 100.0])
def test_ove_step_grads_match_autodiff(batch, scale):
    d = batch
    scale_v = jnp.full((B,), scale, jnp.float32)
    lam_arr = jnp.array([0.001], jnp.float32)
    loss, gwp, gbp, gwn, gbn = model.ove_step(
        d["x"], d["wp"], d["bp"], d["wn"], d["bn"], scale_v, lam_arr
    )

    def total(wp, bp, wn, bn):
        return jnp.sum(ref.ove_loss(d["x"], wp, bp, wn, bn, scale_v, 0.001))

    grads = jax.grad(total, argnums=(0, 1, 2, 3))(
        d["wp"], d["bp"], d["wn"], d["bn"]
    )
    for got, exp in zip((gwp, gbp, gwn, gbn), grads):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   rtol=2e-4, atol=2e-4)


def test_softmax_step_grads_match_autodiff(batch):
    d = batch
    lam = 0.01
    onehot = jnp.eye(C, dtype=jnp.float32)[d["y"]]
    loss, gw, gb = model.softmax_step(d["x"], d["wc"], d["bc"], d["y"],
                                      jnp.array([lam], jnp.float32))

    def total(w, b):
        return jnp.sum(ref.softmax_loss(d["x"], w, b, onehot, lam))

    agw, agb = jax.grad(total, argnums=(0, 1))(d["wc"], d["bc"])
    np.testing.assert_allclose(np.asarray(loss),
                               np.asarray(ref.softmax_loss(d["x"], d["wc"], d["bc"], onehot, lam)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(agw), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(agb), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# eval chunk reductions vs brute force
# ---------------------------------------------------------------------------

def _brute(s, y_rel):
    m = s.max(axis=1)
    am = s.argmax(axis=1)
    se = np.exp(s - m[:, None]).sum(axis=1)
    ts = np.where(y_rel >= 0, s[np.arange(s.shape[0]), np.maximum(y_rel, 0)],
                  model.NEG_INF)
    return m, am, se, ts


def test_eval_chunk_plain_matches_brute(batch):
    d = batch
    rng = np.random.default_rng(7)
    y_rel = jnp.asarray(
        np.where(rng.random(B) < 0.5, rng.integers(0, C, size=B), -1), jnp.int32
    )
    got = model.eval_chunk_plain(d["x"], d["wc"], d["bc"], y_rel)
    s = np.asarray(ref.scores_matrix(d["x"], d["wc"], d["bc"]))
    exp = _brute(s, np.asarray(y_rel))
    for g, e, tol in zip(got, exp, (1e-4, 0, 1e-3, 1e-4)):
        if tol == 0:
            assert (np.asarray(g) == e).all()
        else:
            np.testing.assert_allclose(np.asarray(g), e, rtol=tol, atol=tol)


def test_eval_chunk_bias_correction_applied(batch):
    """Corrected chunk == plain chunk run on (s + lpn)."""
    d = batch
    rng = np.random.default_rng(8)
    lpn = jnp.asarray(rng.normal(size=(B, C)).astype(np.float32) - 5.0)
    y_rel = jnp.asarray(rng.integers(-1, C, size=B), jnp.int32)
    got = model.eval_chunk(d["x"], d["wc"], d["bc"], lpn, y_rel)
    s = np.asarray(ref.scores_matrix(d["x"], d["wc"], d["bc"])) + np.asarray(lpn)
    exp = _brute(s, np.asarray(y_rel))
    for g, e, tol in zip(got, exp, (1e-4, 0, 1e-3, 1e-4)):
        if tol == 0:
            assert (np.asarray(g) == e).all()
        else:
            np.testing.assert_allclose(np.asarray(g), e, rtol=tol, atol=tol)


def test_streaming_lse_merge_equals_global():
    """The rust-side merge rule reproduces a global log-sum-exp: merging the
    per-chunk (max, sumexp) pairs over chunks == lse over the whole row."""
    rng = np.random.default_rng(9)
    s = rng.normal(size=(8, 6 * C)).astype(np.float32)
    m_run = np.full(8, -np.inf)
    se_run = np.zeros(8)
    for j in range(6):
        blk = s[:, j * C:(j + 1) * C]
        m = blk.max(axis=1)
        se = np.exp(blk - m[:, None]).sum(axis=1)
        m_new = np.maximum(m_run, m)
        se_run = se_run * np.exp(m_run - m_new) + se * np.exp(m - m_new)
        m_run = m_new
    lse = m_run + np.log(se_run)
    exp = m_run + np.log(np.exp(s - m_run[:, None]).sum(axis=1))
    np.testing.assert_allclose(lse, exp, rtol=1e-5, atol=1e-5)
