"""AOT pipeline checks: manifest integrity and HLO-text round-trip.

These run the same lowering path as `make artifacts` on tiny shapes (so the
suite stays fast) and verify the contract the rust runtime relies on:
every artifact parses as HLO text, input/output arity and shapes recorded
in the manifest match the lowered computation, and lowering is
deterministic (stable sha256).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_table():
    return aot.build_spec(train_b=128, feat_k=8, aux_k=4, eval_b=128,
                          eval_c=128, softmax_c=128, eval_ca=128)


@pytest.fixture(scope="module")
def lowered_dir(tiny_table, tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(tiny_table, str(d))
    with open(d / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return d, manifest


def test_expected_artifact_set(tiny_table):
    kinds = sorted(n.split("_B")[0] for n in tiny_table)
    assert kinds == sorted([
        "ns_grad", "nce_grad", "ove_grad", "softmax_grad",
        "eval_chunk", "eval_chunk_plain", "scores",
    ])


def test_hlo_text_is_parsable_hlo(lowered_dir):
    d, manifest = lowered_dir
    for name, meta in manifest["artifacts"].items():
        text = (d / meta["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_manifest_shapes_match_eval_shape(tiny_table, lowered_dir):
    _, manifest = lowered_dir
    for name, (fn, args) in tiny_table.items():
        meta = manifest["artifacts"][name]
        assert [list(a.shape) for a in args] == [i["shape"] for i in meta["inputs"]]
        outs = jax.tree_util.tree_leaves(jax.eval_shape(fn, *args))
        assert [list(o.shape) for o in outs] == [o["shape"] for o in meta["outputs"]]
        assert [o.dtype.name for o in outs] == [o2["dtype"] for o2 in meta["outputs"]]


def test_lowering_deterministic(tiny_table):
    name, (fn, args) = sorted(tiny_table.items())[0]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert t1 == t2


def test_local_execution_of_lowered_hlo(lowered_dir):
    """Compile one lowered artifact back with the local CPU client and check
    numerics against the L2 function — the same executable the rust side
    will run."""
    d, manifest = lowered_dir
    name = next(n for n in manifest["artifacts"] if n.startswith("scores_"))
    meta = manifest["artifacts"][name]
    text = (d / meta["file"]).read_text()

    from jax._src.lib import xla_client as xc
    client = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_to_xla_computation = None  # guard accidental use
    # round-trip through the text parser exactly like HloModuleProto::from_text_file
    rng = np.random.default_rng(0)
    args = [np.asarray(rng.normal(size=i["shape"]), dtype=i["dtype"])
            for i in meta["inputs"]]
    expected = model.scores_chunk(*[jnp.asarray(a) for a in args])
    # execute the text via jax by re-parsing: xla_client exposes no text
    # parser here, so we assert the text matches a fresh lowering instead
    # (bit-identical lowering + rust-side execution test covers the rest).
    b, k = args[0].shape
    c = args[1].shape[0]
    fresh = aot.to_hlo_text(
        jax.jit(model.scores_chunk).lower(
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((c, k), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        )
    )
    assert fresh == text
    assert np.isfinite(np.asarray(expected)).all()


def test_shape_validation_rejects_non_multiple_of_128():
    with pytest.raises(ValueError, match="multiple of 128"):
        aot.build_spec(100, 8, 4, 128, 128, 128, 128)


def test_softmax_budget_guard():
    with pytest.raises(ValueError, match="12 MiB"):
        aot.build_spec(128, 512, 4, 128, 128, 128 * 256, 128)
