"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Deterministic seeds for the fixed-shape checks; hypothesis sweeps shapes
(and regularizer strengths) within the kernels' tiling contracts for the
property-based coverage requested in DESIGN.md.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.neg_sampling import grad_core
from compile.kernels.scores import scores_block
from compile.kernels.softmax import softmax_core

RTOL = 2e-5
ATOL = 2e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _gathered_batch(seed, b, k):
    rng = np.random.default_rng(seed)
    return dict(
        x=_rand(rng, b, k),
        wp=_rand(rng, b, k),
        bp=_rand(rng, b),
        wn=_rand(rng, b, k),
        bn=_rand(rng, b),
        lpn_p=_rand(rng, b) - 3.0,  # log-probs are negative-ish
        lpn_n=_rand(rng, b) - 3.0,
    )


def _check_all(outs, expected):
    names = ("loss", "gwp", "gbp", "gwn", "gbn")
    for name, a, b in zip(names, outs, expected):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=RTOL, atol=ATOL, err_msg=name
        )


# ---------------------------------------------------------------------------
# fixed-shape exactness for each mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lam", [0.0, 1e-3, 0.1])
@pytest.mark.parametrize("mode,reffn", [("ns", ref.ns_grads), ("nce", ref.nce_grads)])
def test_grad_core_matches_ref(mode, reffn, lam):
    d = _gathered_batch(0, 256, 64)
    lam_arr = jnp.array([lam], jnp.float32)
    outs = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                     d["lpn_p"], d["lpn_n"], lam_arr, mode=mode)
    exp = reffn(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                d["lpn_p"], d["lpn_n"], lam)
    _check_all(outs, exp)


@pytest.mark.parametrize("lam", [0.0, 1e-3])
@pytest.mark.parametrize("scale", [1.0, 37.5])
def test_grad_core_ove_matches_ref(scale, lam):
    d = _gathered_batch(1, 256, 64)
    b = d["bp"].shape[0]
    scale_v = jnp.full((b,), scale, jnp.float32)
    lam_arr = jnp.array([lam], jnp.float32)
    outs = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                     jnp.zeros(b), scale_v, lam_arr, mode="ove")
    exp = ref.ove_grads(d["x"], d["wp"], d["bp"], d["wn"], d["bn"], scale_v, lam)
    _check_all(outs, exp)


def test_ns_lam_zero_is_plain_eq2():
    """lam=0 reduces Eq. 6 exactly to Eq. 2: loss independent of lpn."""
    d = _gathered_batch(2, 128, 32)
    lam0 = jnp.array([0.0], jnp.float32)
    out_a = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                      d["lpn_p"], d["lpn_n"], lam0, mode="ns")
    out_b = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                      jnp.zeros(128), jnp.zeros(128), lam0, mode="ns")
    _check_all(out_a, out_b)


def test_nce_uniform_base_equals_shifted_ns():
    """With a constant base log-prob, NCE logits are a constant shift of xi.

    The NCE gradient at lam=0 with lpn == const must match the NS gradient
    at lam=0 with biases shifted down by that const.
    """
    d = _gathered_batch(3, 128, 32)
    c = -4.2
    lam0 = jnp.array([0.0], jnp.float32)
    const = jnp.full((128,), c, jnp.float32)
    out_nce = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                        const, const, lam0, mode="nce")
    out_ns = grad_core(d["x"], d["wp"], d["bp"] - c, d["wn"], d["bn"] - c,
                       jnp.zeros(128), jnp.zeros(128), lam0, mode="ns")
    _check_all(out_nce, out_ns)


def test_grad_core_extreme_scores_finite():
    """Saturated scores (paper Eq. 4 regime) must not produce NaN/Inf."""
    b, k = 128, 16
    big = 40.0
    x = jnp.ones((b, k), jnp.float32)
    wp = jnp.full((b, k), big / k, jnp.float32)
    wn = jnp.full((b, k), -big / k, jnp.float32)
    z = jnp.zeros(b, jnp.float32)
    for mode in ("ns", "nce", "ove"):
        outs = grad_core(x, wp, z, wn, z, z, jnp.ones(b), jnp.array([1e-3]),
                         mode=mode)
        for o in outs:
            assert np.isfinite(np.asarray(o)).all(), mode


# ---------------------------------------------------------------------------
# hypothesis shape sweeps (tiling contract: B multiple of block, any K)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b_mult=st.integers(1, 4),
    k=st.sampled_from([1, 3, 16, 64, 200]),
    lam=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
    mode=st.sampled_from(["ns", "nce"]),
)
def test_grad_core_shape_sweep(b_mult, k, lam, seed, mode):
    b = 128 * b_mult
    d = _gathered_batch(seed, b, k)
    lam_arr = jnp.array([lam], jnp.float32)
    outs = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                     d["lpn_p"], d["lpn_n"], lam_arr, mode=mode)
    reffn = ref.ns_grads if mode == "ns" else ref.nce_grads
    exp = reffn(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                d["lpn_p"], d["lpn_n"], lam)
    _check_all(outs, exp)


@settings(max_examples=20, deadline=None)
@given(
    b_mult=st.integers(1, 3),
    c_mult=st.integers(1, 4),
    k=st.sampled_from([1, 2, 16, 64, 130]),
    seed=st.integers(0, 2**31 - 1),
)
def test_scores_shape_sweep(b_mult, c_mult, k, seed):
    b, c = 128 * b_mult, 128 * c_mult
    rng = np.random.default_rng(seed)
    x, wc, bc = _rand(rng, b, k), _rand(rng, c, k), _rand(rng, c)
    got = scores_block(x, wc, bc)
    exp = ref.scores_matrix(x, wc, bc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    c=st.sampled_from([4, 64, 300, 1024]),
    lam=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_core_sweep(c, lam, seed):
    b, k = 128, 32
    rng = np.random.default_rng(seed)
    x, w, bias = _rand(rng, b, k), _rand(rng, c, k), _rand(rng, c)
    y = jnp.asarray(rng.integers(0, c, size=b), jnp.int32)
    onehot = jnp.eye(c, dtype=jnp.float32)[y]
    loss, ds = softmax_core(x, w, bias, y, jnp.array([lam], jnp.float32))
    eloss = ref.softmax_loss(x, w, bias, onehot, lam)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(eloss),
                               rtol=1e-4, atol=1e-4)
    # residual check via the ref grads (which consume ds implicitly)
    _, egw, egb = ref.softmax_grads(x, w, bias, onehot, lam)
    gw = jnp.dot(ds.T, x)
    gb = jnp.sum(ds, axis=0)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(egw),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(egb),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# tiling-contract errors are loud, not silent
# ---------------------------------------------------------------------------

def test_odd_batch_falls_back_to_single_block():
    """Batches that don't tile by the preferred block run as one block
    (pick_block fallback) and still match the oracle."""
    d = _gathered_batch(4, 192, 8)
    out = grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                    d["lpn_p"], d["lpn_n"], jnp.array([0.01], jnp.float32),
                    mode="ns")
    exp = ref.ns_grads(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                       d["lpn_p"], d["lpn_n"], 0.01)
    _check_all(out, exp)


def test_bad_mode_raises():
    d = _gathered_batch(5, 128, 8)
    with pytest.raises(ValueError, match="mode"):
        grad_core(d["x"], d["wp"], d["bp"], d["wn"], d["bn"],
                  d["lpn_p"], d["lpn_n"], jnp.array([0.0]), mode="bogus")


def test_scores_dim_mismatch_raises():
    rng = np.random.default_rng(6)
    with pytest.raises(ValueError, match="feature dims"):
        scores_block(_rand(rng, 128, 8), _rand(rng, 128, 9), _rand(rng, 128))
