"""AOT lowering: jit entry points -> HLO text artifacts + manifest.json.

The interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each entry point is lowered once per shape listed in the spec; the rust
runtime (`rust/src/runtime/`) loads `manifest.json`, compiles every module
on the PJRT CPU client at startup, and exposes typed wrappers keyed by
artifact name.

Usage:
    python -m compile.aot --out-dir ../artifacts [--train-b 256]
        [--feat-k 64] [--aux-k 16] [--eval-c 2048] [--softmax-c 4096]

The shape defaults are the ones every experiment preset in the rust config
system uses; changing them requires re-running `make artifacts` (the
Makefile tracks the python sources as prerequisites).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_spec(train_b: int, feat_k: int, aux_k: int, eval_b: int,
               eval_c: int, softmax_c: int, eval_ca: int):
    """The artifact table: name -> (fn, example_args).

    Shapes must respect the kernels' tiling contracts (batch a multiple of
    128 per neg_sampling.DEFAULT_BLOCK_B, chunk a multiple of 128).
    """
    for nm, v in [("train-b", train_b), ("eval-b", eval_b),
                  ("eval-c", eval_c), ("softmax-c", softmax_c),
                  ("eval-ca", eval_ca)]:
        if v % 128 != 0:
            raise ValueError(f"--{nm}={v} must be a multiple of 128")
    if softmax_c * feat_k * 4 > 12 * 2**20:
        raise ValueError("softmax artifact would exceed the 12 MiB W budget")

    gathered = [
        _spec((train_b, feat_k)),  # x
        _spec((train_b, feat_k)),  # wp
        _spec((train_b,)),         # bp
        _spec((train_b, feat_k)),  # wn
        _spec((train_b,)),         # bn
        _spec((train_b,)),         # lpn_p / zeros
        _spec((train_b,)),         # lpn_n / scale
        _spec((1,)),               # lam
    ]
    pairwise = gathered[:5] + [gathered[6], gathered[7]]  # x..bn, scale, lam

    table = {
        f"ns_grad_B{train_b}_K{feat_k}": (model.ns_step, gathered),
        f"nce_grad_B{train_b}_K{feat_k}": (model.nce_step, gathered),
        f"ove_grad_B{train_b}_K{feat_k}": (model.ove_step, pairwise),
        f"softmax_grad_B{train_b}_K{feat_k}_C{softmax_c}": (
            model.softmax_step,
            [
                _spec((train_b, feat_k)),
                _spec((softmax_c, feat_k)),
                _spec((softmax_c,)),
                _spec((train_b,), I32),
                _spec((1,)),
            ],
        ),
        f"eval_chunk_B{eval_b}_K{feat_k}_C{eval_c}": (
            model.eval_chunk,
            [
                _spec((eval_b, feat_k)),
                _spec((eval_c, feat_k)),
                _spec((eval_c,)),
                _spec((eval_b, eval_c)),
                _spec((eval_b,), I32),
            ],
        ),
        f"eval_chunk_plain_B{eval_b}_K{feat_k}_C{eval_c}": (
            model.eval_chunk_plain,
            [
                _spec((eval_b, feat_k)),
                _spec((eval_c, feat_k)),
                _spec((eval_c,)),
                _spec((eval_b,), I32),
            ],
        ),
        # aux-tree node projection at eval time: X_proj[B,k] @ Wnodes[Ca,k]^T
        f"scores_B{eval_b}_K{aux_k}_C{eval_ca}": (
            model.scores_chunk,
            [
                _spec((eval_b, aux_k)),
                _spec((eval_ca, aux_k)),
                _spec((eval_ca,)),
            ],
        ),
    }
    return table


def lower_all(table, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "version": 1, "artifacts": {}}
    for name, (fn, args) in sorted(table.items()):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *args)
        outs = jax.tree_util.tree_leaves(out_tree)
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(a.shape), "dtype": a.dtype.name} for a in args
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": o.dtype.name} for o in outs
            ],
        }
        print(f"  {name}: {len(text)} chars, {len(args)} in / {len(outs)} out")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--train-b", type=int, default=256)
    p.add_argument("--eval-b", type=int, default=256)
    p.add_argument("--feat-k", type=int, default=64)
    p.add_argument("--aux-k", type=int, default=16)
    p.add_argument("--eval-c", type=int, default=2048)
    p.add_argument("--eval-ca", type=int, default=2048,
                   help="aux-tree node-projection chunk size")
    p.add_argument("--softmax-c", type=int, default=4096)
    args = p.parse_args()

    table = build_spec(args.train_b, args.feat_k, args.aux_k, args.eval_b,
                       args.eval_c, args.softmax_c, args.eval_ca)
    print(f"lowering {len(table)} artifacts -> {args.out_dir}")
    manifest = lower_all(table, args.out_dir)
    manifest["shapes"] = {
        "train_b": args.train_b, "eval_b": args.eval_b, "feat_k": args.feat_k,
        "aux_k": args.aux_k, "eval_c": args.eval_c, "eval_ca": args.eval_ca,
        "softmax_c": args.softmax_c,
    }
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
