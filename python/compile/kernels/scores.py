"""L1 Pallas kernel: tiled dense score block S = X @ Wc^T + bc.

This is the prediction/evaluation hot-spot: scoring a batch of feature
vectors against a chunk of the label matrix. The rust evaluator streams the
full label set through this kernel in chunks of Cc rows, then applies the
paper's bias correction (Eq. 5: + log p_n(y|x)) and reduces top-1 /
log-sum-exp incrementally on the rust side.

TPU mapping: this is the MXU kernel. The grid tiles (batch, label-chunk);
each grid step computes a (BB, CB) output tile from an X tile (BB, K) and a
W tile (CB, K) via jnp.dot with float32 accumulation — on real TPU this is
a (128, K)x(K, 128) systolic-array matmul per step, bf16-ready. VMEM per
step at BB=CB=128, K=512 fp32: X 256 KiB + W 256 KiB + out 64 KiB, far
under budget, so the K dimension stays unsplit (no reduction loop) for
K <= ~4k. The BlockSpec index maps express the HBM->VMEM schedule: X tiles
are re-fetched per label chunk (ci-major order would reuse W; we iterate
bi-major so the *X* tile is resident across the inner ci loop, which is the
right choice because eval batches are small and the label matrix is the
streaming operand).

interpret=True for CPU-PJRT executability (see neg_sampling.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256  # full eval batch per step (perf pass iter. 2)
DEFAULT_BLOCK_C = 512  # wider label tiles: 4x fewer grid steps, still VMEM-safe (perf pass iter. 2)


def _scores_kernel(x_ref, wc_ref, bc_ref, out_ref):
    """One (BB, CB) output tile: dot + bias broadcast."""
    x = x_ref[...]           # [BB, K]
    wc = wc_ref[...]         # [CB, K]
    bc = bc_ref[...]         # [CB]
    acc = jnp.dot(x, wc.T, preferred_element_type=jnp.float32)
    out_ref[...] = acc + bc[None, :]


@functools.partial(jax.jit, static_argnames=("block_b", "block_c"))
def scores_block(x, wc, bc, *, block_b: int = DEFAULT_BLOCK_B,
                 block_c: int = DEFAULT_BLOCK_C):
    """Dense score block S[i, c] = x_i . wc_c + bc_c.

    Args:
      x:  [B, K] feature batch.
      wc: [Cc, K] label-chunk weight rows.
      bc: [Cc] label-chunk biases.

    Returns:
      S: [B, Cc] float32 scores.
    """
    b, k = x.shape
    cc, k2 = wc.shape
    if k != k2:
        raise ValueError(f"feature dims disagree: x has K={k}, wc has K={k2}")
    from . import pick_block
    bb = pick_block(b, block_b)
    cb = pick_block(cc, block_c)
    grid = (b // bb, cc // cb)  # bi-major: X tile resident across ci

    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda bi, ci: (bi, 0)),
            pl.BlockSpec((cb, k), lambda bi, ci: (ci, 0)),
            pl.BlockSpec((cb,), lambda bi, ci: (ci,)),
        ],
        out_specs=pl.BlockSpec((bb, cb), lambda bi, ci: (bi, ci)),
        out_shape=jax.ShapeDtypeStruct((b, cc), jnp.float32),
        interpret=True,
    )(x, wc, bc)
