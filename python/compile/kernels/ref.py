"""Pure-jnp reference oracles for all Pallas kernels.

These implement the paper's losses directly from the equations, with no
tiling/blocking tricks, and are the single source of truth for kernel
correctness (pytest compares every Pallas kernel against these under
hypothesis-driven shape/dtype sweeps).

Notation follows the paper (Bamler & Mandt, ICLR 2020):
  xi      = score  xi_y(x, phi) = w_y . x + b_y                 (affine model, Sec. 5)
  Eq. 2   = plain negative-sampling loss
  Eq. 6   = regularized adversarial negative-sampling loss
  NCE     = Gutmann & Hyvarinen with non-uniform base distribution:
            binary logit  u = xi - log p_n(y|x)
  OVE     = Titsias one-vs-each stochastic bound: -log sigma(xi_y - xi_y')
  A&R     = sampled softmax-bound, same pairwise form with importance
            weight `scale` = (C-1)/S on the negative term.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.nn import log_sigmoid, sigmoid


# ---------------------------------------------------------------------------
# score primitives
# ---------------------------------------------------------------------------

def rowwise_scores(x, w, b):
    """xi_i = w_i . x_i + b_i for a batch of gathered label rows.

    x: [B, K], w: [B, K], b: [B]  ->  [B]
    """
    return jnp.sum(x * w, axis=-1) + b


def scores_matrix(x, wc, bc):
    """Dense score block S[i, c] = x_i . wc_c + bc_c.

    x: [B, K], wc: [Cc, K], bc: [Cc]  ->  [B, Cc]
    Used by evaluation (chunked over the label set).
    """
    return x @ wc.T + bc[None, :]


# ---------------------------------------------------------------------------
# negative-sampling family (Eq. 2 and Eq. 6)
# ---------------------------------------------------------------------------

def ns_loss(x, wp, bp, wn, bn, lpn_p, lpn_n, lam):
    """Per-example regularized negative-sampling loss, Eq. 6.

    With lam == 0 this is exactly Eq. 2 (plain negative sampling).

      l_i = -log sig(xi_p) + lam (xi_p + lpn_p)^2
            -log sig(-xi_n) + lam (xi_n + lpn_n)^2

    Shapes: x [B,K]; wp,wn [B,K]; bp,bn,lpn_p,lpn_n [B]; lam scalar.
    Returns loss [B].
    """
    xi_p = rowwise_scores(x, wp, bp)
    xi_n = rowwise_scores(x, wn, bn)
    loss = (
        -log_sigmoid(xi_p)
        - log_sigmoid(-xi_n)
        + lam * (xi_p + lpn_p) ** 2
        + lam * (xi_n + lpn_n) ** 2
    )
    return loss


def ns_grads(x, wp, bp, wn, bn, lpn_p, lpn_n, lam):
    """Analytic gradients of `ns_loss` w.r.t. the gathered rows.

    d l / d xi_p = -sig(-xi_p) + 2 lam (xi_p + lpn_p)
    d l / d xi_n =  sig(xi_n)  + 2 lam (xi_n + lpn_n)
    d xi / d w   = x ;  d xi / d b = 1

    Returns (loss[B], gwp[B,K], gbp[B], gwn[B,K], gbn[B]).
    """
    xi_p = rowwise_scores(x, wp, bp)
    xi_n = rowwise_scores(x, wn, bn)
    dxi_p = -sigmoid(-xi_p) + 2.0 * lam * (xi_p + lpn_p)
    dxi_n = sigmoid(xi_n) + 2.0 * lam * (xi_n + lpn_n)
    loss = ns_loss(x, wp, bp, wn, bn, lpn_p, lpn_n, lam)
    return loss, dxi_p[:, None] * x, dxi_p, dxi_n[:, None] * x, dxi_n


# ---------------------------------------------------------------------------
# NCE with non-uniform base distribution
# ---------------------------------------------------------------------------

def nce_loss(x, wp, bp, wn, bn, lpn_p, lpn_n, lam):
    """NCE loss with base distribution p_n; logit u = xi - log p_n(y|x).

    The discriminator models log p_D(y|x) directly, so what it must learn
    *includes* whatever the base distribution already captures (the waste
    the paper points out). `lam` is a plain L2-toward-zero pull on xi for
    parity with the NS regularizer.
    """
    xi_p = rowwise_scores(x, wp, bp)
    xi_n = rowwise_scores(x, wn, bn)
    u_p = xi_p - lpn_p
    u_n = xi_n - lpn_n
    return -log_sigmoid(u_p) - log_sigmoid(-u_n) + lam * (xi_p**2 + xi_n**2)


def nce_grads(x, wp, bp, wn, bn, lpn_p, lpn_n, lam):
    """Analytic gradients of `nce_loss` (same output layout as ns_grads)."""
    xi_p = rowwise_scores(x, wp, bp)
    xi_n = rowwise_scores(x, wn, bn)
    u_p = xi_p - lpn_p
    u_n = xi_n - lpn_n
    dxi_p = -sigmoid(-u_p) + 2.0 * lam * xi_p
    dxi_n = sigmoid(u_n) + 2.0 * lam * xi_n
    loss = nce_loss(x, wp, bp, wn, bn, lpn_p, lpn_n, lam)
    return loss, dxi_p[:, None] * x, dxi_p, dxi_n[:, None] * x, dxi_n


# ---------------------------------------------------------------------------
# pairwise bounds: One-vs-Each and sampled Augment&Reduce
# ---------------------------------------------------------------------------

def ove_loss(x, wp, bp, wn, bn, scale, lam):
    """Stochastic one-vs-each term: scale * -log sig(xi_p - xi_n) + L2.

    scale = 1 for OVE proper; scale = (C-1)/S for the sampled softmax-bound
    (A&R-style) estimator with S negatives handled one at a time.
    """
    xi_p = rowwise_scores(x, wp, bp)
    xi_n = rowwise_scores(x, wn, bn)
    return scale * (-log_sigmoid(xi_p - xi_n)) + lam * (xi_p**2 + xi_n**2)


def ove_grads(x, wp, bp, wn, bn, scale, lam):
    """Analytic gradients of `ove_loss` (same output layout as ns_grads)."""
    xi_p = rowwise_scores(x, wp, bp)
    xi_n = rowwise_scores(x, wn, bn)
    d = -scale * sigmoid(xi_n - xi_p)  # d/dxi_p of -scale*log_sig(xi_p-xi_n)
    dxi_p = d + 2.0 * lam * xi_p
    dxi_n = -d + 2.0 * lam * xi_n
    loss = ove_loss(x, wp, bp, wn, bn, scale, lam)
    return loss, dxi_p[:, None] * x, dxi_p, dxi_n[:, None] * x, dxi_n


# ---------------------------------------------------------------------------
# full softmax (Eq. 1), small label sets only
# ---------------------------------------------------------------------------

def softmax_loss(x, w, b, y_onehot, lam):
    """Full softmax loss per example, Eq. 1, plus L2 on the true-label score.

    x: [B,K]; w: [C,K]; b: [C]; y_onehot: [B,C] -> loss [B].
    """
    s = scores_matrix(x, w, b)  # [B, C]
    smax = s.max(axis=1)
    lse = jnp.log(jnp.sum(jnp.exp(s - smax[:, None]), axis=1)) + smax
    xi_y = jnp.sum(s * y_onehot, axis=1)
    return -xi_y + lse + lam * xi_y**2


def softmax_grads(x, w, b, y_onehot, lam):
    """Analytic gradients of `softmax_loss` summed over the batch.

    d l_i / d s_ic = softmax(s_i)_c - y_onehot_ic + 2 lam xi_y y_onehot_ic
    Returns (loss[B], gw[C,K], gb[C]).
    """
    s = scores_matrix(x, w, b)
    p = jnp.exp(s - s.max(axis=1, keepdims=True))
    p = p / p.sum(axis=1, keepdims=True)
    xi_y = jnp.sum(s * y_onehot, axis=1)
    ds = p - y_onehot + 2.0 * lam * xi_y[:, None] * y_onehot  # [B, C]
    loss = softmax_loss(x, w, b, y_onehot, lam)
    return loss, ds.T @ x, ds.sum(axis=0)
