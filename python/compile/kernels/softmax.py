"""L1 Pallas kernel: full-softmax loss core (Eq. 1), small label sets only.

Used by the `softmax` baseline for the Appendix A.2 comparison (EURLex-like
scale, C ~ 4k), where an O(NCK) epoch is tractable. The kernel fuses the
batch-tile score matmul with the stable log-sum-exp and the softmax
residual ds = softmax(s) - onehot(y) (+ the regularizer term on xi_y); the
dense parameter gradients gw = ds^T X and gb = sum(ds) are left to the L2
graph where XLA fuses them into a single matmul.

TPU mapping: grid tiles the batch; each step does a (BB, K)x(K, C) MXU
matmul with the full W resident in VMEM (C=4096, K=512 fp32 -> 8 MiB; the
aot manifest caps softmax artifacts at C*K*4B <= 12 MiB) followed by VPU
row reductions. The label id enters as an int32 vector; one-hot is formed
in-kernel via iota comparison so the host never materializes a [B, C]
one-hot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _softmax_kernel(x_ref, w_ref, b_ref, y_ref, lam_ref, loss_ref, ds_ref):
    x = x_ref[...]          # [BB, K]
    w = w_ref[...]          # [C, K]
    bias = b_ref[...]       # [C]
    y = y_ref[...]          # [BB] int32
    lam = lam_ref[0]

    s = jnp.dot(x, w.T, preferred_element_type=jnp.float32) + bias[None, :]  # [BB, C]
    smax = jnp.max(s, axis=1)
    z = jnp.exp(s - smax[:, None])
    sumz = jnp.sum(z, axis=1)
    lse = jnp.log(sumz) + smax

    c = s.shape[1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (y.shape[0], c), 1)
              == y[:, None]).astype(s.dtype)  # [BB, C]
    xi_y = jnp.sum(s * onehot, axis=1)

    loss_ref[...] = -xi_y + lse + lam * xi_y * xi_y
    p = z / sumz[:, None]
    ds_ref[...] = p - onehot + 2.0 * lam * xi_y[:, None] * onehot


@functools.partial(jax.jit, static_argnames=("block_b",))
def softmax_core(x, w, b, y, lam, *, block_b: int = DEFAULT_BLOCK_B):
    """Fused softmax loss + score-space residual.

    Args:
      x:   [B, K] feature batch.
      w:   [C, K] full label weight matrix.
      b:   [C] label biases.
      y:   [B] int32 true-label ids.
      lam: [1] regularizer strength on the true-label score.

    Returns:
      (loss[B], ds[B, C]) where ds = d loss_i / d s_ic. The caller forms
      gw = ds^T @ x and gb = sum_i ds_i.
    """
    bsz, k = x.shape
    c = w.shape[0]
    from . import pick_block
    bb = pick_block(bsz, block_b)
    grid = (bsz // bb,)

    return pl.pallas_call(
        _softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((c, k), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz,), jnp.float32),
            jax.ShapeDtypeStruct((bsz, c), jnp.float32),
        ],
        interpret=True,
    )(x, w, b, y, lam)
