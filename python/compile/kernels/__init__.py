# L1: Pallas kernel(s) for the paper's compute hot-spot.

def pick_block(n: int, preferred: int) -> int:
    """Largest tile size that divides `n`, trying `preferred`, then 128,
    then whole-`n` (single block). Keeps kernels usable for any batch that
    is a multiple of 128 — and for smaller/odd sizes via one big block —
    while the AOT artifacts use the preferred (perf-tuned) tiling."""
    for cand in (preferred, 128):
        if n >= cand and n % cand == 0:
            return cand
    return n
