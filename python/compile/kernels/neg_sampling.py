"""L1 Pallas kernel: fused gradient core for the sampling-based losses.

This is the training hot-spot of the paper's method and of every
sampling-based baseline: given a batch of feature vectors and the gathered
positive/negative parameter rows, compute the per-example loss and the
analytic gradients w.r.t. the gathered rows, fused in one pass (dot
products, sigmoids, scaling, outer products).

One kernel body serves the three loss families (selected at *trace* time,
so each variant lowers to its own specialized HLO):

  mode = "ns"   regularized negative sampling, paper Eq. 6 (lam=0 -> Eq. 2)
  mode = "nce"  NCE with non-uniform base distribution (logit xi - log p_n)
  mode = "ove"  one-vs-each / sampled softmax-bound pairwise term; the
                `lpn_n` operand is reinterpreted as the per-example
                importance weight `scale` (lpn_p is ignored)

TPU mapping (see DESIGN.md "Hardware adaptation"): the grid tiles the batch
dimension; one grid step holds x/wp/wn tiles of shape (BB, K) plus the
(BB,) vectors in VMEM.  With BB=128, K<=512 fp32 the footprint is
3*128*512*4B ~= 0.75 MiB plus O(BB) vectors — comfortably under a 16 MiB
VMEM budget, leaving room for double buffering of the next tile.  All math
is elementwise + row reductions (VPU work); there is deliberately no MXU
matmul here — the gradient outer product dxi[:,None]*x is rank-1 per row
and stays vectorized.

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls; the
interpret path lowers to plain HLO which the rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile. Experiment batch sizes are multiples of 128; aot.py asserts it.
DEFAULT_BLOCK_B = 256  # one grid step per training batch: fewer interpret-mode loop iterations (perf pass iter. 2)

_MODES = ("ns", "nce", "ove")


def _log_sigmoid(z):
    """Numerically stable log(sigma(z)) = -log1p(exp(-z)) = min(z,0) - log1p(exp(-|z|))."""
    return jnp.minimum(z, 0.0) - jnp.log1p(jnp.exp(-jnp.abs(z)))


def _sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def _grad_core_kernel(
    x_ref, wp_ref, bp_ref, wn_ref, bn_ref, lpn_p_ref, lpn_n_ref, lam_ref,
    loss_ref, gwp_ref, gbp_ref, gwn_ref, gbn_ref,
    *, mode: str,
):
    """One batch tile: fused scores -> loss -> dxi -> row-scaled gradients."""
    x = x_ref[...]            # [BB, K]
    wp = wp_ref[...]          # [BB, K]
    wn = wn_ref[...]          # [BB, K]
    bp = bp_ref[...]          # [BB]
    bn = bn_ref[...]          # [BB]
    lpn_p = lpn_p_ref[...]    # [BB]
    lpn_n = lpn_n_ref[...]    # [BB] (ove: per-example importance weight)
    lam = lam_ref[0]          # scalar

    xi_p = jnp.sum(x * wp, axis=-1) + bp  # [BB]
    xi_n = jnp.sum(x * wn, axis=-1) + bn  # [BB]

    if mode == "ns":
        # Eq. 6: -log sig(xi_p) - log sig(-xi_n)
        #        + lam[(xi_p+lpn_p)^2 + (xi_n+lpn_n)^2]
        rp = xi_p + lpn_p
        rn = xi_n + lpn_n
        loss = -_log_sigmoid(xi_p) - _log_sigmoid(-xi_n) + lam * (rp * rp + rn * rn)
        dxi_p = -_sigmoid(-xi_p) + 2.0 * lam * rp
        dxi_n = _sigmoid(xi_n) + 2.0 * lam * rn
    elif mode == "nce":
        # binary logit u = xi - log p_n(y|x); plain L2 pull on xi.
        u_p = xi_p - lpn_p
        u_n = xi_n - lpn_n
        loss = -_log_sigmoid(u_p) - _log_sigmoid(-u_n) + lam * (xi_p * xi_p + xi_n * xi_n)
        dxi_p = -_sigmoid(-u_p) + 2.0 * lam * xi_p
        dxi_n = _sigmoid(u_n) + 2.0 * lam * xi_n
    elif mode == "ove":
        # scale * -log sig(xi_p - xi_n) + lam(xi_p^2 + xi_n^2); scale=lpn_n.
        scale = lpn_n
        diff = xi_p - xi_n
        loss = scale * (-_log_sigmoid(diff)) + lam * (xi_p * xi_p + xi_n * xi_n)
        d = -scale * _sigmoid(-diff)
        dxi_p = d + 2.0 * lam * xi_p
        dxi_n = -d + 2.0 * lam * xi_n
    else:  # pragma: no cover - trace-time guard
        raise ValueError(f"unknown mode {mode!r}")

    loss_ref[...] = loss
    gwp_ref[...] = dxi_p[:, None] * x
    gbp_ref[...] = dxi_p
    gwn_ref[...] = dxi_n[:, None] * x
    gbn_ref[...] = dxi_n


@functools.partial(jax.jit, static_argnames=("mode", "block_b"))
def grad_core(x, wp, bp, wn, bn, lpn_p, lpn_n, lam, *, mode: str = "ns",
              block_b: int = DEFAULT_BLOCK_B):
    """Fused loss + gathered-row gradients for one training step.

    Args:
      x:      [B, K] feature batch.
      wp, bp: [B, K], [B] gathered positive-label rows/biases.
      wn, bn: [B, K], [B] gathered negative-label rows/biases.
      lpn_p:  [B] log p_n(y|x) for positives (ns), base log-prob (nce),
              ignored (ove).
      lpn_n:  [B] log p_n(y'|x) for negatives (ns/nce) or the per-example
              importance weight `scale` (ove / a&r).
      lam:    [1] regularizer strength (paper's lambda).
      mode:   "ns" | "nce" | "ove" (static; selects the loss family).

    Returns:
      (loss[B], gwp[B,K], gbp[B], gwn[B,K], gbn[B]).
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    b, k = x.shape
    from . import pick_block
    bb = pick_block(b, block_b)
    grid = (b // bb,)
    dt = x.dtype

    row = lambda i: (i, 0)   # noqa: E731 - BlockSpec index maps
    vec = lambda i: (i,)     # noqa: E731
    scl = lambda i: (0,)     # noqa: E731

    return pl.pallas_call(
        functools.partial(_grad_core_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), row),   # x
            pl.BlockSpec((bb, k), row),   # wp
            pl.BlockSpec((bb,), vec),     # bp
            pl.BlockSpec((bb, k), row),   # wn
            pl.BlockSpec((bb,), vec),     # bn
            pl.BlockSpec((bb,), vec),     # lpn_p
            pl.BlockSpec((bb,), vec),     # lpn_n
            pl.BlockSpec((1,), scl),      # lam
        ],
        out_specs=[
            pl.BlockSpec((bb,), vec),     # loss
            pl.BlockSpec((bb, k), row),   # gwp
            pl.BlockSpec((bb,), vec),     # gbp
            pl.BlockSpec((bb, k), row),   # gwn
            pl.BlockSpec((bb,), vec),     # gbn
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), dt),
            jax.ShapeDtypeStruct((b, k), dt),
            jax.ShapeDtypeStruct((b,), dt),
            jax.ShapeDtypeStruct((b, k), dt),
            jax.ShapeDtypeStruct((b,), dt),
        ],
        interpret=True,
    )(x, wp, bp, wn, bn, lpn_p, lpn_n, lam)
