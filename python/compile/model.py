"""L2: the paper's compute graphs, composing the L1 Pallas kernels.

Every public function here is a jit-able entry point that `aot.py` lowers
to one HLO-text artifact per shape. The rust coordinator (L3) owns the
parameters, the data, the auxiliary tree model and the training loop; these
graphs are pure functions of their operands (no state, no host callbacks),
so a step is exactly one PJRT execute.

Entry points
------------
  ns_step / nce_step / ove_step   sampling-based training-step gradients
                                  (grad_core kernel; gathered-row layout)
  softmax_step                    full-softmax loss + dense gradients
  scores_chunk                    raw dense score block (also reused for the
                                  aux-tree node projection at eval time)
  eval_chunk / eval_chunk_plain   fused chunked evaluation reduction:
                                  streaming-LSE partials + chunk top-1 +
                                  true-label score, with (without) the
                                  Eq. 5 bias correction matrix
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.neg_sampling import grad_core
from .kernels.scores import scores_block
from .kernels.softmax import softmax_core

NEG_INF = -3.0e38  # sentinel for "true label not in this chunk"


# ---------------------------------------------------------------------------
# training steps (gathered-row layout; L3 scatters the returned row grads)
# ---------------------------------------------------------------------------

def ns_step(x, wp, bp, wn, bn, lpn_p, lpn_n, lam):
    """Adversarial / uniform / frequency negative sampling (Eq. 6; Eq. 2 at lam=0)."""
    return grad_core(x, wp, bp, wn, bn, lpn_p, lpn_n, lam, mode="ns")


def nce_step(x, wp, bp, wn, bn, lpn_p, lpn_n, lam):
    """NCE with non-uniform base distribution."""
    return grad_core(x, wp, bp, wn, bn, lpn_p, lpn_n, lam, mode="nce")


def ove_step(x, wp, bp, wn, bn, scale, lam):
    """One-vs-each / sampled softmax-bound pairwise step.

    `scale` [B] is the per-example importance weight ((C-1)/S for A&R, 1
    for OVE); it rides in the lpn_n operand slot of the fused kernel.
    """
    zeros = jnp.zeros_like(bp)
    return grad_core(x, wp, bp, wn, bn, zeros, scale, lam, mode="ove")


def softmax_step(x, w, b, y, lam):
    """Full softmax (Eq. 1): per-example loss + dense parameter gradients.

    Returns (loss[B], gw[C,K], gb[C]). The score-space residual comes from
    the fused Pallas kernel; the two dense contractions below are left to
    XLA, which fuses them with the kernel's output layout.
    """
    loss, ds = softmax_core(x, w, b, y, lam)
    gw = jnp.dot(ds.T, x, preferred_element_type=jnp.float32)  # [C, K]
    gb = jnp.sum(ds, axis=0)                                   # [C]
    return loss, gw, gb


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def scores_chunk(x, wc, bc):
    """Raw dense scores for one label chunk: [B, Cc]."""
    return scores_block(x, wc, bc)


def _eval_reduce(s, y_rel):
    """Chunk-local reduction for streaming evaluation.

    s:      [B, Cc] (possibly bias-corrected) scores.
    y_rel:  [B] int32, index of the true label inside this chunk, or -1.

    Returns (chunk_max[B], chunk_argmax[B] i32, chunk_sumexp[B],
    true_score[B]). `chunk_sumexp` is sum(exp(s - chunk_max)); the rust
    side merges chunks with the standard streaming log-sum-exp update, so
    no global pass over C is ever materialized.
    """
    chunk_max = jnp.max(s, axis=1)
    chunk_argmax = jnp.argmax(s, axis=1).astype(jnp.int32)
    chunk_sumexp = jnp.sum(jnp.exp(s - chunk_max[:, None]), axis=1)
    in_chunk = y_rel >= 0
    safe_rel = jnp.maximum(y_rel, 0)
    true_score = jnp.where(
        in_chunk, jnp.take_along_axis(s, safe_rel[:, None], axis=1)[:, 0], NEG_INF
    )
    return chunk_max, chunk_argmax, chunk_sumexp, true_score


def eval_chunk(x, wc, bc, lpn, y_rel):
    """Bias-corrected evaluation chunk (paper Eq. 5).

    lpn: [B, Cc] log p_n(y|x) correction matrix for this chunk, computed by
    the rust tree sweep. Scores used for both ranking and likelihood are
    xi + log p_n.
    """
    s = scores_block(x, wc, bc) + lpn
    return _eval_reduce(s, y_rel)


def eval_chunk_plain(x, wc, bc, y_rel):
    """Uncorrected evaluation chunk (all baselines predict with raw xi)."""
    s = scores_block(x, wc, bc)
    return _eval_reduce(s, y_rel)
