//! E1 — Table 1 regeneration + the cost of the data substrate itself
//! (synthetic generation and preprocessing are part of the harness; they
//! must stay negligible next to training).

use adv_softmax::config::{DatasetPreset, SyntheticConfig};
use adv_softmax::data::Splits;
use adv_softmax::exp::table1;
use adv_softmax::utils::bench::{black_box, Bench};

fn main() -> anyhow::Result<()> {
    // regenerate the table rows (also writes results/table1.csv)
    table1::run(&[DatasetPreset::WikiSim, DatasetPreset::AmazonSim])?;

    let bench = Bench::new(1, 3, 1.0);
    for p in [DatasetPreset::Tiny, DatasetPreset::EurlexSim, DatasetPreset::AmazonSim] {
        let cfg = SyntheticConfig::preset(p);
        bench.run(&format!("generate/{p}"), || {
            black_box(Splits::synthetic(&cfg));
        });
    }
    let splits = Splits::synthetic(&SyntheticConfig::preset(DatasetPreset::AmazonSim));
    bench.run("label_counts/amazon-sim", || {
        black_box(splits.train.label_counts());
    });
    Ok(())
}
