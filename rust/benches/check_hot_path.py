#!/usr/bin/env python3
"""Diff hot-path bench speedups against the committed baseline.

Usage: check_hot_path.py BENCH_hot_path.json benches/hot_path_baseline.json

The baseline file is the source of truth for what is tracked: every
section of ``hot_path_baseline.json`` (keys starting with ``_`` are
notes, non-numeric entries are ignored) is diffed against the measured
results, so adding a floor to the baseline automatically enforces it —
there is no allowlist to forget to update. A tracked entry missing from
the measured results is a hard ``::error`` (exit 1): a silently skipped
floor is indistinguishable from a passing one. Regressions of more than
25% below baseline emit a GitHub Actions ``::warning`` only — shared CI
runners are noisy, so they flag for a human instead of failing the
build.
"""

import json
import sys

REGRESSION_FACTOR = 0.75  # warn below 75% of baseline (>25% regression)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        measured = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    missing = False
    checked = 0
    for section, base_entries in sorted(baseline.items()):
        if section.startswith("_") or not isinstance(base_entries, dict):
            continue  # commentary, not a tracked section
        got_entries = measured.get(section) or {}
        for key, base in sorted(base_entries.items()):
            if key.startswith("_") or not isinstance(base, (int, float)):
                continue
            got = got_entries.get(key)
            if got is None:
                print(f"::error::bench entry {section}.{key} missing from results")
                missing = True
                continue
            checked += 1
            status = "ok"
            if got < base * REGRESSION_FACTOR:
                print(
                    f"::warning::hot-path speedup regression: {key} measured "
                    f"{got:.2f}x vs baseline {base:.2f}x (>25% below baseline)"
                )
                status = "REGRESSED"
            print(f"bench-diff {key:<16} measured {got:6.2f}x  baseline {base:6.2f}x  {status}")
    if checked == 0 and not missing:
        print("::error::baseline tracks no entries — wrong file?")
        return 1
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
