#!/usr/bin/env python3
"""Diff hot-path bench speedups against the committed baseline.

Usage: check_hot_path.py BENCH_hot_path.json benches/hot_path_baseline.json

Compares every entry the baseline tracks (the lane-major kernel speedups
``speedups_scalar_over_kernel``, the double-buffered step-engine speedup
``speedups_step_overlap``, the serving beam-vs-exact speedup
``speedups_serve``, the daemon load-generator floor ``serve_daemon``, the
distributed-round throughput floor ``dist_round`` and, when present, the
worker-pool ``speedups_serial_over_parallel``) and emits
a GitHub Actions ``::warning``
when a measured speedup regresses more than 25% below its baseline value.
Warn-only by design: shared CI runners are noisy, so regressions flag for a
human instead of failing the build. Exit code is 0 unless the inputs are
unreadable or a tracked entry is missing entirely.
"""

import json
import sys

REGRESSION_FACTOR = 0.75  # warn below 75% of baseline (>25% regression)
TRACKED_SECTIONS = (
    "speedups_scalar_over_kernel",
    "speedups_step_overlap",
    "speedups_serve",
    "serve_daemon",
    "dist_round",
    "speedups_serial_over_parallel",
)


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        measured = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    missing = False
    for section in TRACKED_SECTIONS:
        base_entries = baseline.get(section) or {}
        got_entries = measured.get(section) or {}
        for key, base in sorted(base_entries.items()):
            got = got_entries.get(key)
            if got is None:
                print(f"::error::bench entry {section}.{key} missing from results")
                missing = True
                continue
            status = "ok"
            if got < base * REGRESSION_FACTOR:
                print(
                    f"::warning::hot-path speedup regression: {key} measured "
                    f"{got:.2f}x vs baseline {base:.2f}x (>25% below baseline)"
                )
                status = "REGRESSED"
            print(f"bench-diff {key:<16} measured {got:6.2f}x  baseline {base:6.2f}x  {status}")
    return 1 if missing else 0


if __name__ == "__main__":
    sys.exit(main())
