//! E5 — Theorem 2 harness: regenerates the SNR-vs-noise-distribution table
//! and measures the cost of the analytic vs Monte-Carlo estimators.

use adv_softmax::exp::snr::{analytic_snr, monte_carlo_snr, run, SnrOpts};
use adv_softmax::utils::bench::{black_box, Bench};
use adv_softmax::utils::Rng;

fn main() -> anyhow::Result<()> {
    // regenerate the table (also writes results/snr.csv)
    let opts = SnrOpts::default();
    let points = run(&opts)?;
    let best = points
        .iter()
        .max_by(|a, b| a.analytic.total_cmp(&b.analytic))
        .unwrap();
    assert!(best.name.contains("adversarial"), "Theorem 2 shape violated");

    // estimator costs
    let bench = Bench::new(2, 10, 1.0);
    let (g, c) = (opts.num_contexts, opts.num_classes);
    let mut rng = Rng::new(3);
    let p_d: Vec<f64> = {
        // same construction as exp::snr::make_p_d but local to the bench
        let mut p = vec![0f64; g * c];
        for row in p.chunks_exact_mut(c) {
            let mut z = 0.0;
            for v in row.iter_mut() {
                *v = (2.0 * rng.normal() as f64).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
        p
    };
    let uni = vec![1.0 / c as f64; g * c];
    bench.run("snr/analytic(G=8,C=16)", || {
        black_box(analytic_snr(&p_d, &uni, g, c));
    });
    bench.run("snr/monte_carlo(20k samples)", || {
        black_box(monte_carlo_snr(&p_d, &uni, g, c, 20_000, &mut rng));
    });
    Ok(())
}
