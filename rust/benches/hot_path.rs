//! Component micro-benchmarks for the L3 hot path (perf pass, DESIGN.md §7).
//!
//! Measures each stage of a training step in isolation: batch assembly
//! (tree descents), parameter gather, literal creation, PJRT execute,
//! gradient scatter (Adagrad). The sum should roughly match the end-to-end
//! step time measured in figure1_convergence; discrepancies localize
//! overheads.

use adv_softmax::config::{DatasetPreset, Method, RunConfig, SyntheticConfig, TreeConfig};
use adv_softmax::data::Splits;
use adv_softmax::model::ParamStore;
use adv_softmax::runtime::{lit_f32, Registry};
use adv_softmax::sampler::{AdversarialSampler, NoiseSampler};
use adv_softmax::train::{BatchGen, BatchMode, SamplerKind, TrainRun};
use adv_softmax::utils::bench::{black_box, Bench};
use adv_softmax::utils::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let bench = Bench::default();
    let syn = SyntheticConfig::preset(DatasetPreset::Tiny);
    let splits = Splits::synthetic(&syn);
    let data = Arc::new(splits.train.clone());
    let (b, k, c) = (256usize, data.feat_dim, data.num_classes);
    let mut rng = Rng::new(1);

    // --- linalg ---
    let va: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let vb: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    bench.run("linalg/dot_64", || {
        black_box(adv_softmax::linalg::dot(black_box(&va), black_box(&vb)));
    });

    // --- tree sampling / log-prob ---
    let tcfg = TreeConfig { aux_dim: 16, ..Default::default() };
    let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 1);
    let x0 = data.x(0).to_vec();
    let mut srng = Rng::new(2);
    bench.run("sampler/adversarial_sample(C=256)", || {
        black_box(adv.sample(black_box(&x0), &mut srng));
    });
    bench.run("sampler/adversarial_log_prob", || {
        black_box(adv.log_prob(black_box(&x0), 17));
    });
    let mut lps = vec![0f32; c];
    bench.run("sampler/log_prob_all(C=256)", || {
        adv.log_prob_all(black_box(&x0), &mut lps);
        black_box(&lps);
    });

    // --- batch assembly (the pipelined worker's unit of work) ---
    let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
    let sk = SamplerKind::Adversarial { sampler: Arc::new(adv.clone()), x_proj };
    let mut gen = BatchGen::new(data.clone(), sk, BatchMode::NsLike, b, 1.0, Rng::new(3));
    bench.run("batcher/next_batch(B=256,adversarial)", || {
        black_box(gen.next_batch());
    });

    // --- parameter gather + Adagrad scatter ---
    let mut params = ParamStore::zeros(c, k, 0.05);
    let labels: Vec<u32> = (0..b).map(|_| srng.below(c) as u32).collect();
    let mut wbuf = vec![0f32; b * k];
    let mut bbuf = vec![0f32; b];
    bench.run("params/gather(B=256,K=64)", || {
        params.gather(black_box(&labels), &mut wbuf, &mut bbuf);
        black_box(&wbuf);
    });
    let gw: Vec<f32> = (0..b * k).map(|_| srng.normal() * 0.01).collect();
    let gb: Vec<f32> = (0..b).map(|_| srng.normal() * 0.01).collect();
    bench.run("params/adagrad_scatter(B=256,K=64)", || {
        params.apply_sparse(black_box(&labels), black_box(&gw), black_box(&gb));
    });

    // --- literal creation + PJRT execute ---
    let registry = Registry::open_default()?;
    bench.run("runtime/lit_f32(B*K=16k)", || {
        black_box(lit_f32(black_box(&gw), &[b, k]).unwrap());
    });
    let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
    cfg.pipelined = false;
    let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
    bench.run("train/step_once(adversarial,B=256)", || {
        black_box(run.step_once().unwrap());
    });

    Ok(())
}
