//! Component micro-benchmarks for the L3 hot path (perf pass, DESIGN.md §7).
//!
//! Measures each stage of a training step in isolation — batch assembly
//! (tree descents), parameter gather, Adagrad scatter, the eval sweep,
//! literal creation, PJRT execute — and, for every pool-sharded stage, the
//! serial vs. `parallelism = 4` comparison that tracks the multi-worker
//! hot-path refactor. Results are also written to `BENCH_hot_path.json`
//! (cwd) so later PRs can diff the perf trajectory mechanically.
//!
//! The PJRT-dependent cases are skipped with a notice when artifacts (or
//! the real xla runtime) are unavailable; all host-side cases always run.

use adv_softmax::config::{
    DaemonConfig, DatasetPreset, Method, OverlapMode, QuantMode, RunConfig, ServeConfig,
    SyntheticConfig, TreeConfig,
};
use adv_softmax::data::Splits;
use adv_softmax::eval::LpnCache;
use adv_softmax::linalg::Pca;
use adv_softmax::model::ParamStore;
use adv_softmax::runtime::{lit_f32, read_f32, Registry};
use adv_softmax::sampler::{AdversarialSampler, NoiseSampler};
use adv_softmax::serve::daemon::{Daemon, ManualClock, RealClock};
use adv_softmax::serve::{Predictor, ServingModel};
use adv_softmax::train::{
    BatchGen, BatchMode, BatchSource, SamplerKind, StepEngine, StepExecutor, TrainRun,
};
use adv_softmax::tree::fit::{fit_tree, fit_tree_with};
use adv_softmax::tree::{BeamScratch, Tree, TreeKernel};
use adv_softmax::utils::bench::{black_box, Bench, BenchStats};
use adv_softmax::utils::json::Json;
use adv_softmax::utils::{Pool, Rng};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Worker count for the parallel variants (the acceptance-bar setting).
const PAR: usize = 4;

/// (summary key, serial case, parallel case) for the tracked speedups.
const SPEEDUP_PAIRS: [(&str, &str, &str); 6] = [
    ("batch_assembly", "batcher/next_batch(serial)", "batcher/pipeline(workers=4)"),
    ("gather", "params/gather(serial)", "params/gather(workers=4)"),
    ("scatter", "params/adagrad_scatter(serial)", "params/adagrad_scatter(workers=4)"),
    ("eval_sweep", "eval/lpn_cache(serial)", "eval/lpn_cache(workers=4)"),
    ("pca_fit", "fit/pca(serial)", "fit/pca(workers=4)"),
    ("tree_fit", "fit/tree(serial)", "fit/tree(workers=4)"),
];

/// (summary key, scalar-walker case, SIMD-width kernel case) for the
/// single-thread lane-major kernel speedups (PR 3 acceptance bar: ≥ 1.5×;
/// CI's bench-smoke job diffs these against `benches/hot_path_baseline.json`).
const KERNEL_PAIRS: [(&str, &str, &str); 2] = [
    ("descent_batch", "tree/descents(scalar)", "tree/descents(batch8)"),
    ("act_sweep", "tree/act_sweep(scalar)", "tree/act_sweep(batch8)"),
];

/// (summary key, serial-protocol case, overlapped case) for the
/// double-buffered step engine (PR 4 acceptance bar: ≥ 1.2× at
/// `parallelism ≥ 2`; diffed against the committed baseline like the
/// kernel speedups).
const OVERLAP_PAIRS: [(&str, &str, &str); 1] =
    [("step_overlap", "train/step(serial)", "train/step(overlapped)")];

/// (summary key, double-buffered case, three-deep pipelined case) for the
/// dedicated-execute-thread step engine (PR 10 acceptance bar: ≥ 1.15×
/// over the depth-2 protocol at parallelism ≥ 2; diffed against the
/// committed baseline like the rest).
const PIPELINE_PAIRS: [(&str, &str, &str); 1] =
    [("step_pipeline", "train/step(overlapped)", "train/step(pipelined)")];

/// (summary key, exact-oracle case, beam-retrieval case) for the serving
/// top-k path (PR 5 acceptance bar: beam ≥ 2× over the exact O(C) sweep
/// at C ≥ 10k; diffed against the committed baseline like the rest).
const SERVE_PAIRS: [(&str, &str, &str); 1] =
    [("serve_beam", "serve/topk(exact)", "serve/topk(beam)")];

/// (summary key, sequential-RNG kernel, counter-mode kernel) for the
/// lane-RNG descent sampler (PR 9 acceptance bar: ≥ 1.3× — the serial
/// per-lane xoshiro advance was the last sequential dependency in the
/// sample kernel's inner loop).
const RNG_PAIRS: [(&str, &str, &str); 1] =
    [("lane_rng", "tree/descents(serial_rng)", "tree/descents(batch8)")];

/// (summary key, per-prefix descent, 8-lane descent) for the beam search
/// (PR 9 acceptance bar: ≥ 1.5× at the default serving beam width).
const BEAM8_PAIRS: [(&str, &str, &str); 1] =
    [("beam8", "serve/beam_topk(scalar)", "serve/beam_topk(lane8)")];

/// (summary key, f32-row sweep, f16-row sweep) for quantized serving
/// (PR 9 acceptance bar: ≥ 1.5× on the exact O(C) scoring sweep at
/// C = 16384, where the row bytes dominate — the sweep is memory-bound).
const QUANT_PAIRS: [(&str, &str, &str); 1] =
    [("quant_f16", "serve/topk(exact)", "serve/topk(exact,f16)")];

#[derive(Default)]
struct Report {
    results: Vec<(String, BenchStats)>,
}

impl Report {
    fn record(&mut self, name: &str, stats: BenchStats) {
        self.results.push((name.to_string(), stats));
    }

    fn median(&self, name: &str) -> Option<f64> {
        self.results
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.median_ns)
    }

    fn speedup(&self, serial: &str, parallel: &str) -> Option<f64> {
        match (self.median(serial), self.median(parallel)) {
            (Some(s), Some(p)) if p > 0.0 => Some(s / p),
            _ => None,
        }
    }

    fn to_json(&self) -> Json {
        let cases = Json::Obj(
            self.results
                .iter()
                .map(|(name, s)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            ("median_ns", Json::Num(s.median_ns)),
                            ("mean_ns", Json::Num(s.mean_ns)),
                            ("p10_ns", Json::Num(s.p10_ns)),
                            ("p90_ns", Json::Num(s.p90_ns)),
                            ("iters", Json::Num(s.iters as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let speedups = Json::Obj(
            SPEEDUP_PAIRS
                .iter()
                .filter_map(|(key, s, p)| {
                    self.speedup(s, p).map(|x| (key.to_string(), Json::Num(x)))
                })
                .collect(),
        );
        let kernel_speedups = Json::Obj(
            KERNEL_PAIRS
                .iter()
                .filter_map(|(key, s, p)| {
                    self.speedup(s, p).map(|x| (key.to_string(), Json::Num(x)))
                })
                .collect(),
        );
        let overlap_speedups = Json::Obj(
            OVERLAP_PAIRS
                .iter()
                .filter_map(|(key, s, p)| {
                    self.speedup(s, p).map(|x| (key.to_string(), Json::Num(x)))
                })
                .collect(),
        );
        let serve_speedups = Json::Obj(
            SERVE_PAIRS
                .iter()
                .filter_map(|(key, s, p)| {
                    self.speedup(s, p).map(|x| (key.to_string(), Json::Num(x)))
                })
                .collect(),
        );
        let pair_section = |pairs: &[(&str, &str, &str)]| {
            Json::Obj(
                pairs
                    .iter()
                    .filter_map(|&(key, s, p)| {
                        self.speedup(s, p).map(|x| (key.to_string(), Json::Num(x)))
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("bench", Json::Str("hot_path".into())),
            ("parallel_workers", Json::Num(PAR as f64)),
            ("results", cases),
            ("speedups_serial_over_parallel", speedups),
            ("speedups_scalar_over_kernel", kernel_speedups),
            ("speedups_step_overlap", overlap_speedups),
            ("speedups_step_pipeline", pair_section(&PIPELINE_PAIRS)),
            ("speedups_serve", serve_speedups),
            ("speedups_rng", pair_section(&RNG_PAIRS)),
            ("speedups_beam8", pair_section(&BEAM8_PAIRS)),
            ("speedups_quant", pair_section(&QUANT_PAIRS)),
        ])
    }
}

fn main() -> anyhow::Result<()> {
    let bench = Bench::default();
    let mut report = Report::default();
    let syn = SyntheticConfig::preset(DatasetPreset::Tiny);
    let splits = Splits::synthetic(&syn);
    let data = Arc::new(splits.train.clone());
    let (b, k, c) = (256usize, data.feat_dim, data.num_classes);
    let mut rng = Rng::new(1);
    let pool = Pool::new(PAR);

    // --- linalg ---
    let va: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let vb: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let s = bench.run("linalg/dot_64", || {
        black_box(adv_softmax::linalg::dot(black_box(&va), black_box(&vb)));
    });
    report.record("linalg/dot_64", s);

    // --- tree sampling / log-prob ---
    let tcfg = TreeConfig { aux_dim: 16, ..Default::default() };
    let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 1);
    let x0 = data.x(0).to_vec();
    let mut srng = Rng::new(2);
    let s = bench.run("sampler/adversarial_sample(C=256)", || {
        black_box(adv.sample(black_box(&x0), &mut srng));
    });
    report.record("sampler/adversarial_sample(C=256)", s);
    let s = bench.run("sampler/adversarial_log_prob", || {
        black_box(adv.log_prob(black_box(&x0), 17));
    });
    report.record("sampler/adversarial_log_prob", s);
    let mut lps = vec![0f32; c];
    let s = bench.run("sampler/log_prob_all(C=256)", || {
        adv.log_prob_all(black_box(&x0), &mut lps);
        black_box(&lps);
    });
    report.record("sampler/log_prob_all(C=256)", s);

    // --- SIMD-width tree kernels vs the retained scalar walkers ---
    // Synthetic random tree at C = 4096 (depth 12, k = 16): big enough that
    // the weight set stresses the cache hierarchy like a real label space,
    // forced-free so both paths take the branch-free route. The scalar
    // cases are the oracle walkers the parity suite pins the kernels to.
    {
        let (kc, kk, km, ktile) = (4096usize, 16usize, 256usize, 8usize);
        let mut trng = Rng::new(41);
        let tw: Vec<f32> = (0..(kc - 1) * kk).map(|_| 0.3 * trng.normal()).collect();
        let tb: Vec<f32> = (0..kc - 1).map(|_| 0.1 * trng.normal()).collect();
        let ktree = Tree {
            aux_dim: kk,
            num_classes: kc,
            num_leaves: kc,
            depth: 12,
            w: tw,
            b: tb,
            forced: vec![0; kc - 1],
            label_of_leaf: (0..kc as u32).collect(),
            leaf_of_label: (0..kc as u32).collect(),
        };
        let kern = TreeKernel::build(&ktree);
        let xk: Vec<f32> = (0..km * kk).map(|_| trng.normal()).collect();
        let rng_base = Rng::new(77);
        let mut rngs: Vec<Rng> = (0..km).map(|j| rng_base.stream(1, j as u64)).collect();
        let mut labels = vec![0u32; km];
        let mut logps = vec![0f32; km];
        let s = bench.run("tree/descents(scalar)", || {
            for j in 0..km {
                let (y, lp) = ktree.sample(&xk[j * kk..(j + 1) * kk], &mut rngs[j]);
                labels[j] = y;
                logps[j] = lp;
            }
            black_box(&labels);
        });
        report.record("tree/descents(scalar)", s);
        let s = bench.run("tree/descents(batch8)", || {
            kern.sample_batch(&xk, &mut rngs, &mut labels, &mut logps);
            black_box(&labels);
        });
        report.record("tree/descents(batch8)", s);
        // the retained sequential-xoshiro lane kernel: same dots and
        // sigmoid lanes, but each level's uniforms advance 8 private RNG
        // states serially — the speedup over this is the lane-RNG floor
        let s = bench.run("tree/descents(serial_rng)", || {
            kern.sample_batch_serial_rng(&xk, &mut rngs, &mut labels, &mut logps);
            black_box(&labels);
        });
        report.record("tree/descents(serial_rng)", s);

        let nn = kc - 1;
        let mut acts = vec![0f32; ktile * nn];
        let s = bench.run("tree/act_sweep(scalar)", || {
            for j in 0..ktile {
                ktree.node_activations(
                    &xk[j * kk..(j + 1) * kk],
                    &mut acts[j * nn..(j + 1) * nn],
                );
            }
            black_box(&acts);
        });
        report.record("tree/act_sweep(scalar)", s);
        let s = bench.run("tree/act_sweep(batch8)", || {
            kern.node_activations_batch(&xk[..ktile * kk], ktile, &mut acts);
            black_box(&acts);
        });
        report.record("tree/act_sweep(batch8)", s);
    }

    // --- batch assembly: serial descents vs the M-worker pipeline ---
    let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
    let adv_arc = Arc::new(adv.clone());
    let make_gen = |seed: u64| {
        BatchGen::new(
            data.clone(),
            SamplerKind::Adversarial { sampler: adv_arc.clone(), x_proj: x_proj.clone() },
            BatchMode::NsLike,
            b,
            1.0,
            Rng::new(seed),
        )
    };
    let mut serial_src = BatchSource::inline(make_gen(3));
    let s = bench.run("batcher/next_batch(serial)", || {
        let batch = serial_src.next();
        black_box(&batch);
        serial_src.recycle(batch);
    });
    report.record("batcher/next_batch(serial)", s);
    {
        let gen = make_gen(3);
        let mut piped = BatchSource::pipelined(&gen, PAR);
        // measure steady-state consumption throughput of the pipeline
        let s = bench.run("batcher/pipeline(workers=4)", || {
            let batch = piped.next();
            black_box(&batch);
            piped.recycle(batch);
        });
        report.record("batcher/pipeline(workers=4)", s);
    }

    // --- parameter gather + Adagrad scatter, serial vs sharded ---
    let mut params = ParamStore::zeros(c, k, 0.05);
    let labels: Vec<u32> = (0..b).map(|_| srng.below(c) as u32).collect();
    let mut wbuf = vec![0f32; b * k];
    let mut bbuf = vec![0f32; b];
    let s = bench.run("params/gather(serial)", || {
        params.gather(black_box(&labels), &mut wbuf, &mut bbuf);
        black_box(&wbuf);
    });
    report.record("params/gather(serial)", s);
    let s = bench.run("params/gather(workers=4)", || {
        params.gather_par(&pool, black_box(&labels), &mut wbuf, &mut bbuf);
        black_box(&wbuf);
    });
    report.record("params/gather(workers=4)", s);
    let gw: Vec<f32> = (0..b * k).map(|_| srng.normal() * 0.01).collect();
    let gb: Vec<f32> = (0..b).map(|_| srng.normal() * 0.01).collect();
    let s = bench.run("params/adagrad_scatter(serial)", || {
        params.apply_sparse(black_box(&labels), black_box(&gw), black_box(&gb));
    });
    report.record("params/adagrad_scatter(serial)", s);
    let s = bench.run("params/adagrad_scatter(workers=4)", || {
        params.apply_sparse_par(&pool, black_box(&labels), black_box(&gw), black_box(&gb));
    });
    report.record("params/adagrad_scatter(workers=4)", s);

    // --- eval sweep (Eq. 5 correction cache), serial vs sharded ---
    let eval_set = splits.test.subsample(512, &mut Rng::new(7));
    let s = bench.run("eval/lpn_cache(serial)", || {
        black_box(LpnCache::build(&adv_arc, &eval_set));
    });
    report.record("eval/lpn_cache(serial)", s);
    let s = bench.run("eval/lpn_cache(workers=4)", || {
        black_box(LpnCache::build_with(&adv_arc, &eval_set, &pool));
    });
    report.record("eval/lpn_cache(workers=4)", s);

    // --- serving top-k: exact O(C) oracle sweep vs tree-guided beam
    // search + exact re-rank, at C = 16384 (above the 10k acceptance bar).
    // Synthetic random tree like the kernel bench (depth 14, forced-free)
    // with an axis-projection PCA and random classifier rows; raw-ξ
    // scoring isolates retrieval cost (correction costs land on both
    // paths identically). 64 queries per iteration amortize scratch setup
    // the way the request batcher does in serving.
    let daemon_json: Json;
    {
        let (sc, sk, saux, sq) = (16_384usize, 64usize, 16usize, 64usize);
        let mut srng2 = Rng::new(51);
        let tw: Vec<f32> = (0..(sc - 1) * saux).map(|_| 0.3 * srng2.normal()).collect();
        let tb: Vec<f32> = (0..sc - 1).map(|_| 0.1 * srng2.normal()).collect();
        let stree = Tree {
            aux_dim: saux,
            num_classes: sc,
            num_leaves: sc,
            depth: 14,
            w: tw,
            b: tb,
            forced: vec![0; sc - 1],
            label_of_leaf: (0..sc as u32).collect(),
            leaf_of_label: (0..sc as u32).collect(),
        };
        let skern = TreeKernel::build(&stree);

        // --- 8-lane beam descent vs the per-prefix scalar oracle (PR 9),
        // at the default serving beam width on the same C = 16384 tree.
        // Proptest pins the two bit-identical; this measures the win.
        {
            let beam_w = ServeConfig::default().beam;
            let projs: Vec<f32> = (0..sq * saux).map(|_| srng2.normal()).collect();
            let mut cands: Vec<(u32, f32)> = Vec::new();
            let mut bscr = BeamScratch::default();
            let s = bench.run("serve/beam_topk(scalar)", || {
                for t in 0..sq {
                    skern.beam_topk_scalar(
                        &projs[t * saux..(t + 1) * saux],
                        beam_w,
                        &mut cands,
                        &mut bscr,
                    );
                }
                black_box(&cands);
            });
            report.record("serve/beam_topk(scalar)", s);
            let s = bench.run("serve/beam_topk(lane8)", || {
                for t in 0..sq {
                    skern.beam_topk(
                        &projs[t * saux..(t + 1) * saux],
                        beam_w,
                        &mut cands,
                        &mut bscr,
                    );
                }
                black_box(&cands);
            });
            report.record("serve/beam_topk(lane8)", s);
        }

        let spca = Pca {
            mean: vec![0.0; sk],
            components: (0..saux)
                .map(|i| {
                    let mut row = vec![0f32; sk];
                    row[i] = 1.0;
                    row
                })
                .collect(),
            proj_bias: vec![0.0; saux],
            input_dim: sk,
            output_dim: saux,
        };
        let saux_model = AdversarialSampler { pca: spca, tree: stree, kernel: skern };
        let model = Arc::new(ServingModel {
            num_classes: sc,
            feat_dim: sk,
            w: (0..sc * sk).map(|_| 0.1 * srng2.normal()).collect(),
            b: (0..sc).map(|_| 0.01 * srng2.normal()).collect(),
            aux: Some(saux_model),
            correct_bias: false,
        });
        let queries: Vec<f32> = (0..sq * sk).map(|_| srng2.normal()).collect();
        let serve_pool = Pool::serial();
        // quantize pinned per case (not env-defaulted): the serve_beam
        // pair stays an f32-vs-f32 comparison even under REPRO_QUANTIZE,
        // and the quant_f16 pair isolates the row-storage change alone
        let exact_pred = Predictor::new(
            &model,
            ServeConfig { exact: true, quantize: QuantMode::Off, ..Default::default() },
        )
        .unwrap();
        let beam_pred =
            Predictor::new(&model, ServeConfig { quantize: QuantMode::Off, ..Default::default() })
                .unwrap();
        let s = bench.run("serve/topk(exact)", || {
            black_box(exact_pred.predict_batch_with(black_box(&queries), sq, &serve_pool));
        });
        report.record("serve/topk(exact)", s);
        let s = bench.run("serve/topk(beam)", || {
            black_box(beam_pred.predict_batch_with(black_box(&queries), sq, &serve_pool));
        });
        report.record("serve/topk(beam)", s);

        // --- f16-row exact sweep (PR 9): half the bytes through the
        // memory-bound O(C·K) scoring loop, f32 accumulation unchanged.
        let f16_pred = Predictor::new(
            &model,
            ServeConfig { exact: true, quantize: QuantMode::F16, ..Default::default() },
        )
        .unwrap();
        let s = bench.run("serve/topk(exact,f16)", || {
            black_box(f16_pred.predict_batch_with(black_box(&queries), sq, &serve_pool));
        });
        report.record("serve/topk(exact,f16)", s);

        // --- serving daemon load generator (PR 6, same C = 16384 model).
        // Closed loop: 32 virtual clients with one outstanding request
        // each; when every client is waiting the input is quiet, so
        // pump(true) flushes — throughput and latency percentiles of the
        // admission + micro-batch + worker pipeline end to end. CI diffs
        // `closed_qps` against benches/hot_path_baseline.json.
        let dcfg = DaemonConfig {
            queue_capacity: 1024,
            deadline_ms: 250,
            max_batch: 64,
            degrade_beams: vec![16, 4],
            overload_trip: 3,
            worker_timeout_ms: 10_000,
        };
        let mut d = Daemon::new(
            model.clone(),
            ServeConfig::default(),
            dcfg,
            PAR,
            None,
            Box::new(RealClock::new()),
        )?;
        let n_closed = 1024usize;
        let v_clients = 32usize;
        let mut starts = vec![Duration::ZERO; n_closed];
        let mut lat_ms: Vec<f64> = Vec::with_capacity(n_closed);
        let (mut issued, mut done, mut inflight) = (0usize, 0usize, 0usize);
        let t0 = Instant::now();
        while done < n_closed {
            while issued < n_closed && inflight < v_clients {
                let qi = issued % sq;
                let (id, immediate) = d.submit_features(&queries[qi * sk..(qi + 1) * sk]);
                starts[id as usize] = t0.elapsed();
                issued += 1;
                match immediate {
                    Some(_) => done += 1, // shed at admission (not closed-loop normal)
                    None => inflight += 1,
                }
            }
            for r in d.pump(true) {
                let waited = t0.elapsed().saturating_sub(starts[r.id as usize]);
                lat_ms.push(waited.as_secs_f64() * 1e3);
                done += 1;
                inflight -= 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let cs = d.stats();
        let closed_qps = (cs.ok + cs.degraded) as f64 / wall.max(1e-9);
        lat_ms.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| -> f64 {
            if lat_ms.is_empty() {
                return 0.0;
            }
            lat_ms[((lat_ms.len() - 1) as f64 * p) as usize]
        };
        let (closed_p50, closed_p99) = (pct(0.50), pct(0.99));

        // Open loop: seeded bursty arrivals on a virtual clock (bursts
        // model a stalled upstream flushing its backlog; stalls push
        // queued requests past their deadline), so the shed / degraded /
        // deadline accounting is reproducible regardless of machine
        // speed. Rates are recorded for the trajectory file, not floored.
        let ocfg = DaemonConfig {
            queue_capacity: 40,
            deadline_ms: 20,
            max_batch: 16,
            degrade_beams: vec![16, 4],
            overload_trip: 1,
            worker_timeout_ms: 10_000,
        };
        let oclock = ManualClock::new();
        let mut d = Daemon::new(
            model.clone(),
            ServeConfig::default(),
            ocfg,
            PAR,
            None,
            Box::new(oclock.clone()),
        )?;
        let mut arng = Rng::new(4242);
        let n_open = 1024usize;
        let mut submitted = 0usize;
        while submitted < n_open {
            if arng.next_f64() < 0.08 {
                let burst = 24 + arng.below(32);
                for _ in 0..burst.min(n_open - submitted) {
                    let qi = submitted % sq;
                    d.submit_features(&queries[qi * sk..(qi + 1) * sk]);
                    submitted += 1;
                }
            } else {
                oclock.advance(1 + arng.below(3) as u64);
                let qi = submitted % sq;
                d.submit_features(&queries[qi * sk..(qi + 1) * sk]);
                submitted += 1;
            }
            if arng.next_f64() < 0.05 {
                oclock.advance(25); // stall past the deadline
            }
            d.pump(false);
        }
        oclock.advance(25);
        d.drain();
        let os = d.stats();
        let total = (os.submitted as f64).max(1.0);
        daemon_json = Json::obj(vec![
            ("closed_clients", Json::Num(v_clients as f64)),
            ("closed_requests", Json::Num(n_closed as f64)),
            ("closed_qps", Json::Num(closed_qps)),
            ("closed_p50_ms", Json::Num(closed_p50)),
            ("closed_p99_ms", Json::Num(closed_p99)),
            ("open_requests", Json::Num(os.submitted as f64)),
            ("open_ok_rate", Json::Num(os.ok as f64 / total)),
            ("open_degraded_rate", Json::Num(os.degraded as f64 / total)),
            ("open_shed_rate", Json::Num(os.shed_queue_full as f64 / total)),
            ("open_deadline_rate", Json::Num(os.rejected_deadline as f64 / total)),
        ]);
        println!(
            "serve_daemon closed-loop {closed_qps:.0} qps (p50 {closed_p50:.2} ms, \
             p99 {closed_p99:.2} ms, clients={v_clients})"
        );
        println!(
            "serve_daemon open-loop ok={} degraded={} shed={} deadline={} of {}",
            os.ok, os.degraded, os.shed_queue_full, os.rejected_deadline, os.submitted
        );
    }

    // --- distributed round protocol (PR 7): SimNet round throughput.
    // Pure host path (coordinator + 2 clients on a ManualClock), so it
    // always runs; small-but-real shapes keep the gradient math and frame
    // encode/parse on the measured path. The reassign case pays for a full
    // lease expiry, eviction, deterministic reassignment and a rejoin
    // through Warmup, so it is the floor for failover cost. CI diffs both
    // rates against benches/hot_path_baseline.json (higher is better).
    let dist_round_json: Json;
    {
        use adv_softmax::config::DistConfig;
        use adv_softmax::dist::{Phase, SimNet};
        let dcfg = DistConfig {
            clients: 2,
            rounds: 8,
            batches_per_round: 8,
            batch_size: 32,
            num_classes: 256,
            feat_dim: 16,
            lr: 0.05,
            seed: 11,
            lease_ms: 1000,
            resend_ms: 200,
        };
        let runs = 3usize;
        let t0 = Instant::now();
        for _ in 0..runs {
            let mut net = SimNet::new(dcfg.clone(), 2, None)?;
            anyhow::ensure!(net.run_to_completion(5000)?, "dist bench run wedged");
        }
        let clean_secs = t0.elapsed().as_secs_f64();
        let rounds_per_sec = (runs * dcfg.rounds) as f64 / clean_secs.max(1e-9);
        let t0 = Instant::now();
        for _ in 0..runs {
            let mut net = SimNet::new(dcfg.clone(), 2, None)?;
            while net.coord().phase() != Phase::Train {
                net.step()?;
            }
            net.kill(1);
            // rejoin before the lease lapses so the failover (eviction,
            // reassignment, rejoin through Warmup) is all on the clock
            for _ in 0..10 {
                net.step()?;
            }
            net.rejoin(1);
            anyhow::ensure!(net.run_to_completion(5000)?, "dist reassign bench run wedged");
        }
        let reassign_secs = t0.elapsed().as_secs_f64();
        let reassign_rounds_per_sec = (runs * dcfg.rounds) as f64 / reassign_secs.max(1e-9);
        dist_round_json = Json::obj(vec![
            ("rounds_per_sec", Json::Num(rounds_per_sec)),
            ("reassign_rounds_per_sec", Json::Num(reassign_rounds_per_sec)),
        ]);
        println!(
            "dist_round clean {rounds_per_sec:.1} rounds/s, kill+rejoin \
             {reassign_rounds_per_sec:.1} rounds/s (2 clients, B=8x32, C=256)"
        );
    }

    // --- step engine: serial vs double-buffered (PR 4) vs the three-deep
    // execute pipeline (PR 10). The PJRT execute is gated in this
    // environment, so the device half is a deterministic host mock: the
    // logistic-NS row gradients recomputed DEVICE_PASSES times, putting
    // the emulated kernel latency on the same order as the host stages
    // the engine must hide (the overlap win is measured where it matters
    // — device time ≈ prefetchable host time; with a much slower device
    // all protocols converge to device-bound). When artifacts are
    // available the real TrainRun is measured under all settings as well
    // (below). The gradient math is a hand-synced copy of MockNsGrad in
    // tests/overlap_parity.rs (bench targets can't import test modules
    // without shipping test support in the lib); change the NS input
    // layout in both places.
    let step_stage_json: Json;
    {
        struct MockNsExec {
            b: usize,
            k: usize,
        }
        /// Gradient passes emulating the Pallas kernel's latency.
        const DEVICE_PASSES: usize = 8;
        impl StepExecutor for MockNsExec {
            fn run_step(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
                let (b, k) = (self.b, self.k);
                let x = read_f32(&inputs[0])?;
                let wp = read_f32(&inputs[1])?;
                let bp = read_f32(&inputs[2])?;
                let wn = read_f32(&inputs[3])?;
                let bn = read_f32(&inputs[4])?;
                let lpn_p = read_f32(&inputs[5])?;
                let lpn_n = read_f32(&inputs[6])?;
                let lam = read_f32(&inputs[7])?[0];
                let mut loss = vec![0f32; b];
                let mut gwp = vec![0f32; b * k];
                let mut gbp = vec![0f32; b];
                let mut gwn = vec![0f32; b * k];
                let mut gbn = vec![0f32; b];
                for _pass in 0..DEVICE_PASSES {
                    for i in 0..b {
                        let xi = &x[i * k..(i + 1) * k];
                        let xp = wp[i * k..(i + 1) * k]
                            .iter()
                            .zip(xi.iter())
                            .map(|(w, v)| w * v)
                            .sum::<f32>()
                            + bp[i];
                        let xn = wn[i * k..(i + 1) * k]
                            .iter()
                            .zip(xi.iter())
                            .map(|(w, v)| w * v)
                            .sum::<f32>()
                            + bn[i];
                        let up = xp - lpn_p[i];
                        let un = xn - lpn_n[i];
                        loss[i] = (1.0 + (-up).exp()).ln() + (1.0 + un.exp()).ln();
                        let dp = -1.0 / (1.0 + up.exp());
                        let dn = 1.0 / (1.0 + (-un).exp());
                        gbp[i] = dp;
                        gbn[i] = dn;
                        for j in 0..k {
                            gwp[i * k + j] = dp * xi[j] + lam * wp[i * k + j];
                            gwn[i * k + j] = dn * xi[j] + lam * wn[i * k + j];
                        }
                    }
                }
                Ok(vec![
                    lit_f32(&loss, &[b])?,
                    lit_f32(&gwp, &[b, k])?,
                    lit_f32(&gbp, &[b])?,
                    lit_f32(&gwn, &[b, k])?,
                    lit_f32(&gbn, &[b])?,
                ])
            }
        }

        let exec = MockNsExec { b, k };
        let mut stage_rows = Vec::new();
        for (name, key, depth) in [
            ("train/step(serial)", "serial", 1usize),
            ("train/step(overlapped)", "overlapped", 2),
            ("train/step(pipelined)", "pipelined", 3),
        ] {
            let gen = make_gen(5);
            let mut src = BatchSource::pipelined(&gen, PAR);
            let mut step_params = ParamStore::zeros(c, k, 0.05);
            let mut engine = StepEngine::new(BatchMode::NsLike, b, k, 1e-3, depth);
            let s = bench.run(name, || {
                black_box(engine.step(&exec, &mut step_params, &pool, &mut src).unwrap());
            });
            report.record(name, s);
            // per-stage coordinator breakdown + execute occupancy (how
            // well the host stages hide behind the emulated device)
            let t = engine.times();
            println!("{name} {}", t.report());
            stage_rows.push((
                key.to_string(),
                Json::obj(vec![
                    ("execute_occupancy", Json::Num(t.execute_occupancy())),
                    ("gather_s", Json::Num(t.gather_s)),
                    ("pack_s", Json::Num(t.pack_s)),
                    ("execute_s", Json::Num(t.execute_s)),
                    ("readback_s", Json::Num(t.readback_s)),
                    ("scatter_s", Json::Num(t.scatter_s)),
                ]),
            ));
        }
        step_stage_json = Json::Obj(stage_rows.into_iter().collect());
    }

    // --- aux-model fit stages (the paper's one-off cost): PCA covariance
    // accumulation and the level-synchronous tree fit, serial vs sharded.
    // Both are bit-deterministic, so serial and parallel cases measure the
    // exact same computation (fit-parity tests enforce this). Lower
    // iteration floor than the micro cases (one fit is ~5 orders slower),
    // but the same REPRO_BENCH_SECONDS budget knob (CI smoke relies on it).
    let fit_bench = Bench::with_env_budget(1, 5, 0.5);
    let s = fit_bench.run("fit/pca(serial)", || {
        black_box(Pca::fit(&data.features, data.len(), k, tcfg.aux_dim, 1));
    });
    report.record("fit/pca(serial)", s);
    let s = fit_bench.run("fit/pca(workers=4)", || {
        black_box(Pca::fit_with(&data.features, data.len(), k, tcfg.aux_dim, 1, &pool));
    });
    report.record("fit/pca(workers=4)", s);
    let s = fit_bench.run("fit/tree(serial)", || {
        let mut frng = Rng::new(9);
        black_box(fit_tree(
            x_proj.as_slice(), &data.labels, data.len(), tcfg.aux_dim, c, &tcfg, &mut frng,
        ));
    });
    report.record("fit/tree(serial)", s);
    let s = fit_bench.run("fit/tree(workers=4)", || {
        let mut frng = Rng::new(9);
        black_box(fit_tree_with(
            x_proj.as_slice(), &data.labels, data.len(), tcfg.aux_dim, c, &tcfg, &mut frng,
            &pool,
        ));
    });
    report.record("fit/tree(workers=4)", s);

    // --- literal creation + PJRT execute (skipped without artifacts) ---
    match Registry::open_default() {
        Ok(registry) => {
            let s = bench.run("runtime/lit_f32(B*K=16k)", || {
                black_box(lit_f32(black_box(&gw), &[b, k]).unwrap());
            });
            report.record("runtime/lit_f32(B*K=16k)", s);
            let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
            cfg.pipelined = false;
            cfg.overlap = OverlapMode::Off;
            let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
            let s = bench.run("train/step_once(adversarial,B=256)", || {
                black_box(run.step_once().unwrap());
            });
            report.record("train/step_once(adversarial,B=256)", s);
            // the real artifact under both step protocols (pipelined
            // batches + parallelism 4, the acceptance-bar setting)
            for (name, mode) in [
                ("train/step_once(adversarial,serial)", OverlapMode::Off),
                ("train/step_once(adversarial,overlapped)", OverlapMode::On),
                ("train/step_once(adversarial,pipelined)", OverlapMode::Pipeline),
            ] {
                let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
                cfg.parallelism = PAR;
                cfg.overlap = mode;
                let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
                let s = bench.run(name, || {
                    black_box(run.step_once().unwrap());
                });
                report.record(name, s);
            }
        }
        Err(e) => {
            eprintln!("skipping PJRT benches (artifacts/runtime unavailable): {e:#}");
        }
    }

    // --- serial vs parallel summary + machine-readable trajectory file ---
    for (key, serial, parallel) in SPEEDUP_PAIRS {
        if let Some(x) = report.speedup(serial, parallel) {
            println!("speedup {key:<16} {x:>6.2}x  (workers={PAR})");
        }
    }
    for (key, scalar, kernel) in KERNEL_PAIRS {
        if let Some(x) = report.speedup(scalar, kernel) {
            println!("speedup {key:<16} {x:>6.2}x  (scalar walker vs lane kernel)");
        }
    }
    for (key, serial, overlapped) in OVERLAP_PAIRS {
        if let Some(x) = report.speedup(serial, overlapped) {
            println!("speedup {key:<16} {x:>6.2}x  (serial vs double-buffered step)");
        }
    }
    for (key, overlapped, pipelined) in PIPELINE_PAIRS {
        if let Some(x) = report.speedup(overlapped, pipelined) {
            println!("speedup {key:<16} {x:>6.2}x  (double-buffered vs three-deep pipeline)");
        }
    }
    for (key, exact, beamed) in SERVE_PAIRS {
        if let Some(x) = report.speedup(exact, beamed) {
            println!("speedup {key:<16} {x:>6.2}x  (exact O(C) sweep vs beam top-k)");
        }
    }
    for (key, serial, lane) in RNG_PAIRS {
        if let Some(x) = report.speedup(serial, lane) {
            println!("speedup {key:<16} {x:>6.2}x  (sequential-RNG vs counter-mode descents)");
        }
    }
    for (key, scalar, lane) in BEAM8_PAIRS {
        if let Some(x) = report.speedup(scalar, lane) {
            println!("speedup {key:<16} {x:>6.2}x  (per-prefix vs 8-lane beam descent)");
        }
    }
    for (key, f32c, f16c) in QUANT_PAIRS {
        if let Some(x) = report.speedup(f32c, f16c) {
            println!("speedup {key:<16} {x:>6.2}x  (f32 vs f16 rows, exact sweep)");
        }
    }
    let out = "BENCH_hot_path.json";
    let mut json = report.to_json();
    if let Json::Obj(m) = &mut json {
        m.insert("serve_daemon".to_string(), daemon_json);
        m.insert("dist_round".to_string(), dist_round_json);
        m.insert("step_stage_times".to_string(), step_stage_json);
    }
    std::fs::write(out, json.to_string())?;
    println!("wrote {out}");
    Ok(())
}
