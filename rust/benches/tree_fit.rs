//! E7 — auxiliary-model fit cost (Sec. 3 requirement (i): "subleading
//! computational overhead"). Measures greedy tree fitting across label-set
//! sizes and reports per-point-per-level cost, plus the quality (train
//! log-likelihood vs the uniform floor) and the level-sharded parallel
//! speedup (the parallel fit is bit-identical to the serial one, so both
//! cases measure the exact same computation).

use adv_softmax::config::TreeConfig;
use adv_softmax::tree::fit::{fit_tree, fit_tree_with};
use adv_softmax::utils::bench::Bench;
use adv_softmax::utils::{Pool, Rng};

fn main() {
    let bench = Bench::new(0, 2, 0.5);
    let pool = Pool::new(4);
    let k = 16;
    let mut rng = Rng::new(1);
    for (c, n) in [(256usize, 8_192usize), (1024, 16_384), (4096, 32_768)] {
        let mut x = vec![0f32; n * k];
        let mut y = vec![0u32; n];
        for i in 0..n {
            let lbl = rng.below(c) as u32;
            y[i] = lbl;
            for j in 0..k {
                x[i * k + j] = ((lbl as usize >> (j % 12)) & 1) as f32 * 2.0 - 1.0
                    + 0.4 * rng.normal();
            }
        }
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let mut loglik = 0.0;
        let stats = bench.run(&format!("tree_fit C={c} N={n}"), || {
            let mut frng = Rng::new(9);
            let (_, s) = fit_tree(&x, &y, n, k, c, &cfg, &mut frng);
            loglik = s.train_mean_loglik;
        });
        let mut loglik_par = 0.0;
        let stats_par = bench.run(&format!("tree_fit C={c} N={n} workers=4"), || {
            let mut frng = Rng::new(9);
            let (_, s) = fit_tree_with(&x, &y, n, k, c, &cfg, &mut frng, &pool);
            loglik_par = s.train_mean_loglik;
        });
        let levels = (c as f64).log2();
        println!(
            "  -> {:.0} ns/point/level, train loglik {:.3} (uniform floor {:.3}), \
             parallel speedup {:.2}x",
            stats.median_ns / (n as f64 * levels),
            loglik,
            -(c as f64).ln(),
            stats.median_ns / stats_par.median_ns,
        );
        assert!(loglik > -(c as f64).ln(), "tree must beat uniform");
        assert!(
            (loglik - loglik_par).abs() < 1e-12,
            "parallel fit must be bit-identical to serial ({loglik} vs {loglik_par})"
        );
    }
}
