//! E6 — the Sec. 3 complexity claim: drawing an adversarial negative costs
//! O(k log C), i.e. sampling time grows logarithmically in the label-set
//! size while uniform/alias sampling is O(1) and a full conditional
//! (softmax-style) pass is O(kC).
//!
//! Regenerates the scaling series: per-draw latency for C = 2^10 .. 2^16,
//! plus the O(kC) full-sweep for contrast. The printed series is the
//! figure; the final check asserts the log-vs-linear separation.

use adv_softmax::config::TreeConfig;
use adv_softmax::sampler::{AdversarialSampler, NoiseSampler, UniformSampler};
use adv_softmax::utils::bench::{black_box, Bench};
use adv_softmax::utils::Rng;

fn synthetic(c: usize, n: usize, k: usize, rng: &mut Rng) -> adv_softmax::data::Dataset {
    let mut x = vec![0f32; n * k];
    let mut y = vec![0u32; n];
    for i in 0..n {
        let lbl = rng.below(c) as u32;
        y[i] = lbl;
        for j in 0..k {
            x[i * k + j] = ((lbl as usize >> (j % 16)) & 1) as f32 + 0.3 * rng.normal();
        }
    }
    adv_softmax::data::Dataset::new(x, y, k, c)
}

fn main() {
    let bench = Bench::new(3, 30, 0.5);
    let k = 16;
    let mut rng = Rng::new(1);
    println!("# per-draw cost vs C (adversarial tree = O(k log C))");
    let mut tree_medians = Vec::new();
    let mut sweep_medians = Vec::new();
    for exp in [10usize, 12, 14, 16] {
        let c = 1usize << exp;
        let n = (4 * c).min(100_000).max(8192);
        let data = synthetic(c, n, k, &mut rng);
        let tcfg = TreeConfig {
            aux_dim: k,
            fit_subsample: 30_000,
            ..Default::default()
        };
        let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 7);
        let x0 = data.x(0).to_vec();
        let mut proj = vec![0f32; k];
        adv.pca.project(&x0, &mut proj);
        let mut srng = Rng::new(2);
        // batch 1024 draws per iteration so timer noise stays small
        let s = bench.run(&format!("tree_sample x1024 (C=2^{exp})"), || {
            for _ in 0..1024 {
                black_box(adv.tree.sample(black_box(&proj), &mut srng));
            }
        });
        tree_medians.push(s.median_ns / 1024.0);

        let mut lps = vec![0f32; c];
        let s2 = bench.run(&format!("full_sweep_logp  (C=2^{exp})"), || {
            adv.tree.log_prob_all(black_box(&proj), &mut lps);
            black_box(&lps);
        });
        sweep_medians.push(s2.median_ns);

        let uni = UniformSampler::new(c);
        bench.run(&format!("uniform_sample x1024 (C=2^{exp})"), || {
            for _ in 0..1024 {
                black_box(uni.sample(&[], &mut srng));
            }
        });
    }

    // shape check: tree draw cost grows ~ log C (ratio over the 64x C range
    // far below the O(C) sweep's growth)
    let tree_growth = tree_medians.last().unwrap() / tree_medians.first().unwrap();
    let sweep_growth = sweep_medians.last().unwrap() / sweep_medians.first().unwrap();
    println!("\ntree-draw growth over 64x C: {tree_growth:.2}x (log-like)");
    println!("full-sweep growth over 64x C: {sweep_growth:.2}x (linear-like)");
    assert!(
        tree_growth < sweep_growth / 4.0,
        "expected O(k log C) sampling to grow far slower than the O(kC) sweep"
    );
}
