// repro-lint fixture: `unsafe` without a safety justification must fail.
// Trailing ERROR markers name the rule expected on that line; the lint
// test compares its diagnostics against these markers exactly.
// (Not compiled — this directory is excluded from the cargo targets and
// skipped by the tree walk.)

pub fn read_first(xs: &[f32]) -> f32 {
    unsafe { *xs.get_unchecked(0) } //~ ERROR safety-comment
}

pub struct Cell(*mut f32);

unsafe impl Send for Cell {} //~ ERROR safety-comment

pub fn documented(xs: &[f32]) -> f32 {
    // SAFETY: caller guarantees xs is non-empty (checked at the call site).
    unsafe { *xs.get_unchecked(0) }
}
