// repro-lint fixture: raw thread spawns outside utils/pool.rs. All
// threads must come from the pool layer so shutdown, naming, and panic
// propagation stay centralized.

use std::thread;

pub fn spawn_wrong() {
    thread::spawn(|| {}); //~ ERROR thread-spawn
}

pub fn builder_wrong() {
    let b = thread::Builder::new().name("rogue".into()); //~ ERROR thread-spawn
    let _ = b.spawn(|| {});
}

#[cfg(test)]
mod tests {
    #[test]
    fn spawns_in_tests_still_fail() {
        std::thread::spawn(|| {}); //~ ERROR thread-spawn
    }
}
