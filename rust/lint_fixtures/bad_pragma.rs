// repro-lint fixture: allow pragmas must be well-formed, name a known
// rule, and carry a justification; a malformed pragma is itself a
// violation and suppresses nothing.

use std::time::Instant;

pub fn unclosed_pragma_does_not_suppress() -> Instant {
    // repro-lint: allow(wall-clock without a closing paren //~ ERROR pragma
    Instant::now() //~ ERROR wall-clock
}

pub fn unknown_rule_pragma() -> Instant {
    // repro-lint: allow(no-such-rule) because reasons //~ ERROR pragma
    Instant::now() //~ ERROR wall-clock
}

pub fn justified_pragma_suppresses() -> Instant {
    // repro-lint: allow(wall-clock) fixture demonstrates a sanctioned read
    Instant::now()
}
