// repro-lint fixture: floating-point reductions outside linalg's
// canonical-order kernels. Integer reductions and order-insensitive
// min/max folds are exempt.

pub fn float_sum(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() //~ ERROR float-reduce
}

pub fn multiline_sum(xs: &[f32]) -> f32 {
    let total: f32 = xs
        .iter()
        .sum(); //~ ERROR float-reduce
    total
}

pub fn additive_fold(xs: &[f64]) -> f64 {
    xs.iter().fold(0.0, |acc, x| acc + x) //~ ERROR float-reduce
}

pub fn int_sum_is_fine(xs: &[u64]) -> u64 {
    xs.iter().sum()
}

pub fn max_fold_is_fine(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}
