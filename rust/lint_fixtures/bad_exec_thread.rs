// repro-lint fixture: a hand-rolled execute thread. The step engine's
// dedicated execute thread (pipeline depth 3) must come from the
// sanctioned utils::spawn_named path — naming, panic propagation and
// join discipline stay centralized in the pool layer. A raw spawn that
// ships executes over a channel dodges all of that.

use std::sync::mpsc;
use std::thread;

pub struct BadExecThread {
    pub req_tx: mpsc::SyncSender<Vec<u8>>,
    pub handle: thread::JoinHandle<()>,
}

pub fn spawn_exec_thread() -> BadExecThread {
    let (req_tx, req_rx) = mpsc::sync_channel::<Vec<u8>>(1);
    let handle = thread::spawn(move || { //~ ERROR thread-spawn
        while let Ok(_req) = req_rx.recv() {}
    });
    BadExecThread { req_tx, handle }
}

pub fn spawn_exec_thread_named() -> thread::JoinHandle<()> {
    // hand-naming the thread does not make it sanctioned either
    let builder = thread::Builder::new().name("step-exec".into()); //~ ERROR thread-spawn
    builder.spawn(|| {}).expect("spawn")
}
