// repro-lint fixture: direct wall-clock reads outside the clock layer.

use std::time::Instant;

pub fn elapsed_wrong() -> f64 {
    let t0 = Instant::now(); //~ ERROR wall-clock
    t0.elapsed().as_secs_f64()
}

pub fn epoch_wrong() -> std::time::SystemTime { //~ ERROR wall-clock
    std::time::SystemTime::now() //~ ERROR wall-clock
}

#[cfg(test)]
mod tests {
    // timing inside tests is exempt: tests assert determinism, they do not
    // produce reproducible results
    #[test]
    fn timing_in_tests_is_fine() {
        let _ = std::time::Instant::now();
    }
}
