// repro-lint fixture: a file exercising every rule's *sanctioned* form.
// Must produce zero diagnostics.

use std::collections::HashMap;

pub fn documented_unsafe(xs: &[f32], i: usize) -> f32 {
    assert!(i < xs.len());
    // SAFETY: bounds asserted above; the reference is read-only and does
    // not outlive xs.
    unsafe { *xs.get_unchecked(i) }
}

/// Reads one element without bounds checks.
///
/// # Safety
/// `i` must be in bounds for `xs`.
pub unsafe fn doc_safety_section(xs: &[f32], i: usize) -> f32 {
    *xs.get_unchecked(i)
}

pub fn point_lookups(counts: &mut HashMap<u64, u64>) -> u64 {
    counts.insert(7, 1);
    counts.get(&7).copied().unwrap_or(0)
}

pub fn integer_reduction(xs: &[u32]) -> u32 {
    xs.iter().sum()
}

pub fn order_insensitive_fold(xs: &[f32]) -> f32 {
    xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
}

pub fn justified_float_sum(xs: &[f64]) -> f64 {
    // repro-lint: allow(float-reduce) serial iterator sum in input order
    let total: f64 = xs.iter().sum();
    total
}

pub fn prose_mentions_are_ignored() -> &'static str {
    // Instant::now, SystemTime, thread::spawn, and unsafe in comments or
    // strings are not violations.
    "Instant::now thread::spawn unsafe HashMap.iter()"
}

#[cfg(test)]
mod tests {
    #[test]
    fn timing_and_float_sums_in_tests_are_exempt() {
        let t0 = std::time::Instant::now();
        let s: f64 = [1.0f64, 2.0].iter().sum();
        assert!(s > 2.9 && t0.elapsed().as_secs() < 3600);
    }
}
