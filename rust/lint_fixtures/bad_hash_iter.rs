// repro-lint fixture: iteration over hash-ordered containers leaks the
// hasher's order into results; point lookups are fine.

use std::collections::{HashMap, HashSet};

pub fn sum_values(counts: &HashMap<u64, u64>) -> u64 {
    let mut total = 0;
    for v in counts.values() { //~ ERROR hash-iteration
        total += v;
    }
    total
}

pub fn collect_members(seen: &HashSet<u32>) -> Vec<u32> {
    let mut out = Vec::new();
    for m in seen { //~ ERROR hash-iteration
        out.push(*m);
    }
    out
}

pub fn drain_all(counts: &mut HashMap<u64, u64>) {
    counts.drain(); //~ ERROR hash-iteration
}

pub fn lookups_are_fine(counts: &mut HashMap<u64, u64>) -> Option<u64> {
    counts.insert(1, 2);
    counts.remove(&3);
    counts.get(&1).copied()
}
