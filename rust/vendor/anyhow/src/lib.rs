//! Vendored minimal reimplementation of the `anyhow` API surface used by
//! this workspace (the build environment has no network access to
//! crates.io, so the dependency closure is checked in).
//!
//! Covered: [`Error`], [`Result`], the [`Context`] trait on `Result` and
//! `Option`, and the `anyhow!` / `bail!` / `ensure!` macros. Error values
//! carry a human-readable context chain; like the real crate, [`Error`]
//! deliberately does **not** implement `std::error::Error` so the blanket
//! `From<E: std::error::Error>` conversion stays coherent.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    /// Context messages, outermost (most recently attached) first; the
    /// last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Build an error from a standard error, capturing its source chain.
    pub fn new<E: std::error::Error>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate over the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (`Result::Err` or `Option::None`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(e.root_cause(), "no such file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(11).is_err());
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<i32> {
            let n: i32 = "12x".parse()?;
            Ok(n)
        }
        assert!(g().is_err());
    }
}
