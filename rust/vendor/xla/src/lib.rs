//! Vendored **host-only stub** of the `xla` PJRT bindings.
//!
//! The build environment cannot link the real XLA/PJRT runtime, so this
//! crate implements the API surface the workspace uses in two tiers:
//!
//! * **Fully functional host types** — [`Literal`] stores shape + bytes on
//!   the host, so literal creation, element counts, and typed reads all
//!   behave exactly like the real crate (the coordinator's gather/scatter
//!   hot path and its benches run unmodified).
//! * **Gated runtime types** — [`PjRtClient::cpu`] and everything behind
//!   it return a descriptive [`Error`]; executing AOT artifacts requires
//!   building against the real `xla` crate. Callers already treat runtime
//!   construction as fallible, so the stub degrades into clear messages
//!   instead of link errors.

use std::fmt;
use std::path::Path;

/// Stub error type (Display/Debug + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT runtime unavailable: this workspace is built against the vendored \
     host-only xla stub (rust/vendor/xla); build with the real xla crate to execute AOT artifacts";

/// Element types used by this workspace's artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
}

/// A host tensor: element type, dims, and a flat little-endian byte buffer.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Build from raw bytes (memcpy, no element-wise conversion).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product::<usize>().max(1);
        if n * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal byte size {} does not match shape {dims:?} ({} elements of {} bytes)",
                data.len(),
                n,
                ty.byte_size()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.ty.byte_size()
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error(format!(
                "type mismatch: literal is {:?}, read as {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        let n = self.element_count();
        let mut out: Vec<T> = Vec::with_capacity(n);
        // SAFETY: `data` holds exactly `n` little-endian elements of T
        // (invariant established at construction); the byte copy into the
        // freshly reserved, properly aligned buffer initializes all n.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
            out.set_len(n);
        }
        Ok(out)
    }

    /// Copy into an existing typed buffer (avoids an allocation).
    pub fn copy_raw_to<T: NativeType>(&self, out: &mut [T]) -> Result<()> {
        if T::ELEMENT_TYPE != self.ty {
            return Err(Error(format!(
                "type mismatch: literal is {:?}, read as {:?}",
                self.ty,
                T::ELEMENT_TYPE
            )));
        }
        if out.len() != self.element_count() {
            return Err(Error(format!(
                "buffer has {} elements, literal has {}",
                out.len(),
                self.element_count()
            )));
        }
        // SAFETY: lengths checked above; byte-for-byte copy of POD data.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
        }
        Ok(())
    }

    /// Flatten a tuple literal into its elements. The stub never produces
    /// tuples (execution is gated), so this only errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("stub literal is not a tuple".into()))
    }

    /// **Host-stub extension** (not in the real crate): refill this
    /// literal in place from raw bytes, reusing its byte buffer's
    /// allocation. The step engine's literal scratch
    /// (`runtime::literal::LitScratch`) recycles retired step inputs
    /// through this; a build against the real `xla` crate must fall back
    /// to per-call [`Literal::create_from_shape_and_untyped_data`].
    pub fn refill_untyped(
        &mut self,
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<()> {
        let n: usize = dims.iter().product::<usize>().max(1);
        if n * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal byte size {} does not match shape {dims:?} ({} elements of {} bytes)",
                data.len(),
                n,
                ty.byte_size()
            )));
        }
        self.ty = ty;
        self.dims.clear();
        self.dims.extend_from_slice(dims);
        self.data.clear();
        self.data.extend_from_slice(data); // reuses the Vec's capacity
        Ok(())
    }
}

/// Parsed HLO module text (held verbatim; compilation is gated).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("read {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client handle. Construction fails in the stub build.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error(STUB_MSG.into()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.into()))
    }
}

/// Compiled executable handle (unreachable in the stub build).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.into()))
    }

    /// **Host-stub extension**: execute with input-buffer donation — the
    /// caller hands over its input literals by value and the runtime may
    /// reuse their device allocations for the outputs (PJRT
    /// `ExecuteOptions::untuple_result` + donated-input aliasing). The
    /// pipelined step engine routes steady-state executes through this so
    /// step t's inputs come back as t's readback storage instead of
    /// round-tripping through an allocator. Gated like [`Self::execute`]:
    /// the stub cannot run HLO, so the donated literals are returned
    /// untouched alongside the error for the caller's recycler.
    pub fn execute_donated(
        &self,
        args: Vec<Literal>,
    ) -> std::result::Result<Vec<Vec<PjRtBuffer>>, (Error, Vec<Literal>)> {
        Err((Error(STUB_MSG.into()), args))
    }
}

/// Device buffer handle (unreachable in the stub build).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB_MSG.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        let mut out = [0f32; 3];
        lit.copy_raw_to::<f32>(&mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn literal_shape_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4])
            .is_err());
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[0u8; 4]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn runtime_is_gated() {
        assert!(PjRtClient::cpu().is_err());
    }

    #[test]
    fn refill_reuses_storage_and_checks_shape() {
        let a = [1.0f32, 2.0];
        let bytes: Vec<u8> = a.iter().flat_map(|v| v.to_le_bytes()).collect();
        let mut lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &bytes).unwrap();
        let before = lit.data.as_ptr();
        let b = [-3.5f32, 4.25];
        let bytes2: Vec<u8> = b.iter().flat_map(|v| v.to_le_bytes()).collect();
        lit.refill_untyped(ElementType::F32, &[2], &bytes2).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), b);
        assert_eq!(lit.data.as_ptr(), before, "same-size refill must reuse the buffer");
        // shape/byte mismatch rejected, literal left usable
        assert!(lit.refill_untyped(ElementType::F32, &[3], &bytes2).is_err());
        assert_eq!(lit.to_vec::<f32>().unwrap(), b);
        // retyping to a same-width element type is allowed
        let ints = [7i32];
        let ibytes: Vec<u8> = ints.iter().flat_map(|v| v.to_le_bytes()).collect();
        lit.refill_untyped(ElementType::S32, &[1], &ibytes).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), ints);
        assert_eq!(lit.dims(), &[1]);
    }

    fn f32_lit(data: &[f32], dims: &[usize]) -> Literal {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, &bytes).unwrap()
    }

    #[test]
    fn refill_growth_forces_clean_realloc() {
        let mut lit = f32_lit(&[1.0, 2.0], &[2]);
        let grown = [5.0f32, 6.0, 7.0, 8.0, 9.0, 10.0];
        let bytes: Vec<u8> = grown.iter().flat_map(|v| v.to_le_bytes()).collect();
        lit.refill_untyped(ElementType::F32, &[2, 3], &bytes).unwrap();
        assert_eq!(lit.dims(), &[2, 3]);
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit.to_vec::<f32>().unwrap(), grown);
        // the grown buffer holds exactly the new bytes, no stale tail
        assert_eq!(lit.data.len(), 24);
    }

    #[test]
    fn refill_shrink_reuses_capacity() {
        let mut lit = f32_lit(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[6]);
        let before = lit.data.as_ptr();
        let cap = lit.data.capacity();
        let small = [9.5f32, -8.5];
        let bytes: Vec<u8> = small.iter().flat_map(|v| v.to_le_bytes()).collect();
        lit.refill_untyped(ElementType::F32, &[2], &bytes).unwrap();
        assert_eq!(lit.data.as_ptr(), before, "shrink must keep the allocation");
        assert_eq!(lit.data.capacity(), cap);
        assert_eq!(lit.element_count(), 2);
        assert_eq!(lit.to_vec::<f32>().unwrap(), small);
    }

    #[test]
    fn execute_donated_is_gated_and_returns_inputs() {
        let exe = PjRtLoadedExecutable;
        let args = vec![f32_lit(&[1.0], &[1]), f32_lit(&[2.0, 3.0], &[2])];
        let (err, back) = exe.execute_donated(args).unwrap_err();
        assert!(err.0.contains("PJRT runtime unavailable"));
        assert_eq!(back.len(), 2, "donated inputs must come back for recycling");
        assert_eq!(back[1].to_vec::<f32>().unwrap(), vec![2.0, 3.0]);
    }
}
