//! Distributed training rounds: a fault-tolerant coordinator/client
//! protocol with drop/rejoin and bit-exact aggregation.
//!
//! One host, N processes: a coordinator ([`coordinator`]) drives the
//! round state machine (WaitingForMembers → Warmup → Train → Witness)
//! over the virtual-time [`crate::utils::timer::Clock`], assigning each
//! round's batch seqs to the joined clients ([`client`]) and collecting
//! their sparse Adagrad update sets over a versioned, length-checked
//! Unix-socket line protocol ([`protocol`], version `dist1`).
//!
//! **The invariant** (the whole point): the committed parameters after
//! round *r* are a pure function of `(seed, r)` — independent of the
//! client count, the assignment, frame interleaving, faults, evictions
//! and rejoins. Every client computes update sets against the round-start
//! replica; the coordinator buffers them and applies at Witness in
//! ascending batch-seq order through the canonical
//! [`crate::model::ParamStore::apply_sparse`]. M clients therefore
//! produce learning curves bit-identical to 1 client — verified by
//! `tests/dist_parity.rs`, with kill/rejoin mid-run, and under a seeded
//! drop/delay/duplicate/corrupt fault mix by `tests/dist_chaos.rs` via
//! the in-memory [`sim::SimNet`].
//!
//! Robustness follows the serving daemon's playbook: leases renewed by
//! heartbeats, typed error frames (`bad-version`, `bad-frame`,
//! `bad-field`, `bad-length`, `stale-round`, `unknown-client`),
//! idempotent acks, deterministic reassignment of a dead client's seqs,
//! and per-round [`RoundStats`] whose `accounted()` check proves every
//! update was applied exactly once. Fault injection shares the
//! [`crate::utils::faults::FaultPlan`] spec (`REPRO_FAULTS`) with the
//! daemon.
//!
//! CLI entry points: `repro coord --clients N` / `repro worker --connect
//! PATH` (socket glue below); everything else runs in-process.

pub mod client;
pub mod coordinator;
pub mod protocol;
pub mod sim;

pub use client::{ClientStats, DistClient, GradStep, HostNsStep};
pub use coordinator::{reassign_seqs, CoordStats, Coordinator, Leases, Phase, RoundStats};
pub use protocol::{params_checksum, ErrorTag, Frame, FrameError, SnapPart, UpdateSet};
pub use sim::SimNet;

#[cfg(unix)]
use std::collections::BTreeMap;
#[cfg(unix)]
use std::path::Path;
#[cfg(unix)]
use std::time::Duration;

#[cfg(unix)]
use crate::config::DistConfig;
#[cfg(unix)]
use crate::utils::faults::{FaultGate, FaultPlan};
#[cfg(unix)]
use crate::utils::timer::RealClock;
#[cfg(unix)]
use crate::utils::transport::{drain_ready, Inbound, LineClient, LineServer, Recv};
#[cfg(unix)]
use anyhow::Result;

/// Poll cadence for the socket event loops.
#[cfg(unix)]
const SOCKET_POLL_MS: u64 = 10;

/// Serve a training run over a Unix socket until all rounds commit (or a
/// raw `shutdown` line arrives). Inbound frames pass through a
/// [`FaultGate`] (stage `"coord-in"`) so the daemon's `REPRO_FAULTS`
/// spec exercises the real socket path too; returns the finished
/// [`Coordinator`] for stats/params inspection.
#[cfg(unix)]
pub fn run_coord_socket(
    cfg: &DistConfig,
    path: &Path,
    faults: Option<FaultPlan>,
) -> Result<Coordinator> {
    let server = LineServer::bind(path)?;
    let mut coord = Coordinator::new(cfg.clone(), Box::new(RealClock::new()))?;
    let loop_clock = RealClock::new();
    let mut gate = FaultGate::new(faults, "coord-in");
    // gate-delayed inbound frames, keyed (due_ms, arrival seq)
    let mut held: BTreeMap<(u64, u64), (usize, String)> = BTreeMap::new();
    let mut held_seq = 0u64;
    let mut stop = false;
    while !coord.is_done() && !stop {
        let mut inbox: Vec<Inbound> = Vec::new();
        match server.rx().recv_timeout(Duration::from_millis(SOCKET_POLL_MS)) {
            Ok(first) => {
                inbox.push(first);
                inbox.extend(drain_ready(server.rx()));
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        let now = loop_clock.now_ms();
        for item in inbox {
            match item {
                Inbound::Shutdown => stop = true,
                Inbound::Line { client, line } => {
                    if line.trim() == "shutdown" {
                        stop = true;
                        continue;
                    }
                    let gated = gate.pass(&line);
                    for delivered in gated.lines {
                        if gated.delay_ms == 0 {
                            for (conn, reply) in coord.on_line(client, &delivered) {
                                server.send(conn, &reply);
                            }
                        } else {
                            held.insert((now + gated.delay_ms, held_seq), (client, delivered));
                            held_seq += 1;
                        }
                    }
                }
            }
        }
        let due: Vec<(u64, u64)> = held.range(..=(now, u64::MAX)).map(|(&k, _)| k).collect();
        for key in due {
            if let Some((client, line)) = held.remove(&key) {
                for (conn, reply) in coord.on_line(client, &line) {
                    server.send(conn, &reply);
                }
            }
        }
        for (conn, reply) in coord.tick() {
            server.send(conn, &reply);
        }
    }
    server.shutdown();
    Ok(coord)
}

/// Run one worker against a coordinator socket until the run finishes
/// (`shutdown` frame) or the socket closes. Returns the client's
/// counters.
#[cfg(unix)]
pub fn run_worker_socket(
    path: &Path,
    name: &str,
    heartbeat_ms: u64,
    resend_ms: u64,
) -> Result<ClientStats> {
    let mut conn = LineClient::connect_retry(path, 100, 50)?;
    let mut client = DistClient::new(name, Box::new(RealClock::new()), heartbeat_ms, resend_ms);
    while !client.finished() {
        for line in client.tick() {
            conn.send(&line)?;
        }
        match conn.recv_timeout(SOCKET_POLL_MS) {
            Recv::Line(line) => {
                for reply in client.on_line(&line) {
                    conn.send(&reply)?;
                }
            }
            Recv::Timeout => {}
            Recv::Closed => break,
        }
    }
    Ok(client.stats())
}
