//! The tick-driven coordinator state machine for distributed training
//! rounds.
//!
//! Phases follow the Psyche-style round loop: **WaitingForMembers** (block
//! until the configured client count joins) → **Warmup** (broadcast the
//! parameter snapshot and the first assignments, wait for `ready` acks) →
//! **Train** (collect the round's update sets, ack each, police leases)
//! → **Witness** (apply the buffered sets in ascending batch-seq order,
//! record the round, broadcast the commit) → Train … until the configured
//! round count, then **Done**. All transitions happen in [`Coordinator::tick`]
//! against the injected [`Clock`], so every one of them is observable and
//! reproducible under a `ManualClock`.
//!
//! **Bit-exactness.** Every update set of round *r* is computed against
//! the round-start parameters P_r and buffered; nothing is applied until
//! Witness, which applies the full set in ascending seq order through the
//! canonical [`ParamStore::apply_sparse`]. P_{r+1} is therefore a pure
//! function of (P_r, seed, round) — independent of how many clients
//! computed the sets, which client computed which seq, how frames
//! interleaved, or whether seqs were reassigned after an eviction. M
//! clients produce parameters bit-identical to 1 client, faults or not.
//!
//! **Robustness.** Clients hold leases renewed by any frame (heartbeats
//! when otherwise idle). A lease that reaches its deadline marks the
//! client dead: its unapplied seqs are reassigned deterministically
//! (ascending seqs, round-robin over ascending survivor ids —
//! [`reassign_seqs`]), and any later frame from the evicted id draws a
//! typed `unknown-client` error, which tells the client to rejoin through
//! Warmup (fresh snapshot, current round state). Every round's ledger is
//! a [`RoundStats`] whose [`RoundStats::accounted`] invariant mirrors the
//! serving daemon's `DaemonStats`: updates are applied exactly once —
//! never lost, never double-applied, never silently skipped.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::DistConfig;
use crate::dist::protocol::{
    params_checksum, ErrorTag, Frame, FrameError, SnapPart, UpdateSet,
};
use crate::model::ParamStore;
use crate::utils::timer::Clock;
use anyhow::Result;

/// Outbound frames, addressed by transport connection id.
pub type Outbound = Vec<(usize, String)>;

/// The coordinator's lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    WaitingForMembers,
    Warmup,
    Train,
    Witness,
    Done,
}

/// One round's ledger. [`RoundStats::accounted`] is the no-loss /
/// no-double-apply invariant checked at every commit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RoundStats {
    pub round: u64,
    /// Batch seqs this round owns (always `batches_per_round`).
    pub assigned: u64,
    /// Update sets applied at Witness.
    pub applied: u64,
    /// Valid update frames received for this round (incl. duplicates).
    pub received: u64,
    /// Re-delivered seqs (client resend or duplicate-frame fault); acked
    /// again, never re-applied.
    pub duplicates: u64,
    /// Frames for already-committed rounds answered `stale-round`.
    pub stale: u64,
    /// Frames rejected with a parse/validation error during this round.
    pub malformed: u64,
    /// Seqs moved to survivors after an eviction.
    pub reassigned: u64,
    /// Clients whose lease expired during this round.
    pub evictions: u64,
    /// Snapshot resyncs served during this round.
    pub resyncs: u64,
    /// Bit pattern of the round's mean batch loss (f64).
    pub loss_bits: u64,
}

impl RoundStats {
    /// Exactly-once accounting: every received update frame is either the
    /// first copy of its seq (applied at Witness) or a duplicate, and at
    /// commit every assigned seq was applied.
    pub fn accounted(&self) -> bool {
        self.received == self.applied + self.duplicates && self.applied == self.assigned
    }

    pub fn loss(&self) -> f64 {
        f64::from_bits(self.loss_bits)
    }
}

/// Aggregate coordinator counters (across all rounds).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoordStats {
    pub joins: u64,
    pub evictions: u64,
    pub reassigned: u64,
    pub resyncs: u64,
    pub duplicates: u64,
    pub stale: u64,
    pub malformed: u64,
    pub heartbeats: u64,
    pub errors_sent: u64,
}

impl CoordStats {
    pub fn summary(&self) -> String {
        format!(
            "joins={} evictions={} reassigned={} resyncs={} duplicates={} \
             stale={} malformed={} heartbeats={}",
            self.joins,
            self.evictions,
            self.reassigned,
            self.resyncs,
            self.duplicates,
            self.stale,
            self.malformed,
            self.heartbeats
        )
    }
}

/// Client leases: a deadline per member, renewed by any frame. Expiry is
/// inclusive — a lease renewed at time t with window L is dead at exactly
/// t + L, not one tick later.
#[derive(Clone, Debug, Default)]
pub struct Leases {
    lease_ms: u64,
    deadline: BTreeMap<u64, u64>,
}

impl Leases {
    pub fn new(lease_ms: u64) -> Self {
        Self { lease_ms, deadline: BTreeMap::new() }
    }

    /// Reset `client`'s deadline to `now_ms + lease_ms`.
    pub fn renew(&mut self, client: u64, now_ms: u64) {
        self.deadline.insert(client, now_ms + self.lease_ms);
    }

    pub fn remove(&mut self, client: u64) {
        self.deadline.remove(&client);
    }

    pub fn deadline(&self, client: u64) -> Option<u64> {
        self.deadline.get(&client).copied()
    }

    /// Clients whose lease has expired at `now_ms` (deadline <= now),
    /// ascending.
    pub fn expired(&self, now_ms: u64) -> Vec<u64> {
        self.deadline
            .iter()
            .filter(|(_, &d)| d <= now_ms)
            .map(|(&c, _)| c)
            .collect()
    }
}

/// Deterministic reassignment of orphaned batch seqs: seqs ascending,
/// round-robin over survivors ascending. A pure function of the two sets
/// — the same eviction always produces the same reassignment, so a chaos
/// trace replays exactly. (`survivors` must be sorted; callers pass
/// `BTreeMap` key order.)
pub fn reassign_seqs(seqs: &[u64], survivors: &[u64]) -> Vec<(u64, u64)> {
    debug_assert!(survivors.windows(2).all(|w| w[0] < w[1]), "survivors must be sorted");
    if survivors.is_empty() {
        return Vec::new();
    }
    seqs.iter()
        .enumerate()
        .map(|(i, &seq)| (seq, survivors[i % survivors.len()]))
        .collect()
}

struct Member {
    #[allow(dead_code)] // reported in logs; the protocol keys on the id
    name: String,
    ready: bool,
}

/// The coordinator: owns the authoritative [`ParamStore`], assigns batch
/// seqs, buffers update sets, and commits rounds. Transport-agnostic —
/// [`Coordinator::on_line`] consumes protocol lines addressed by
/// connection id and both entry points return outbound `(conn, line)`
/// pairs; the socket glue and the in-memory sim are thin shells.
pub struct Coordinator {
    cfg: DistConfig,
    clock: Box<dyn Clock>,
    params: ParamStore,
    phase: Phase,
    round: u64,
    next_client: u64,
    /// client id → transport connection (and back).
    conn_of: BTreeMap<u64, usize>,
    client_of: BTreeMap<usize, u64>,
    members: BTreeMap<u64, Member>,
    leases: Leases,
    /// Current round: seq → owning client.
    owner: BTreeMap<u64, u64>,
    /// Current round: seqs with no accepted update yet.
    missing: BTreeSet<u64>,
    /// Current round: accepted update sets, keyed (= applied) in seq order.
    staging: BTreeMap<u64, UpdateSet>,
    cur: RoundStats,
    rounds: Vec<RoundStats>,
    stats: CoordStats,
}

impl Coordinator {
    pub fn new(cfg: DistConfig, clock: Box<dyn Clock>) -> Result<Self> {
        cfg.validate()?;
        let params = ParamStore::zeros(cfg.num_classes, cfg.feat_dim, cfg.lr);
        let leases = Leases::new(cfg.lease_ms);
        Ok(Self {
            cfg,
            clock,
            params,
            phase: Phase::WaitingForMembers,
            round: 0,
            next_client: 0,
            conn_of: BTreeMap::new(),
            client_of: BTreeMap::new(),
            members: BTreeMap::new(),
            leases,
            owner: BTreeMap::new(),
            missing: BTreeSet::new(),
            staging: BTreeMap::new(),
            cur: RoundStats::default(),
            rounds: Vec::new(),
            stats: CoordStats::default(),
        })
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn is_done(&self) -> bool {
        self.phase == Phase::Done
    }

    /// The authoritative parameters (P_r for the round in progress).
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// Committed rounds, in order.
    pub fn round_stats(&self) -> &[RoundStats] {
        &self.rounds
    }

    /// The learning curve as loss bit patterns, one per committed round.
    pub fn loss_bits(&self) -> Vec<u64> {
        self.rounds.iter().map(|r| r.loss_bits).collect()
    }

    pub fn stats(&self) -> CoordStats {
        self.stats
    }

    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    pub fn leases(&self) -> &Leases {
        &self.leases
    }

    // -- inbound ----------------------------------------------------------

    /// Consume one protocol line from connection `conn`.
    pub fn on_line(&mut self, conn: usize, line: &str) -> Outbound {
        let mut out = Vec::new();
        let text = line.trim();
        if text.is_empty() || self.phase == Phase::Done {
            return out;
        }
        match Frame::parse(text) {
            Ok(frame) => self.on_frame(conn, frame, &mut out),
            Err(e) => self.reject(conn, e, &mut out),
        }
        out
    }

    fn reject(&mut self, conn: usize, e: FrameError, out: &mut Outbound) {
        self.stats.malformed += 1;
        self.cur.malformed += 1;
        self.send_error(conn, e.tag, &e.detail, out);
    }

    fn send_error(&mut self, conn: usize, tag: ErrorTag, detail: &str, out: &mut Outbound) {
        self.stats.errors_sent += 1;
        let frame = Frame::Error { tag, detail: detail.to_string() };
        out.push((conn, frame.encode(self.cfg.feat_dim)));
    }

    fn on_frame(&mut self, conn: usize, frame: Frame, out: &mut Outbound) {
        match frame {
            Frame::Join { name } => self.on_join(conn, name, out),
            Frame::Heartbeat { client, .. } => {
                if self.check_member(conn, client, out) {
                    self.stats.heartbeats += 1;
                }
            }
            Frame::Ready { client, round } => {
                if self.check_member(conn, client, out) && round == self.round {
                    if let Some(m) = self.members.get_mut(&client) {
                        m.ready = true;
                    }
                }
            }
            Frame::Update { client, round, set } => self.on_update(conn, client, round, set, out),
            Frame::Resync { client } => {
                if self.check_member(conn, client, out) {
                    self.stats.resyncs += 1;
                    self.cur.resyncs += 1;
                    self.send_sync(conn, client, out);
                }
            }
            // coordinator-bound lines may only be the five client frames
            _ => {
                let e = FrameError {
                    tag: ErrorTag::BadFrame,
                    detail: "not a client frame".to_string(),
                };
                self.reject(conn, e, out);
            }
        }
    }

    /// Membership gate shared by all non-join frames: renews the lease on
    /// success, answers `unknown-client` (prompting a rejoin) otherwise.
    fn check_member(&mut self, conn: usize, client: u64, out: &mut Outbound) -> bool {
        if self.members.contains_key(&client) {
            self.leases.renew(client, self.clock.now_ms());
            // follow the client to its current connection (reconnects)
            if self.conn_of.get(&client) != Some(&conn) {
                if let Some(&old) = self.conn_of.get(&client) {
                    self.client_of.remove(&old);
                }
                self.conn_of.insert(client, conn);
                self.client_of.insert(conn, client);
            }
            true
        } else {
            self.send_error(conn, ErrorTag::UnknownClient, &format!("client {client}"), out);
            false
        }
    }

    fn on_join(&mut self, conn: usize, name: String, out: &mut Outbound) {
        // a join on a connection that already has a live client is a
        // restart: evict the old identity first (its seqs reassign)
        if let Some(&old) = self.client_of.get(&conn) {
            self.evict(old, out);
        }
        let client = self.next_client;
        self.next_client += 1;
        self.stats.joins += 1;
        self.members.insert(client, Member { name, ready: false });
        self.conn_of.insert(client, conn);
        self.client_of.insert(conn, client);
        self.leases.renew(client, self.clock.now_ms());
        let welcome = Frame::Welcome {
            client,
            round: self.round,
            seed: self.cfg.seed,
            c: self.cfg.num_classes as u64,
            k: self.cfg.feat_dim as u64,
            batch: self.cfg.batch_size as u64,
            lr: self.cfg.lr,
        };
        out.push((conn, welcome.encode(self.cfg.feat_dim)));
        if self.phase != Phase::WaitingForMembers {
            // mid-run join: hand over the current round's state (Warmup
            // from the client's point of view), plus any orphaned seqs
            let orphans: Vec<u64> = self
                .missing
                .iter()
                .filter(|s| !self.owner.contains_key(s))
                .copied()
                .collect();
            for seq in orphans {
                self.owner.insert(seq, client);
            }
            self.send_sync(conn, client, out);
        }
    }

    /// Snapshot + `begin` for one client: the full bit pattern of the
    /// round-start parameters and the client's current assignment.
    fn send_sync(&mut self, conn: usize, client: u64, out: &mut Outbound) {
        for line in self.snapshot_lines() {
            out.push((conn, line));
        }
        if self.phase != Phase::WaitingForMembers {
            out.push((conn, self.begin_line(client)));
        }
    }

    fn snapshot_lines(&self) -> Vec<String> {
        let (gw2, gb2) = self.params.opt.accumulators();
        SnapPart::ALL
            .iter()
            .map(|&part| {
                let data = match part {
                    SnapPart::W => self.params.w.clone(),
                    SnapPart::B => self.params.b.clone(),
                    SnapPart::Gw2 => gw2.to_vec(),
                    SnapPart::Gb2 => gb2.to_vec(),
                };
                Frame::Snap { round: self.round, part, data }.encode(self.cfg.feat_dim)
            })
            .collect()
    }

    fn begin_line(&self, client: u64) -> String {
        let frame = Frame::Begin {
            round: self.round,
            ranges: self.ranges_of(client),
            csum: params_checksum(&self.params),
        };
        frame.encode(self.cfg.feat_dim)
    }

    /// The client's owned seqs, merged into half-open ranges.
    fn ranges_of(&self, client: u64) -> Vec<(u64, u64)> {
        let mut ranges: Vec<(u64, u64)> = Vec::new();
        for (&seq, &o) in &self.owner {
            if o != client {
                continue;
            }
            match ranges.last_mut() {
                Some((_, end)) if *end == seq => *end = seq + 1,
                _ => ranges.push((seq, seq + 1)),
            }
        }
        ranges
    }

    fn on_update(
        &mut self,
        conn: usize,
        client: u64,
        round: u64,
        set: UpdateSet,
        out: &mut Outbound,
    ) {
        if !self.check_member(conn, client, out) {
            return;
        }
        if round != self.round {
            self.stats.stale += 1;
            self.cur.stale += 1;
            let what = if round < self.round { "already committed" } else { "not started" };
            self.send_error(conn, ErrorTag::StaleRound, &format!("round {round} {what}"), out);
            return;
        }
        // validate the payload against the run shape before staging it
        let b = self.cfg.batches_per_round as u64;
        let (lo, hi) = (self.round * b, (self.round + 1) * b);
        if set.seq < lo || set.seq >= hi {
            let e = FrameError {
                tag: ErrorTag::BadFrame,
                detail: format!("seq {} outside round range [{lo}, {hi})", set.seq),
            };
            self.reject(conn, e, out);
            return;
        }
        if set.gw.len() != set.labels.len() * self.cfg.feat_dim
            || set.gb.len() != set.labels.len()
        {
            let e = FrameError {
                tag: ErrorTag::BadLength,
                detail: format!("update rows do not match feat_dim {}", self.cfg.feat_dim),
            };
            self.reject(conn, e, out);
            return;
        }
        if set.labels.iter().any(|&y| y as usize >= self.cfg.num_classes) {
            let e = FrameError {
                tag: ErrorTag::BadFrame,
                detail: format!("label out of range (c={})", self.cfg.num_classes),
            };
            self.reject(conn, e, out);
            return;
        }
        self.cur.received += 1;
        let seq = set.seq;
        match self.staging.entry(seq) {
            std::collections::btree_map::Entry::Occupied(_) => {
                // resend or duplicate-frame fault: ack again, apply once
                self.cur.duplicates += 1;
                self.stats.duplicates += 1;
            }
            std::collections::btree_map::Entry::Vacant(slot) => {
                slot.insert(set);
                self.missing.remove(&seq);
            }
        }
        let ack = Frame::Ack { round: self.round, seq };
        out.push((conn, ack.encode(self.cfg.feat_dim)));
    }

    // -- tick -------------------------------------------------------------

    /// Advance the state machine one tick: police leases, then run the
    /// current phase's transition if its condition holds. All time comes
    /// from the injected clock; all outbound frames are returned.
    pub fn tick(&mut self) -> Outbound {
        let mut out = Vec::new();
        if self.phase == Phase::Done {
            return out;
        }
        let now = self.clock.now_ms();
        for client in self.leases.expired(now) {
            self.evict(client, &mut out);
        }
        match self.phase {
            Phase::WaitingForMembers => {
                if self.members.len() >= self.cfg.clients {
                    self.start_round();
                    let conns: Vec<(u64, usize)> =
                        self.conn_of.iter().map(|(&c, &n)| (c, n)).collect();
                    for (client, conn) in conns {
                        self.send_sync(conn, client, &mut out);
                    }
                    self.phase = Phase::Warmup;
                }
            }
            Phase::Warmup => {
                if !self.members.is_empty() && self.members.values().all(|m| m.ready) {
                    self.phase = Phase::Train;
                }
            }
            Phase::Train => {
                if self.missing.is_empty() {
                    self.phase = Phase::Witness;
                }
            }
            Phase::Witness => self.commit(&mut out),
            Phase::Done => {}
        }
        out
    }

    /// Reset the per-round state for `self.round` and deal its seqs to
    /// the current members in contiguous chunks over ascending ids.
    fn start_round(&mut self) {
        let b = self.cfg.batches_per_round as u64;
        let lo = self.round * b;
        self.owner.clear();
        self.staging.clear();
        self.missing = (lo..lo + b).collect();
        self.cur = RoundStats { round: self.round, assigned: b, ..RoundStats::default() };
        let ids: Vec<u64> = self.members.keys().copied().collect();
        if ids.is_empty() {
            return; // every seq is orphaned; the next joiner inherits them
        }
        let n = b as usize;
        let per = n / ids.len();
        let extra = n % ids.len();
        let mut seq = lo;
        for (i, &id) in ids.iter().enumerate() {
            let take = per + usize::from(i < extra);
            for _ in 0..take {
                self.owner.insert(seq, id);
                seq += 1;
            }
        }
    }

    /// Witness: apply the round's staged update sets in ascending seq
    /// order, record the ledger, and broadcast the commit (`apply` frames
    /// in the same order, then next round's `begin` — or `shutdown` after
    /// the final round).
    fn commit(&mut self, out: &mut Outbound) {
        debug_assert!(self.missing.is_empty());
        let mut losses = Vec::with_capacity(self.staging.len());
        for set in self.staging.values() {
            self.params.apply_sparse(&set.labels, &set.gw, &set.gb);
            self.cur.applied += 1;
            losses.push(set.loss);
        }
        let mean = crate::linalg::sum_f64(losses) / self.cur.assigned as f64;
        self.cur.loss_bits = mean.to_bits();
        debug_assert!(self.cur.accounted(), "round accounting broke: {:?}", self.cur);
        self.rounds.push(self.cur);
        let apply_lines: Vec<String> = self
            .staging
            .values()
            .map(|set| {
                let frame = Frame::Apply { round: self.round, set: set.clone() };
                frame.encode(self.cfg.feat_dim)
            })
            .collect();
        self.round += 1;
        let finished = self.round as usize >= self.cfg.rounds;
        if finished {
            let bye = Frame::Shutdown.encode(self.cfg.feat_dim);
            for &conn in self.conn_of.values() {
                for line in &apply_lines {
                    out.push((conn, line.clone()));
                }
                out.push((conn, bye.clone()));
            }
            self.phase = Phase::Done;
            return;
        }
        self.start_round();
        let conns: Vec<(u64, usize)> = self.conn_of.iter().map(|(&c, &n)| (c, n)).collect();
        for (client, conn) in conns {
            for line in &apply_lines {
                out.push((conn, line.clone()));
            }
            out.push((conn, self.begin_line(client)));
        }
        self.phase = Phase::Train;
    }

    /// Remove a dead client and deterministically reassign its unapplied
    /// seqs to the survivors, refreshing their assignments.
    fn evict(&mut self, client: u64, out: &mut Outbound) {
        if self.members.remove(&client).is_none() {
            return;
        }
        self.leases.remove(client);
        if let Some(conn) = self.conn_of.remove(&client) {
            self.client_of.remove(&conn);
        }
        self.stats.evictions += 1;
        self.cur.evictions += 1;
        let orphaned: Vec<u64> = self
            .owner
            .iter()
            .filter(|&(seq, &o)| o == client && self.missing.contains(seq))
            .map(|(&seq, _)| seq)
            .collect();
        // drop the dead client's ownership entirely (applied seqs stay
        // applied; unapplied ones move or wait for a joiner)
        self.owner.retain(|_, o| *o != client);
        if orphaned.is_empty() {
            return;
        }
        self.cur.reassigned += orphaned.len() as u64;
        self.stats.reassigned += orphaned.len() as u64;
        let survivors: Vec<u64> = self.members.keys().copied().collect();
        for (seq, new_owner) in reassign_seqs(&orphaned, &survivors) {
            self.owner.insert(seq, new_owner);
        }
        // refreshed assignments (the round may now complete without the
        // dead client); survivors merge, recompute only what's new
        for &survivor in &survivors {
            if let Some(&conn) = self.conn_of.get(&survivor) {
                out.push((conn, self.begin_line(survivor)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::timer::ManualClock;

    // -- leases: expiry exactly at deadline, renewal resets ---------------

    #[test]
    fn lease_expires_exactly_at_deadline() {
        let mut leases = Leases::new(100);
        leases.renew(7, 0);
        assert_eq!(leases.deadline(7), Some(100));
        assert!(leases.expired(99).is_empty(), "one ms early is alive");
        assert_eq!(leases.expired(100), vec![7], "expiry is inclusive at the deadline");
        assert_eq!(leases.expired(5000), vec![7]);
    }

    #[test]
    fn lease_renewal_resets_the_deadline() {
        let mut leases = Leases::new(100);
        leases.renew(3, 0);
        leases.renew(3, 60);
        assert!(leases.expired(100).is_empty(), "renewal at 60 pushed the deadline to 160");
        assert!(leases.expired(159).is_empty());
        assert_eq!(leases.expired(160), vec![3]);
    }

    #[test]
    fn expired_reports_all_dead_clients_in_order() {
        let mut leases = Leases::new(50);
        leases.renew(9, 0);
        leases.renew(2, 10);
        leases.renew(5, 100);
        assert_eq!(leases.expired(60), vec![2, 9], "ascending ids, both past deadline");
        leases.remove(9);
        assert_eq!(leases.expired(60), vec![2]);
    }

    // -- reassignment: deterministic ordering -----------------------------

    #[test]
    fn reassignment_is_deterministic_round_robin() {
        let seqs = [12, 15, 17, 18, 19];
        let survivors = [2, 5, 9];
        let want = vec![(12, 2), (15, 5), (17, 9), (18, 2), (19, 5)];
        assert_eq!(reassign_seqs(&seqs, &survivors), want);
        // pure: same inputs, same output
        assert_eq!(reassign_seqs(&seqs, &survivors), want);
    }

    #[test]
    fn reassignment_with_no_survivors_is_empty() {
        assert!(reassign_seqs(&[1, 2, 3], &[]).is_empty());
    }

    #[test]
    fn reassignment_to_single_survivor_takes_everything() {
        assert_eq!(reassign_seqs(&[4, 6], &[11]), vec![(4, 11), (6, 11)]);
    }

    // -- coordinator state machine ----------------------------------------

    fn test_cfg() -> DistConfig {
        DistConfig {
            clients: 1,
            rounds: 2,
            batches_per_round: 2,
            batch_size: 1,
            num_classes: 4,
            feat_dim: 2,
            lr: 0.1,
            seed: 7,
            lease_ms: 1000,
            resend_ms: 100,
        }
    }

    fn coord(cfg: DistConfig) -> (Coordinator, ManualClock) {
        let clock = ManualClock::new();
        let c = Coordinator::new(cfg, Box::new(clock.clone())).unwrap();
        (c, clock)
    }

    fn update_line(client: u64, round: u64, seq: u64) -> String {
        let set = UpdateSet {
            seq,
            labels: vec![1, 3],
            gw: vec![0.5, -0.5, 0.25, -0.25],
            gb: vec![0.5, -0.5],
            loss: 1.25,
        };
        Frame::Update { client, round, set }.encode(2)
    }

    fn kinds(out: &[(usize, String)]) -> Vec<String> {
        out.iter()
            .map(|(_, line)| {
                line.split_whitespace().nth(1).unwrap_or("?").to_string()
            })
            .collect()
    }

    #[test]
    fn full_two_round_run_with_one_client() {
        let (mut c, _clock) = coord(test_cfg());
        assert_eq!(c.phase(), Phase::WaitingForMembers);
        assert!(c.tick().is_empty(), "no members yet: nothing to do");

        let out = c.on_line(0, &Frame::Join { name: "w0".into() }.encode(2));
        assert_eq!(kinds(&out), vec!["welcome"], "snapshot waits for round start");
        let out = c.tick();
        assert_eq!(c.phase(), Phase::Warmup);
        assert_eq!(kinds(&out), vec!["snap", "snap", "snap", "snap", "begin"]);
        let begin = Frame::parse(&out.last().unwrap().1).unwrap();
        let Frame::Begin { round, ranges, .. } = begin else { panic!("not begin") };
        assert_eq!(round, 0);
        assert_eq!(ranges, vec![(0, 2)], "single member owns the whole round");

        c.on_line(0, &Frame::Ready { client: 0, round: 0 }.encode(2));
        c.tick();
        assert_eq!(c.phase(), Phase::Train);

        let out = c.on_line(0, &update_line(0, 0, 0));
        assert_eq!(kinds(&out), vec!["ack"]);
        // duplicate of seq 0: acked again, never double-staged
        let out = c.on_line(0, &update_line(0, 0, 0));
        assert_eq!(kinds(&out), vec!["ack"]);
        c.on_line(0, &update_line(0, 0, 1));
        c.tick(); // Train -> Witness
        assert_eq!(c.phase(), Phase::Witness);
        let out = c.tick(); // Witness: commit round 0
        assert_eq!(c.phase(), Phase::Train);
        assert_eq!(c.round(), 1);
        assert_eq!(kinds(&out), vec!["apply", "apply", "begin"]);

        let stats = c.round_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].applied, 2);
        assert_eq!(stats[0].duplicates, 1);
        assert!(stats[0].accounted());
        assert_eq!(stats[0].loss(), 1.25);

        // a stale round-0 update after the commit draws a typed error
        let out = c.on_line(0, &update_line(0, 0, 1));
        let err = Frame::parse(&out[0].1).unwrap();
        assert!(
            matches!(err, Frame::Error { tag: ErrorTag::StaleRound, .. }),
            "expected stale-round, got {err:?}"
        );

        c.on_line(0, &update_line(0, 1, 2));
        c.on_line(0, &update_line(0, 1, 3));
        c.tick();
        let out = c.tick(); // commit round 1: final -> shutdown
        assert!(c.is_done());
        assert_eq!(kinds(&out), vec!["apply", "apply", "shutdown"]);
        assert_eq!(c.round_stats().len(), 2);
        assert!(c.round_stats().iter().all(|r| r.accounted()));
        assert!(c.params().w.iter().any(|&x| x != 0.0), "updates reached the parameters");
    }

    #[test]
    fn unknown_client_and_malformed_frames_are_typed() {
        let (mut c, _clock) = coord(test_cfg());
        let out = c.on_line(0, &Frame::Heartbeat { client: 99, round: 0 }.encode(2));
        let err = Frame::parse(&out[0].1).unwrap();
        assert!(matches!(err, Frame::Error { tag: ErrorTag::UnknownClient, .. }));

        let out = c.on_line(0, "not even close");
        let err = Frame::parse(&out[0].1).unwrap();
        assert!(matches!(err, Frame::Error { tag: ErrorTag::BadVersion, .. }));
        assert_eq!(c.stats().malformed, 1);
    }

    #[test]
    fn lease_expiry_evicts_and_reassigns_to_survivors() {
        let mut cfg = test_cfg();
        cfg.clients = 2;
        cfg.batches_per_round = 4;
        let (mut c, clock) = coord(cfg);
        c.on_line(0, &Frame::Join { name: "a".into() }.encode(2));
        c.on_line(1, &Frame::Join { name: "b".into() }.encode(2));
        c.tick(); // -> Warmup, assignments dealt
        c.on_line(0, &Frame::Ready { client: 0, round: 0 }.encode(2));
        c.on_line(1, &Frame::Ready { client: 1, round: 0 }.encode(2));
        c.tick(); // -> Train
        assert_eq!(c.phase(), Phase::Train);
        assert_eq!(c.member_count(), 2);

        // client 1 goes silent; client 0 heartbeats past the lease window
        clock.advance(600);
        c.on_line(0, &Frame::Heartbeat { client: 0, round: 0 }.encode(2));
        clock.advance(400); // t=1000: client 1's lease (renewed at ~0) is due
        let out = c.tick();
        assert_eq!(c.member_count(), 1, "silent client evicted");
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.stats().reassigned, 2, "the dead client's two seqs moved");
        // the survivor got a refreshed begin covering the whole round
        let begin = out
            .iter()
            .find_map(|(conn, line)| match Frame::parse(line) {
                Ok(Frame::Begin { ranges, .. }) => Some((*conn, ranges)),
                _ => None,
            })
            .expect("survivor is told its new assignment");
        assert_eq!(begin, (0, vec![(0, 4)]));

        // frames from the evicted id now draw unknown-client
        let out = c.on_line(1, &update_line(1, 0, 3));
        let err = Frame::parse(&out[0].1).unwrap();
        assert!(matches!(err, Frame::Error { tag: ErrorTag::UnknownClient, .. }));

        // the survivor finishes the round alone; accounting still closes
        for seq in 0..4 {
            c.on_line(0, &update_line(0, 0, seq));
        }
        c.tick();
        c.tick();
        assert_eq!(c.round(), 1);
        let r0 = c.round_stats()[0];
        assert!(r0.accounted(), "{r0:?}");
        assert_eq!(r0.evictions, 1);
        assert_eq!(r0.reassigned, 2);
    }

    #[test]
    fn rejoin_inherits_orphaned_seqs_when_no_survivors() {
        let mut cfg = test_cfg();
        cfg.clients = 1;
        let (mut c, clock) = coord(cfg);
        c.on_line(0, &Frame::Join { name: "a".into() }.encode(2));
        c.tick();
        c.on_line(0, &Frame::Ready { client: 0, round: 0 }.encode(2));
        c.tick();
        assert_eq!(c.phase(), Phase::Train);
        clock.advance(1000); // sole client dies; nobody to reassign to
        c.tick();
        assert_eq!(c.member_count(), 0);
        assert_eq!(c.phase(), Phase::Train, "round stays open for a joiner");

        let out = c.on_line(3, &Frame::Join { name: "a2".into() }.encode(2));
        // welcome + full snapshot + begin with the whole orphaned round
        assert_eq!(kinds(&out), vec!["welcome", "snap", "snap", "snap", "snap", "begin"]);
        let Frame::Begin { ranges, .. } = Frame::parse(&out.last().unwrap().1).unwrap() else {
            panic!("expected begin");
        };
        assert_eq!(ranges, vec![(0, 2)], "rejoiner inherits every orphaned seq");
        let Frame::Welcome { client, .. } = Frame::parse(&out[0].1).unwrap() else {
            panic!("expected welcome");
        };
        assert_eq!(client, 1, "rejoiner gets a fresh identity");
    }
}
