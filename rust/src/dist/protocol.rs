//! The dist round protocol's wire format: versioned, length-checked line
//! frames with typed parse errors.
//!
//! Every frame is one text line, `dist1 <type> key=value ...`. Floating
//! point payloads travel as fixed-width lowercase hex of the IEEE-754 bit
//! pattern (8 digits per `f32`, 16 per `f64`) — the wire is **bit-exact**
//! by construction, so a replica that applies the same update sets holds
//! byte-identical parameters. Vector payloads carry explicit counts and
//! are length-checked against them; any mismatch, unknown type, or wrong
//! version parses to a typed [`FrameError`] rather than a panic or a
//! silent skip, and the peer answers with an `error tag=<tag>` frame.
//!
//! Frame inventory (client → coordinator, then coordinator → client):
//!
//! ```text
//! dist1 join name=<token>
//! dist1 ready client=<id> round=<r>
//! dist1 hb client=<id> round=<r>
//! dist1 update client=<id> round=<r> seq=<s> n=<rows> k=<feat> loss=<f64hex> labels=<hex> gw=<hex> gb=<hex>
//! dist1 resync client=<id>
//!
//! dist1 welcome client=<id> round=<r> seed=<u64> c=<classes> k=<feat> batch=<b> lr=<f32hex>
//! dist1 snap round=<r> part=<w|b|gw2|gb2> n=<count> data=<hex>
//! dist1 begin round=<r> ranges=<a:b+c:d|-> csum=<u64hex>
//! dist1 ack round=<r> seq=<s>
//! dist1 apply round=<r> seq=<s> n=<rows> k=<feat> loss=<f64hex> labels=<hex> gw=<hex> gb=<hex>
//! dist1 error tag=<tag> detail=<text...>
//! dist1 shutdown
//! ```
//!
//! Error tags: `bad-version`, `bad-frame`, `bad-field`, `bad-length`,
//! `stale-round`, `unknown-client`. The first four are parse-level; the
//! last two are protocol-level (the coordinator rejects frames from
//! evicted clients or for already-committed rounds, and the client reacts
//! by rejoining through Warmup).

use crate::model::ParamStore;

/// Protocol version token leading every frame.
pub const PROTO_VERSION: &str = "dist1";

/// Typed reasons a frame is rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorTag {
    /// Leading version token is not [`PROTO_VERSION`].
    BadVersion,
    /// Unknown frame type or malformed structure.
    BadFrame,
    /// A field is missing or fails to parse.
    BadField,
    /// A vector payload disagrees with its declared count.
    BadLength,
    /// Frame addresses a round the coordinator already committed.
    StaleRound,
    /// Frame from a client id the coordinator evicted (or never issued).
    UnknownClient,
}

impl ErrorTag {
    pub fn name(self) -> &'static str {
        match self {
            ErrorTag::BadVersion => "bad-version",
            ErrorTag::BadFrame => "bad-frame",
            ErrorTag::BadField => "bad-field",
            ErrorTag::BadLength => "bad-length",
            ErrorTag::StaleRound => "stale-round",
            ErrorTag::UnknownClient => "unknown-client",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "bad-version" => Some(ErrorTag::BadVersion),
            "bad-frame" => Some(ErrorTag::BadFrame),
            "bad-field" => Some(ErrorTag::BadField),
            "bad-length" => Some(ErrorTag::BadLength),
            "stale-round" => Some(ErrorTag::StaleRound),
            "unknown-client" => Some(ErrorTag::UnknownClient),
            _ => None,
        }
    }
}

/// A rejected frame: the tag goes on the wire, the detail in logs/tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FrameError {
    pub tag: ErrorTag,
    pub detail: String,
}

impl FrameError {
    fn new(tag: ErrorTag, detail: impl Into<String>) -> Self {
        Self { tag, detail: detail.into() }
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.tag.name(), self.detail)
    }
}

/// The four snapshot payloads, in their canonical transmission order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SnapPart {
    W,
    B,
    Gw2,
    Gb2,
}

impl SnapPart {
    pub const ALL: [SnapPart; 4] = [SnapPart::W, SnapPart::B, SnapPart::Gw2, SnapPart::Gb2];

    pub fn name(self) -> &'static str {
        match self {
            SnapPart::W => "w",
            SnapPart::B => "b",
            SnapPart::Gw2 => "gw2",
            SnapPart::Gb2 => "gb2",
        }
    }

    fn from_name(name: &str) -> Option<Self> {
        match name {
            "w" => Some(SnapPart::W),
            "b" => Some(SnapPart::B),
            "gw2" => Some(SnapPart::Gw2),
            "gb2" => Some(SnapPart::Gb2),
            _ => None,
        }
    }
}

/// One batch's sparse Adagrad update: the rows touched (positive then
/// negative labels), their weight/bias gradients, and the batch loss.
/// A pure function of (round-start parameters, run seed, `seq`), which is
/// what makes aggregation order the only thing the coordinator must fix.
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateSet {
    pub seq: u64,
    pub labels: Vec<u32>,
    /// Row-major gradients, `labels.len() * feat_dim`.
    pub gw: Vec<f32>,
    pub gb: Vec<f32>,
    /// Mean per-example loss of the batch.
    pub loss: f64,
}

/// A parsed protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Join { name: String },
    Ready { client: u64, round: u64 },
    Heartbeat { client: u64, round: u64 },
    Update { client: u64, round: u64, set: UpdateSet },
    Resync { client: u64 },
    Welcome { client: u64, round: u64, seed: u64, c: u64, k: u64, batch: u64, lr: f32 },
    Snap { round: u64, part: SnapPart, data: Vec<f32> },
    Begin { round: u64, ranges: Vec<(u64, u64)>, csum: u64 },
    Ack { round: u64, seq: u64 },
    Apply { round: u64, set: UpdateSet },
    Error { tag: ErrorTag, detail: String },
    Shutdown,
}

// ---------------------------------------------------------------------------
// hex codecs
// ---------------------------------------------------------------------------

/// Fixed-width hex of each `f32`'s bit pattern, concatenated.
pub fn encode_f32s(xs: &[f32]) -> String {
    let mut out = String::with_capacity(xs.len() * 8);
    for x in xs {
        out.push_str(&format!("{:08x}", x.to_bits()));
    }
    out
}

/// Inverse of [`encode_f32s`]; the payload must hold exactly `expect`
/// values.
pub fn decode_f32s(field: &str, s: &str, expect: usize) -> Result<Vec<f32>, FrameError> {
    Ok(decode_u32s(field, s, expect)?.into_iter().map(f32::from_bits).collect())
}

/// Fixed-width hex of each `u32`, concatenated.
pub fn encode_u32s(xs: &[u32]) -> String {
    let mut out = String::with_capacity(xs.len() * 8);
    for x in xs {
        out.push_str(&format!("{x:08x}"));
    }
    out
}

/// Inverse of [`encode_u32s`]; length-checked against `expect`.
pub fn decode_u32s(field: &str, s: &str, expect: usize) -> Result<Vec<u32>, FrameError> {
    if s.len() != expect * 8 {
        return Err(FrameError::new(
            ErrorTag::BadLength,
            format!("field {field}: {} hex chars, expected {}", s.len(), expect * 8),
        ));
    }
    let mut out = Vec::with_capacity(expect);
    for chunk in s.as_bytes().chunks(8) {
        let txt = std::str::from_utf8(chunk)
            .map_err(|_| FrameError::new(ErrorTag::BadField, format!("field {field}: not hex")))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| {
            FrameError::new(ErrorTag::BadField, format!("field {field}: bad hex {txt:?}"))
        })?;
        out.push(v);
    }
    Ok(out)
}

fn encode_f64(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

fn decode_f64(field: &str, s: &str) -> Result<f64, FrameError> {
    if s.len() != 16 {
        return Err(FrameError::new(
            ErrorTag::BadLength,
            format!("field {field}: {} hex chars, expected 16", s.len()),
        ));
    }
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|_| FrameError::new(ErrorTag::BadField, format!("field {field}: bad hex {s:?}")))
}

fn encode_ranges(ranges: &[(u64, u64)]) -> String {
    if ranges.is_empty() {
        return "-".to_string();
    }
    ranges.iter().map(|(a, b)| format!("{a}:{b}")).collect::<Vec<_>>().join("+")
}

fn decode_ranges(s: &str) -> Result<Vec<(u64, u64)>, FrameError> {
    if s == "-" {
        return Ok(Vec::new());
    }
    let mut out = Vec::new();
    for part in s.split('+') {
        let (a, b) = part.split_once(':').ok_or_else(|| {
            FrameError::new(ErrorTag::BadField, format!("range {part:?}: expected A:B"))
        })?;
        let a = parse_u64("ranges", a)?;
        let b = parse_u64("ranges", b)?;
        if b < a {
            return Err(FrameError::new(ErrorTag::BadField, format!("range {part:?}: B < A")));
        }
        out.push((a, b));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// frame encode / parse
// ---------------------------------------------------------------------------

fn encode_update_body(set: &UpdateSet, k: usize) -> String {
    format!(
        "seq={} n={} k={} loss={} labels={} gw={} gb={}",
        set.seq,
        set.labels.len(),
        k,
        encode_f64(set.loss),
        encode_u32s(&set.labels),
        encode_f32s(&set.gw),
        encode_f32s(&set.gb),
    )
}

impl Frame {
    /// Render the frame as one protocol line. `feat_dim` is the row width
    /// update/apply payloads are length-checked against.
    pub fn encode(&self, feat_dim: usize) -> String {
        match self {
            Frame::Join { name } => format!("{PROTO_VERSION} join name={name}"),
            Frame::Ready { client, round } => {
                format!("{PROTO_VERSION} ready client={client} round={round}")
            }
            Frame::Heartbeat { client, round } => {
                format!("{PROTO_VERSION} hb client={client} round={round}")
            }
            Frame::Update { client, round, set } => format!(
                "{PROTO_VERSION} update client={client} round={round} {}",
                encode_update_body(set, feat_dim)
            ),
            Frame::Resync { client } => format!("{PROTO_VERSION} resync client={client}"),
            Frame::Welcome { client, round, seed, c, k, batch, lr } => format!(
                "{PROTO_VERSION} welcome client={client} round={round} seed={seed} \
                 c={c} k={k} batch={batch} lr={:08x}",
                lr.to_bits()
            ),
            Frame::Snap { round, part, data } => format!(
                "{PROTO_VERSION} snap round={round} part={} n={} data={}",
                part.name(),
                data.len(),
                encode_f32s(data)
            ),
            Frame::Begin { round, ranges, csum } => format!(
                "{PROTO_VERSION} begin round={round} ranges={} csum={csum:016x}",
                encode_ranges(ranges)
            ),
            Frame::Ack { round, seq } => format!("{PROTO_VERSION} ack round={round} seq={seq}"),
            Frame::Apply { round, set } => format!(
                "{PROTO_VERSION} apply round={round} {}",
                encode_update_body(set, feat_dim)
            ),
            Frame::Error { tag, detail } => {
                format!("{PROTO_VERSION} error tag={} detail={detail}", tag.name())
            }
            Frame::Shutdown => format!("{PROTO_VERSION} shutdown"),
        }
    }

    /// Parse one protocol line. Rejections are typed: wrong version, an
    /// unknown type, a missing/bad field, or a payload whose length
    /// disagrees with its declared count.
    pub fn parse(line: &str) -> Result<Frame, FrameError> {
        let line = line.trim();
        let (version, rest) = line.split_once(char::is_whitespace).unwrap_or((line, ""));
        if version != PROTO_VERSION {
            return Err(FrameError::new(
                ErrorTag::BadVersion,
                format!("version token {version:?}, expected {PROTO_VERSION:?}"),
            ));
        }
        let (kind, body) = rest.split_once(char::is_whitespace).unwrap_or((rest, ""));
        let fields = Fields::scan(body);
        match kind {
            "join" => Ok(Frame::Join { name: fields.get("name")?.to_string() }),
            "ready" => Ok(Frame::Ready {
                client: fields.u64("client")?,
                round: fields.u64("round")?,
            }),
            "hb" => Ok(Frame::Heartbeat {
                client: fields.u64("client")?,
                round: fields.u64("round")?,
            }),
            "update" => Ok(Frame::Update {
                client: fields.u64("client")?,
                round: fields.u64("round")?,
                set: fields.update_set()?,
            }),
            "resync" => Ok(Frame::Resync { client: fields.u64("client")? }),
            "welcome" => Ok(Frame::Welcome {
                client: fields.u64("client")?,
                round: fields.u64("round")?,
                seed: fields.u64("seed")?,
                c: fields.u64("c")?,
                k: fields.u64("k")?,
                batch: fields.u64("batch")?,
                lr: f32::from_bits(fields.hex_u32("lr")?),
            }),
            "snap" => {
                let part = fields.get("part").and_then(|p| {
                    SnapPart::from_name(p).ok_or_else(|| {
                        FrameError::new(ErrorTag::BadField, format!("unknown snap part {p:?}"))
                    })
                })?;
                let n = fields.u64("n")? as usize;
                let data = decode_f32s("data", fields.get("data")?, n)?;
                Ok(Frame::Snap { round: fields.u64("round")?, part, data })
            }
            "begin" => Ok(Frame::Begin {
                round: fields.u64("round")?,
                ranges: decode_ranges(fields.get("ranges")?)?,
                csum: fields.hex_u64("csum")?,
            }),
            "ack" => Ok(Frame::Ack { round: fields.u64("round")?, seq: fields.u64("seq")? }),
            "apply" => {
                Ok(Frame::Apply { round: fields.u64("round")?, set: fields.update_set()? })
            }
            "error" => {
                let tag = fields.get("tag").and_then(|t| {
                    ErrorTag::from_name(t).ok_or_else(|| {
                        FrameError::new(ErrorTag::BadField, format!("unknown error tag {t:?}"))
                    })
                })?;
                // the detail is free text: everything after "detail="
                let detail = body
                    .split_once("detail=")
                    .map(|(_, d)| d.to_string())
                    .unwrap_or_default();
                Ok(Frame::Error { tag, detail })
            }
            "shutdown" => Ok(Frame::Shutdown),
            other => {
                Err(FrameError::new(ErrorTag::BadFrame, format!("unknown frame type {other:?}")))
            }
        }
    }
}

/// Whitespace-separated `key=value` tokens of a frame body.
struct Fields<'a> {
    pairs: Vec<(&'a str, &'a str)>,
}

impl<'a> Fields<'a> {
    fn scan(body: &'a str) -> Self {
        let pairs = body.split_whitespace().filter_map(|tok| tok.split_once('=')).collect();
        Self { pairs }
    }

    fn get(&self, key: &str) -> Result<&'a str, FrameError> {
        self.pairs
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
            .ok_or_else(|| FrameError::new(ErrorTag::BadField, format!("missing field {key}")))
    }

    fn u64(&self, key: &str) -> Result<u64, FrameError> {
        parse_u64(key, self.get(key)?)
    }

    fn hex_u32(&self, key: &str) -> Result<u32, FrameError> {
        let v = self.get(key)?;
        u32::from_str_radix(v, 16).map_err(|_| {
            FrameError::new(ErrorTag::BadField, format!("field {key}: bad hex {v:?}"))
        })
    }

    fn hex_u64(&self, key: &str) -> Result<u64, FrameError> {
        let v = self.get(key)?;
        u64::from_str_radix(v, 16).map_err(|_| {
            FrameError::new(ErrorTag::BadField, format!("field {key}: bad hex {v:?}"))
        })
    }

    /// The shared `seq/n/k/loss/labels/gw/gb` body of update and apply
    /// frames, length-checked: `labels` holds `n` rows, `gw` holds `n*k`
    /// values, `gb` holds `n`.
    fn update_set(&self) -> Result<UpdateSet, FrameError> {
        let n = self.u64("n")? as usize;
        let k = self.u64("k")? as usize;
        let labels = decode_u32s("labels", self.get("labels")?, n)?;
        let gw = decode_f32s("gw", self.get("gw")?, n * k)?;
        let gb = decode_f32s("gb", self.get("gb")?, n)?;
        Ok(UpdateSet {
            seq: self.u64("seq")?,
            labels,
            gw,
            gb,
            loss: decode_f64("loss", self.get("loss")?)?,
        })
    }
}

fn parse_u64(key: &str, v: &str) -> Result<u64, FrameError> {
    v.parse()
        .map_err(|_| FrameError::new(ErrorTag::BadField, format!("field {key}: bad number {v:?}")))
}

// ---------------------------------------------------------------------------
// parameter checksum
// ---------------------------------------------------------------------------

/// FNV-1a over a parameter store's full bit pattern (dims, weights,
/// biases, both Adagrad accumulators). Replicas compare this against the
/// coordinator's value in every `begin` frame; any divergence — a dropped
/// or duplicated apply frame, a missed snapshot part — is caught before
/// the replica computes a single gradient against wrong parameters.
pub fn params_checksum(params: &ParamStore) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            h ^= (v >> shift) & 0xff;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    mix(params.num_classes as u64);
    mix(params.feat_dim as u64);
    for x in &params.w {
        mix(x.to_bits() as u64);
    }
    for x in &params.b {
        mix(x.to_bits() as u64);
    }
    let (gw2, gb2) = params.opt.accumulators();
    for x in gw2 {
        mix(x.to_bits() as u64);
    }
    for x in gb2 {
        mix(x.to_bits() as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_set() -> UpdateSet {
        UpdateSet {
            seq: 42,
            labels: vec![3, 1, 7, 1],
            gw: (0..8).map(|i| i as f32 * 0.25 - 1.0).collect(),
            gb: vec![0.5, -0.5, 1.5e-8, -0.0],
            loss: 0.6931471805599453,
        }
    }

    #[test]
    fn every_frame_round_trips() {
        let k = 2; // gw rows are 2 wide in sample_set
        let frames = vec![
            Frame::Join { name: "worker-a".into() },
            Frame::Ready { client: 3, round: 9 },
            Frame::Heartbeat { client: 0, round: 0 },
            Frame::Update { client: 1, round: 4, set: sample_set() },
            Frame::Resync { client: 2 },
            Frame::Welcome { client: 5, round: 1, seed: 99, c: 64, k: 2, batch: 16, lr: 0.05 },
            Frame::Snap { round: 2, part: SnapPart::Gw2, data: vec![0.0, -1.5, 3.25e-7] },
            Frame::Begin { round: 7, ranges: vec![(56, 60), (62, 64)], csum: 0xdead_beef },
            Frame::Begin { round: 7, ranges: vec![], csum: 1 },
            Frame::Ack { round: 7, seq: 58 },
            Frame::Apply { round: 7, set: sample_set() },
            Frame::Error { tag: ErrorTag::StaleRound, detail: "round 3 already committed".into() },
            Frame::Shutdown,
        ];
        for frame in frames {
            let line = frame.encode(k);
            let back = Frame::parse(&line).unwrap_or_else(|e| panic!("parse {line:?}: {e}"));
            assert_eq!(back, frame, "round-trip failed for {line:?}");
        }
    }

    #[test]
    fn float_payloads_are_bit_exact() {
        // values that decimal formatting would mangle survive the hex wire
        let xs = vec![f32::MIN_POSITIVE, -0.0, 1.0 + f32::EPSILON, 3.1415927];
        let back = decode_f32s("x", &encode_f32s(&xs), xs.len()).unwrap();
        let bits: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
        let back_bits: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, back_bits);
    }

    #[test]
    fn wrong_version_is_typed() {
        let err = Frame::parse("dist2 shutdown").unwrap_err();
        assert_eq!(err.tag, ErrorTag::BadVersion);
        let err = Frame::parse("garbage").unwrap_err();
        assert_eq!(err.tag, ErrorTag::BadVersion);
    }

    #[test]
    fn unknown_type_and_missing_fields_are_typed() {
        assert_eq!(Frame::parse("dist1 frobnicate").unwrap_err().tag, ErrorTag::BadFrame);
        assert_eq!(Frame::parse("dist1 ready client=1").unwrap_err().tag, ErrorTag::BadField);
        assert_eq!(Frame::parse("dist1 ack round=x seq=0").unwrap_err().tag, ErrorTag::BadField);
    }

    #[test]
    fn length_mismatch_is_typed() {
        let mut line = Frame::Update { client: 0, round: 0, set: sample_set() }.encode(2);
        // claim one more row than the payload carries
        line = line.replace("n=4", "n=5");
        assert_eq!(Frame::parse(&line).unwrap_err().tag, ErrorTag::BadLength);
        // truncated payload (a corrupted frame) is caught the same way
        let snap = Frame::Snap { round: 0, part: SnapPart::W, data: vec![1.0, 2.0] }.encode(2);
        let cut = &snap[..snap.len() - 3];
        let err = Frame::parse(cut).unwrap_err();
        assert!(matches!(err.tag, ErrorTag::BadLength | ErrorTag::BadField), "{err}");
    }

    #[test]
    fn error_tags_name_round_trip() {
        for tag in [
            ErrorTag::BadVersion,
            ErrorTag::BadFrame,
            ErrorTag::BadField,
            ErrorTag::BadLength,
            ErrorTag::StaleRound,
            ErrorTag::UnknownClient,
        ] {
            assert_eq!(ErrorTag::from_name(tag.name()), Some(tag));
        }
        assert_eq!(ErrorTag::from_name("nope"), None);
    }

    #[test]
    fn checksum_sees_every_component() {
        let base = ParamStore::zeros(4, 3, 0.1);
        let h0 = params_checksum(&base);
        assert_eq!(h0, params_checksum(&ParamStore::zeros(4, 3, 0.1)), "deterministic");
        let mut w = ParamStore::zeros(4, 3, 0.1);
        w.w[5] = 1.0e-30; // a single flipped bit anywhere must change the sum
        assert_ne!(params_checksum(&w), h0);
        let mut b = ParamStore::zeros(4, 3, 0.1);
        b.b[2] = -0.0; // -0.0 != +0.0 bitwise
        assert_ne!(params_checksum(&b), h0);
        let mut acc = ParamStore::zeros(4, 3, 0.1);
        acc.apply_sparse(&[1], &[0.5, 0.5, 0.5], &[0.5]);
        assert_ne!(params_checksum(&acc), h0);
    }
}
