//! The client side of the distributed round protocol: a worker that
//! joins a coordinator, mirrors the round-start parameters in a local
//! replica, computes its assigned batches' update sets, and survives a
//! hostile transport.
//!
//! Like the [`super::coordinator`], the client is transport-agnostic and
//! tick-driven: [`DistClient::on_line`] consumes coordinator frames,
//! [`DistClient::tick`] emits everything time-based (join retries,
//! heartbeats, resends, resync probes) against the injected
//! [`Clock`]. The socket worker and the in-memory sim are thin shells.
//!
//! **Replica discipline.** The replica is only ever written by (a) a
//! snapshot install — the coordinator's full bit pattern — or (b) `apply`
//! frames replayed in the coordinator's commit order. Update sets are
//! computed against the replica *between* commits, i.e. against exactly
//! the round-start parameters P_r, which is what makes aggregation
//! independent of which client computes which batch. Every `begin`
//! carries the coordinator's parameter checksum; any divergence (dropped
//! or duplicated `apply`, torn snapshot) is caught there and repaired
//! with a full resync rather than silently training on skewed weights.
//!
//! **Loss recovery.** Un-acked update sets are resent every `resend_ms`;
//! the coordinator acks duplicates idempotently. If the client is idle
//! with nothing to resend and hears nothing for two resend windows, it
//! probes with a `resync`. A typed `unknown-client` error (lease
//! expired) drops the identity and rejoins through Warmup; `stale-round`
//! abandons the stale work and resyncs into the current round.

use std::collections::{BTreeMap, BTreeSet};

use crate::dist::protocol::{params_checksum, ErrorTag, Frame, SnapPart, UpdateSet};
use crate::model::ParamStore;
use crate::utils::timer::Clock;
use crate::utils::Rng;

/// Stream salt for batch example draws ("batch").
const BATCH_SALT: u64 = 0x62_61_74_63_68;

/// A deterministic per-batch gradient step: maps (round-start parameters,
/// batch seq) to one sparse update set. Implementations must be pure —
/// the same `(params, seq)` must yield the same bits on every client.
pub trait GradStep: Send {
    fn compute(&self, params: &ParamStore, seq: u64) -> UpdateSet;
}

/// The built-in workload: synthetic negative-sampling logistic pairs, the
/// paper's Sec. 4 surrogate objective on on-the-fly Gaussian features.
/// The batch is drawn from `Rng(seed).stream(BATCH_SALT, seq)`, so it is
/// a pure function of the run seed and the batch seq — never of which
/// client computes it.
#[derive(Clone, Copy, Debug)]
pub struct HostNsStep {
    pub seed: u64,
    pub c: usize,
    pub k: usize,
    pub batch: usize,
}

impl GradStep for HostNsStep {
    fn compute(&self, params: &ParamStore, seq: u64) -> UpdateSet {
        let mut rng = Rng::new(self.seed).stream(BATCH_SALT, seq);
        let n = self.batch;
        let mut labels = Vec::with_capacity(2 * n);
        let mut gw = Vec::with_capacity(2 * n * self.k);
        let mut gb = Vec::with_capacity(2 * n);
        let mut losses = Vec::with_capacity(n);
        for _ in 0..n {
            let y = rng.below(self.c) as u32;
            let x: Vec<f32> = (0..self.k).map(|_| rng.normal()).collect();
            let mut neg = rng.below(self.c) as u32;
            if neg == y {
                neg = (neg + 1) % (self.c as u32);
            }
            let up = (crate::linalg::dot(&x, params.row(y)) + params.b[y as usize]) as f64;
            let un = (crate::linalg::dot(&x, params.row(neg)) + params.b[neg as usize]) as f64;
            // L = ln(1 + e^{-u+}) + ln(1 + e^{u-})  (paper Eq. 3 pair)
            losses.push((-up).exp().ln_1p() + un.exp().ln_1p());
            let dp = (-1.0 / (1.0 + up.exp())) as f32;
            let dn = (1.0 / (1.0 + (-un).exp())) as f32;
            labels.push(y);
            for &xi in &x {
                gw.push(dp * xi);
            }
            gb.push(dp);
            labels.push(neg);
            for &xi in &x {
                gw.push(dn * xi);
            }
            gb.push(dn);
        }
        let loss = crate::linalg::sum_f64(losses) / n as f64;
        UpdateSet { seq, labels, gw, gb, loss }
    }
}

/// Client-side counters (mirrors the coordinator's ledger for tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Update sets computed (first-time, not resends).
    pub computed: u64,
    /// Update lines re-emitted by the resend timer.
    pub resent: u64,
    /// Acks consumed.
    pub acked: u64,
    /// `apply` frames replayed into the replica.
    pub applies: u64,
    /// Resync requests sent (checksum mismatch, stale round, idle probe).
    pub resyncs: u64,
    /// Identity resets after an `unknown-client` error.
    pub rejoins: u64,
    /// Inbound lines that failed to parse (or were not client-bound).
    pub malformed_in: u64,
    /// Typed error frames received.
    pub errors_in: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ClientPhase {
    /// No identity yet: retry `join` until a `welcome` arrives.
    Joining,
    /// Welcomed; installing the snapshot, waiting for the first `begin`.
    Warmup,
    /// In the round loop: compute, send, resend, replay commits.
    Running,
    /// Coordinator said `shutdown`; emit nothing further.
    Finished,
}

/// A protocol client. Owns a replica [`ParamStore`] and the deterministic
/// [`HostNsStep`]; both are built from the `welcome` frame, so a fresh
/// process (or a rejoining one) needs nothing but the socket and a name.
pub struct DistClient {
    name: String,
    clock: Box<dyn Clock>,
    heartbeat_ms: u64,
    resend_ms: u64,
    phase: ClientPhase,
    client: Option<u64>,
    round: u64,
    k: usize,
    replica: Option<ParamStore>,
    step: Option<HostNsStep>,
    /// Seqs the coordinator assigned to us this round.
    assignment: BTreeSet<u64>,
    /// seq -> encoded update line awaiting an ack.
    pending: BTreeMap<u64, String>,
    acked: BTreeSet<u64>,
    /// Seqs whose `apply` we already replayed (dedupes duplicated frames).
    applied: BTreeSet<u64>,
    next_join_ms: u64,
    last_hb_ms: u64,
    last_resend_ms: u64,
    last_progress_ms: u64,
    stats: ClientStats,
}

impl DistClient {
    pub fn new(
        name: impl Into<String>,
        clock: Box<dyn Clock>,
        heartbeat_ms: u64,
        resend_ms: u64,
    ) -> Self {
        Self {
            name: name.into(),
            clock,
            heartbeat_ms: heartbeat_ms.max(1),
            resend_ms: resend_ms.max(1),
            phase: ClientPhase::Joining,
            client: None,
            round: 0,
            k: 0,
            replica: None,
            step: None,
            assignment: BTreeSet::new(),
            pending: BTreeMap::new(),
            acked: BTreeSet::new(),
            applied: BTreeSet::new(),
            next_join_ms: 0,
            last_hb_ms: 0,
            last_resend_ms: 0,
            last_progress_ms: 0,
            stats: ClientStats::default(),
        }
    }

    pub fn finished(&self) -> bool {
        self.phase == ClientPhase::Finished
    }

    pub fn client_id(&self) -> Option<u64> {
        self.client
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn stats(&self) -> ClientStats {
        self.stats
    }

    /// The local replica (None until welcomed).
    pub fn replica(&self) -> Option<&ParamStore> {
        self.replica.as_ref()
    }

    fn join_line(&self) -> String {
        Frame::Join { name: self.name.clone() }.encode(self.k)
    }

    /// Drop the identity and everything derived from it; the next tick
    /// rejoins from scratch (the coordinator hands back a fresh id and a
    /// full snapshot — Warmup again).
    fn reset_identity(&mut self) {
        self.phase = ClientPhase::Joining;
        self.client = None;
        self.round = 0;
        self.replica = None;
        self.step = None;
        self.assignment.clear();
        self.pending.clear();
        self.acked.clear();
        self.applied.clear();
    }

    // -- inbound ----------------------------------------------------------

    /// Consume one coordinator line; returns protocol lines to send back.
    pub fn on_line(&mut self, line: &str) -> Vec<String> {
        let mut out = Vec::new();
        let text = line.trim();
        if text.is_empty() || self.phase == ClientPhase::Finished {
            return out;
        }
        let frame = match Frame::parse(text) {
            Ok(f) => f,
            Err(_) => {
                self.stats.malformed_in += 1;
                return out;
            }
        };
        match frame {
            Frame::Welcome { client, round, seed, c, k, batch, lr } => {
                self.on_welcome(client, round, seed, c, k, batch, lr);
            }
            Frame::Snap { part, data, .. } => self.on_snap(part, &data),
            Frame::Begin { round, ranges, csum } => self.on_begin(round, &ranges, csum, &mut out),
            Frame::Ack { round, seq } => {
                if round == self.round && self.pending.remove(&seq).is_some() {
                    self.acked.insert(seq);
                    self.stats.acked += 1;
                    self.last_progress_ms = self.clock.now_ms();
                }
            }
            Frame::Apply { round, set } => self.on_apply(round, set),
            Frame::Error { tag, .. } => self.on_error(tag, &mut out),
            Frame::Shutdown => self.phase = ClientPhase::Finished,
            // join/ready/hb/update/resync are coordinator-bound
            _ => self.stats.malformed_in += 1,
        }
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn on_welcome(
        &mut self,
        client: u64,
        round: u64,
        seed: u64,
        c: u64,
        k: u64,
        batch: u64,
        lr: f32,
    ) {
        self.client = Some(client);
        self.round = round;
        self.k = k as usize;
        self.replica = Some(ParamStore::zeros(c as usize, k as usize, lr));
        self.step = Some(HostNsStep { seed, c: c as usize, k: k as usize, batch: batch as usize });
        self.assignment.clear();
        self.pending.clear();
        self.acked.clear();
        self.applied.clear();
        self.phase = ClientPhase::Warmup;
        self.last_progress_ms = self.clock.now_ms();
    }

    fn on_snap(&mut self, part: SnapPart, data: &[f32]) {
        let Some(replica) = self.replica.as_mut() else { return };
        let dst: &mut [f32] = match part {
            SnapPart::W => &mut replica.w,
            SnapPart::B => &mut replica.b,
            SnapPart::Gw2 => replica.opt.accumulators_mut().0,
            SnapPart::Gb2 => replica.opt.accumulators_mut().1,
        };
        if dst.len() == data.len() {
            dst.copy_from_slice(data);
            self.last_progress_ms = self.clock.now_ms();
        } else {
            self.stats.malformed_in += 1;
        }
    }

    fn on_begin(&mut self, round: u64, ranges: &[(u64, u64)], csum: u64, out: &mut Vec<String>) {
        let Some(client) = self.client else { return };
        let Some(replica) = self.replica.as_ref() else { return };
        if round < self.round {
            return; // late frame from a committed round
        }
        let now = self.clock.now_ms();
        self.last_progress_ms = now;
        if params_checksum(replica) != csum {
            // replica diverged (lost apply / torn snapshot): full resync
            self.stats.resyncs += 1;
            out.push(Frame::Resync { client }.encode(self.k));
            return;
        }
        if round > self.round {
            self.round = round;
            self.pending.clear();
            self.acked.clear();
            self.applied.clear();
        }
        self.assignment = ranges.iter().flat_map(|&(a, b)| a..b).collect();
        self.phase = ClientPhase::Running;
        out.push(Frame::Ready { client, round: self.round }.encode(self.k));
        let todo: Vec<u64> = self
            .assignment
            .iter()
            .filter(|s| !self.acked.contains(s) && !self.pending.contains_key(s))
            .copied()
            .collect();
        if todo.is_empty() {
            return;
        }
        let step = self.step.as_ref().expect("step exists whenever replica does");
        for seq in todo {
            let set = step.compute(replica, seq);
            let line = Frame::Update { client, round: self.round, set }.encode(self.k);
            out.push(line.clone());
            self.pending.insert(seq, line);
            self.stats.computed += 1;
        }
        self.last_resend_ms = now;
    }

    fn on_apply(&mut self, round: u64, set: UpdateSet) {
        if round != self.round {
            return; // a commit we already resynced past (or never reach)
        }
        if !self.applied.insert(set.seq) {
            return; // duplicated apply frame: replay exactly once
        }
        if let Some(replica) = self.replica.as_mut() {
            replica.apply_sparse(&set.labels, &set.gw, &set.gb);
            self.stats.applies += 1;
            self.last_progress_ms = self.clock.now_ms();
        }
    }

    fn on_error(&mut self, tag: ErrorTag, out: &mut Vec<String>) {
        self.stats.errors_in += 1;
        match tag {
            ErrorTag::UnknownClient => {
                // lease expired while we were partitioned: start over
                self.stats.rejoins += 1;
                self.reset_identity();
                out.push(self.join_line());
                self.next_join_ms = self.clock.now_ms() + self.resend_ms;
            }
            ErrorTag::StaleRound => {
                // the round committed without us; drop the stale work and
                // pull the current round's state (first stale error only —
                // in-flight resends draw more of these)
                if !self.pending.is_empty() {
                    self.pending.clear();
                    if let Some(client) = self.client {
                        self.stats.resyncs += 1;
                        out.push(Frame::Resync { client }.encode(self.k));
                    }
                }
            }
            // our frame got corrupted in flight; the resend timer re-emits
            // the original from `pending`
            _ => {}
        }
    }

    // -- tick -------------------------------------------------------------

    /// Time-based sends: join retries while identityless, heartbeats to
    /// keep the lease, resends for un-acked updates, and a resync probe
    /// when idle too long (two resend windows with no progress).
    pub fn tick(&mut self) -> Vec<String> {
        let mut out = Vec::new();
        if self.phase == ClientPhase::Finished {
            return out;
        }
        let now = self.clock.now_ms();
        let Some(client) = self.client else {
            if now >= self.next_join_ms {
                out.push(self.join_line());
                self.next_join_ms = now + self.resend_ms;
            }
            return out;
        };
        if now.saturating_sub(self.last_hb_ms) >= self.heartbeat_ms {
            out.push(Frame::Heartbeat { client, round: self.round }.encode(self.k));
            self.last_hb_ms = now;
        }
        if !self.pending.is_empty() && now.saturating_sub(self.last_resend_ms) >= self.resend_ms {
            for line in self.pending.values() {
                out.push(line.clone());
                self.stats.resent += 1;
            }
            self.last_resend_ms = now;
        }
        if self.pending.is_empty()
            && now.saturating_sub(self.last_progress_ms) >= 2 * self.resend_ms
        {
            // nothing to resend and the coordinator has gone quiet: the
            // commit or our assignment may have been lost — ask for it
            self.stats.resyncs += 1;
            out.push(Frame::Resync { client }.encode(self.k));
            self.last_progress_ms = now;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::timer::ManualClock;

    fn client(clock: &ManualClock) -> DistClient {
        DistClient::new("w0", Box::new(clock.clone()), 50, 200)
    }

    fn welcome_line(client: u64, round: u64) -> String {
        let frame = Frame::Welcome {
            client,
            round,
            seed: 7,
            c: 8,
            k: 3,
            batch: 2,
            lr: 0.1,
        };
        frame.encode(3)
    }

    fn zeros_csum() -> u64 {
        params_checksum(&ParamStore::zeros(8, 3, 0.1))
    }

    #[test]
    fn host_ns_step_is_a_pure_function_of_seed_and_seq() {
        let step = HostNsStep { seed: 11, c: 16, k: 4, batch: 3 };
        let params = ParamStore::zeros(16, 4, 0.1);
        let a = step.compute(&params, 5);
        let b = step.compute(&params, 5);
        assert_eq!(a, b, "identical inputs, identical bits");
        let c = step.compute(&params, 6);
        assert_ne!(a.labels, c.labels, "different seqs draw different batches");
        assert_eq!(a.labels.len(), 6, "pos+neg rows per example");
        assert_eq!(a.gw.len(), 6 * 4);
        assert_eq!(a.gb.len(), 6);
        assert!(a.loss.is_finite());
        assert!(a.labels.iter().all(|&y| y < 16));
    }

    #[test]
    fn joins_until_welcomed_then_computes_assignment() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        let out = c.tick();
        assert_eq!(out.len(), 1);
        assert!(matches!(Frame::parse(&out[0]), Ok(Frame::Join { .. })));
        assert!(c.tick().is_empty(), "join retry is rate-limited");
        clock.advance(200);
        assert_eq!(c.tick().len(), 1, "unanswered join retries after resend_ms");

        assert!(c.on_line(&welcome_line(4, 0)).is_empty());
        assert_eq!(c.client_id(), Some(4));
        let begin = Frame::Begin { round: 0, ranges: vec![(0, 2)], csum: zeros_csum() };
        let out = c.on_line(&begin.encode(3));
        assert_eq!(out.len(), 3, "ready + one update per assigned seq");
        assert!(matches!(Frame::parse(&out[0]), Ok(Frame::Ready { client: 4, round: 0 })));
        for (i, line) in out[1..].iter().enumerate() {
            let Ok(Frame::Update { client, round, set }) = Frame::parse(line) else {
                panic!("expected update, got {line:?}");
            };
            assert_eq!((client, round, set.seq), (4, 0, i as u64));
            assert_eq!(set.gw.len(), set.labels.len() * 3);
        }
        assert_eq!(c.stats().computed, 2);
    }

    #[test]
    fn unacked_updates_resend_and_acks_retire_them() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        c.on_line(&welcome_line(0, 0));
        let begin = Frame::Begin { round: 0, ranges: vec![(0, 2)], csum: zeros_csum() };
        c.on_line(&begin.encode(3));
        assert!(c.tick().iter().all(|l| !l.contains(" update ")), "too early to resend");
        clock.advance(200);
        let out = c.tick();
        assert_eq!(out.iter().filter(|l| l.contains(" update ")).count(), 2);
        assert_eq!(c.stats().resent, 2);

        c.on_line(&Frame::Ack { round: 0, seq: 0 }.encode(3));
        clock.advance(200);
        let out = c.tick();
        assert_eq!(out.iter().filter(|l| l.contains(" update ")).count(), 1, "only seq 1 left");
        assert_eq!(c.stats().acked, 1);
    }

    #[test]
    fn checksum_mismatch_asks_for_resync() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        c.on_line(&welcome_line(0, 0));
        let begin = Frame::Begin { round: 0, ranges: vec![(0, 2)], csum: 0xdead };
        let out = c.on_line(&begin.encode(3));
        assert_eq!(out.len(), 1);
        assert!(matches!(Frame::parse(&out[0]), Ok(Frame::Resync { client: 0 })));
        assert_eq!(c.stats().resyncs, 1);
    }

    #[test]
    fn commit_replay_keeps_the_replica_in_lockstep() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        c.on_line(&welcome_line(0, 0));
        let begin = Frame::Begin { round: 0, ranges: vec![(0, 2)], csum: zeros_csum() };
        let updates = c.on_line(&begin.encode(3));
        // mirror the coordinator: stage both sets, apply in seq order
        let mut authority = ParamStore::zeros(8, 3, 0.1);
        let mut applies = Vec::new();
        for line in &updates[1..] {
            let Ok(Frame::Update { set, .. }) = Frame::parse(line) else { panic!() };
            authority.apply_sparse(&set.labels, &set.gw, &set.gb);
            applies.push(Frame::Apply { round: 0, set }.encode(3));
        }
        for line in &applies {
            assert!(c.on_line(line).is_empty());
        }
        // duplicated apply frames replay exactly once
        assert!(c.on_line(&applies[0]).is_empty());
        assert_eq!(c.stats().applies, 2);
        let next = Frame::Begin {
            round: 1,
            ranges: vec![(2, 4)],
            csum: params_checksum(&authority),
        };
        let out = c.on_line(&next.encode(3));
        assert_eq!(c.round(), 1);
        assert_eq!(out.len(), 3, "checksum matched: ready + two fresh updates");
        assert_eq!(c.stats().resyncs, 0);
    }

    #[test]
    fn unknown_client_error_rejoins_from_scratch() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        c.on_line(&welcome_line(2, 0));
        let out = c.on_line(&Frame::Error {
            tag: ErrorTag::UnknownClient,
            detail: "client 2".into(),
        }
        .encode(3));
        assert_eq!(out.len(), 1);
        assert!(matches!(Frame::parse(&out[0]), Ok(Frame::Join { .. })));
        assert_eq!(c.client_id(), None);
        assert_eq!(c.stats().rejoins, 1);
        assert!(c.replica().is_none(), "replica discarded with the identity");
    }

    #[test]
    fn heartbeats_and_idle_resync_probe_fire_on_schedule() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        c.on_line(&welcome_line(0, 0));
        clock.advance(50);
        let out = c.tick();
        assert!(out.iter().any(|l| l.contains(" hb ")), "heartbeat at heartbeat_ms");
        // two resend windows with no progress -> resync probe
        clock.advance(350);
        let out = c.tick();
        assert!(out.iter().any(|l| l.contains(" resync ")), "idle probe: {out:?}");
        assert_eq!(c.stats().resyncs, 1);
    }

    #[test]
    fn shutdown_silences_the_client() {
        let clock = ManualClock::new();
        let mut c = client(&clock);
        c.on_line(&welcome_line(0, 0));
        c.on_line(&Frame::Shutdown.encode(3));
        assert!(c.finished());
        clock.advance(10_000);
        assert!(c.tick().is_empty());
    }
}
