//! In-memory network simulation for the distributed round protocol: one
//! [`Coordinator`] plus M [`DistClient`]s wired through [`FaultGate`]s on
//! a shared [`ManualClock`].
//!
//! The sim is fully deterministic: virtual time advances in fixed ticks,
//! frames are delivered from a FIFO queue (delayed frames re-enter at
//! their due time from a `BTreeMap` keyed `(due_ms, arrival_counter)`),
//! and every fault decision is a counter-based draw from the
//! [`FaultPlan`]. Re-running the same `(config, M, plan)` replays the
//! exact same [`SimNet::trace`] — the chaos tests assert this, and it is
//! what makes any distributed-protocol failure reproducible from its
//! seed. Used by `tests/dist_parity.rs`, `tests/dist_chaos.rs` and the
//! `dist_round` hot-path benchmark.

use std::collections::{BTreeMap, VecDeque};

use crate::config::DistConfig;
use crate::dist::client::DistClient;
use crate::dist::coordinator::Coordinator;
use crate::utils::faults::{FaultGate, FaultPlan};
use crate::utils::timer::ManualClock;
use anyhow::{bail, Result};

/// Upper bound on deliveries within one tick; a synchronous message
/// cascade longer than this means the protocol is ping-ponging.
const MAX_DELIVERIES_PER_STEP: usize = 100_000;

#[derive(Clone, Debug)]
enum Dest {
    /// To the coordinator, from the client on connection `conn`.
    Coord { conn: usize },
    /// To the client currently bound to connection `conn`.
    Client { conn: usize },
}

#[derive(Clone, Debug)]
struct Envelope {
    dest: Dest,
    line: String,
}

/// One coordinator + M clients over a simulated faulty transport.
pub struct SimNet {
    clock: ManualClock,
    coord: Coordinator,
    /// Slot -> live client (None while killed).
    clients: Vec<Option<DistClient>>,
    /// Slot -> its current connection id (changes on rejoin).
    conn_of_slot: Vec<usize>,
    /// Rejoin generation per slot (for deterministic worker names).
    generation: Vec<u64>,
    next_conn: usize,
    /// client -> coordinator gate.
    c2s: FaultGate,
    /// coordinator -> client gate.
    s2c: FaultGate,
    delayed: BTreeMap<(u64, u64), Envelope>,
    delay_seq: u64,
    queue: VecDeque<Envelope>,
    trace: Vec<String>,
    tick_ms: u64,
    cfg: DistConfig,
}

impl SimNet {
    /// Build a net with `m` clients. `plan` gates both directions
    /// independently (stages `"c2s"` and `"s2c"`); `None` is a perfect
    /// network.
    pub fn new(cfg: DistConfig, m: usize, plan: Option<FaultPlan>) -> Result<Self> {
        let clock = ManualClock::new();
        let coord = Coordinator::new(cfg.clone(), Box::new(clock.clone()))?;
        let mut net = Self {
            clock,
            coord,
            clients: Vec::new(),
            conn_of_slot: Vec::new(),
            generation: Vec::new(),
            next_conn: 0,
            c2s: FaultGate::new(plan.clone(), "c2s"),
            s2c: FaultGate::new(plan, "s2c"),
            delayed: BTreeMap::new(),
            delay_seq: 0,
            queue: VecDeque::new(),
            trace: Vec::new(),
            tick_ms: 50,
            cfg,
        };
        for slot in 0..m {
            let client = net.make_client(slot, 0);
            net.clients.push(Some(client));
            net.conn_of_slot.push(net.next_conn);
            net.generation.push(0);
            net.next_conn += 1;
        }
        Ok(net)
    }

    /// Virtual milliseconds advanced per [`SimNet::step`] (default 50).
    pub fn set_tick_ms(&mut self, ms: u64) {
        self.tick_ms = ms.max(1);
    }

    fn make_client(&self, slot: usize, generation: u64) -> DistClient {
        DistClient::new(
            format!("w{slot}.{generation}"),
            Box::new(self.clock.clone()),
            self.cfg.heartbeat_ms(),
            self.cfg.resend_ms,
        )
    }

    pub fn coord(&self) -> &Coordinator {
        &self.coord
    }

    pub fn clock(&self) -> &ManualClock {
        &self.clock
    }

    /// Chronological record of every delivered frame.
    pub fn trace(&self) -> &[String] {
        &self.trace
    }

    pub fn client(&self, slot: usize) -> Option<&DistClient> {
        self.clients[slot].as_ref()
    }

    /// Kill a client process: it stops ticking and answering. The
    /// coordinator is *not* told — only the missed heartbeats are.
    pub fn kill(&mut self, slot: usize) {
        self.clients[slot] = None;
    }

    /// Restart a killed client as a fresh process on a new connection; it
    /// re-enters through Join/Warmup and inherits whatever seqs are
    /// orphaned.
    pub fn rejoin(&mut self, slot: usize) {
        self.generation[slot] += 1;
        self.clients[slot] = Some(self.make_client(slot, self.generation[slot]));
        self.conn_of_slot[slot] = self.next_conn;
        self.next_conn += 1;
    }

    fn slot_of_conn(&self, conn: usize) -> Option<usize> {
        self.conn_of_slot.iter().position(|&c| c == conn)
    }

    /// Route one outbound frame through its direction's gate, queueing
    /// (or delaying) the surviving copies.
    fn post(&mut self, dest: Dest, line: &str) {
        let gate = match dest {
            Dest::Coord { .. } => &mut self.c2s,
            Dest::Client { .. } => &mut self.s2c,
        };
        let gated = gate.pass(line);
        for delivered in gated.lines {
            let env = Envelope { dest: dest.clone(), line: delivered };
            if gated.delay_ms == 0 {
                self.queue.push_back(env);
            } else {
                let due = self.clock.now_ms() + gated.delay_ms;
                self.delayed.insert((due, self.delay_seq), env);
                self.delay_seq += 1;
            }
        }
    }

    fn post_from_coord(&mut self, frames: Vec<(usize, String)>) {
        for (conn, line) in frames {
            self.post(Dest::Client { conn }, &line);
        }
    }

    fn post_from_client(&mut self, conn: usize, lines: Vec<String>) {
        for line in lines {
            self.post(Dest::Coord { conn }, &line);
        }
    }

    /// One tick: advance virtual time, release due delayed frames, tick
    /// the coordinator and every live client, then drain the delivery
    /// queue to quiescence.
    pub fn step(&mut self) -> Result<()> {
        self.clock.advance(self.tick_ms);
        let now = self.clock.now_ms();
        // release delayed frames whose due time has arrived, in (due,
        // arrival) order
        let due: Vec<(u64, u64)> = self
            .delayed
            .range(..=(now, u64::MAX))
            .map(|(&key, _)| key)
            .collect();
        for key in due {
            if let Some(env) = self.delayed.remove(&key) {
                self.queue.push_back(env);
            }
        }
        let out = self.coord.tick();
        self.post_from_coord(out);
        for slot in 0..self.clients.len() {
            let conn = self.conn_of_slot[slot];
            if let Some(client) = self.clients[slot].as_mut() {
                let lines = client.tick();
                self.post_from_client(conn, lines);
            }
        }
        let mut delivered = 0usize;
        while let Some(env) = self.queue.pop_front() {
            delivered += 1;
            if delivered > MAX_DELIVERIES_PER_STEP {
                bail!("delivery cascade exceeded {MAX_DELIVERIES_PER_STEP} frames in one tick");
            }
            match env.dest {
                Dest::Coord { conn } => {
                    self.trace.push(format!("t={now} c{conn}->coord {}", env.line));
                    let replies = self.coord.on_line(conn, &env.line);
                    self.post_from_coord(replies);
                }
                Dest::Client { conn } => {
                    let Some(slot) = self.slot_of_conn(conn) else {
                        continue; // connection retired by a rejoin
                    };
                    let Some(client) = self.clients[slot].as_mut() else {
                        continue; // killed: frames to it fall on the floor
                    };
                    self.trace.push(format!("t={now} coord->c{conn} {}", env.line));
                    let replies = client.on_line(&env.line);
                    self.post_from_client(conn, replies);
                }
            }
        }
        Ok(())
    }

    /// Step until the coordinator finishes all rounds; `false` if it did
    /// not finish within `max_steps`.
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<bool> {
        for _ in 0..max_steps {
            if self.coord.is_done() {
                return Ok(true);
            }
            self.step()?;
        }
        Ok(self.coord.is_done())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::coordinator::Phase;

    fn small_cfg(clients: usize) -> DistConfig {
        DistConfig {
            clients,
            rounds: 3,
            batches_per_round: 4,
            batch_size: 2,
            num_classes: 16,
            feat_dim: 4,
            lr: 0.1,
            seed: 42,
            lease_ms: 1000,
            resend_ms: 200,
        }
    }

    #[test]
    fn clean_run_completes_all_rounds() {
        let mut net = SimNet::new(small_cfg(2), 2, None).unwrap();
        assert!(net.run_to_completion(200).unwrap());
        assert_eq!(net.coord().round_stats().len(), 3);
        assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
        assert_eq!(net.coord().stats().evictions, 0);
        assert!(net.coord().params().w.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn kill_mid_run_reassigns_and_completes() {
        let mut net = SimNet::new(small_cfg(2), 2, None).unwrap();
        // run until training is underway, then kill slot 1
        while net.coord().phase() != Phase::Train {
            net.step().unwrap();
        }
        net.kill(1);
        assert!(net.run_to_completion(500).unwrap(), "survivor finishes alone");
        assert!(net.coord().round_stats().iter().all(|r| r.accounted()));
        assert_eq!(net.coord().stats().evictions, 1);
    }

    #[test]
    fn trace_is_identical_across_reruns() {
        let run = || {
            let mut net = SimNet::new(small_cfg(2), 2, None).unwrap();
            net.run_to_completion(200).unwrap();
            net.trace().to_vec()
        };
        let a = run();
        let b = run();
        assert!(!a.is_empty());
        assert_eq!(a, b);
    }
}
