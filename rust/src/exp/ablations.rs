//! A1-A3 — ablations of the design choices DESIGN.md calls out:
//!
//! * **A1 bias removal** (Eq. 5): train the adversarial method once, score
//!   the test set with and without the + log p_n(y|x) correction.
//! * **A2 auxiliary dimension k**: quality/speed trade-off of the PCA
//!   projection (paper fixes k = 16).
//! * **A3 regularizer** (Eq. 6 vs plain Eq. 2): lambda = tuned vs 0.

use super::{print_table, write_csv};
use crate::config::{DatasetPreset, Method, RunConfig, SyntheticConfig};
use crate::data::Splits;
use crate::runtime::Registry;
use crate::train::TrainRun;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct AblationOpts {
    pub dataset: DatasetPreset,
    pub seconds: f64,
    pub max_steps: usize,
    pub seed: u64,
}

impl Default for AblationOpts {
    fn default() -> Self {
        Self {
            dataset: DatasetPreset::Tiny,
            seconds: 30.0,
            max_steps: 3_000,
            seed: 1,
        }
    }
}

fn base_cfg(o: &AblationOpts) -> RunConfig {
    let mut cfg = RunConfig::new(o.dataset, Method::Adversarial);
    cfg.max_seconds = o.seconds;
    cfg.max_steps = o.max_steps;
    cfg.seed = o.seed;
    cfg
}

/// A1: bias correction on/off after one adversarial training run.
pub fn bias_removal(registry: &Registry, o: &AblationOpts) -> Result<(f64, f64)> {
    let splits = Splits::synthetic(&SyntheticConfig::preset(o.dataset));
    let cfg = base_cfg(o);
    let mut run = TrainRun::prepare(registry, &splits, &cfg)?;
    run.train()?;
    let with = run.evaluate_with(true)?;
    let without = run.evaluate_with(false)?;
    let rows = vec![
        vec!["with Eq.5 correction".into(), format!("{:.4}", with.accuracy),
             format!("{:.4}", with.log_likelihood)],
        vec!["without (raw xi)".into(), format!("{:.4}", without.accuracy),
             format!("{:.4}", without.log_likelihood)],
    ];
    print_table(
        "Ablation A1: bias removal (adversarial method)",
        &["scoring", "accuracy", "loglik"],
        &rows,
    );
    write_csv("ablation_bias.csv", &["scoring", "accuracy", "loglik"], &rows)?;
    Ok((with.accuracy, without.accuracy))
}

/// A2: auxiliary dimension sweep.
pub fn aux_dim_sweep(registry: &Registry, o: &AblationOpts, ks: &[usize]) -> Result<Vec<(usize, f64, f64)>> {
    let splits = Splits::synthetic(&SyntheticConfig::preset(o.dataset));
    let mut out = Vec::new();
    for &k in ks {
        let mut cfg = base_cfg(o);
        cfg.tree.aux_dim = k;
        let mut run = TrainRun::prepare(registry, &splits, &cfg)?;
        let curve = run.train()?;
        out.push((k, curve.best_accuracy(), run.aux_fit_seconds));
    }
    let rows: Vec<Vec<String>> = out
        .iter()
        .map(|(k, acc, fit)| vec![k.to_string(), format!("{acc:.4}"), format!("{fit:.2}s")])
        .collect();
    print_table(
        "Ablation A2: auxiliary PCA dimension k",
        &["k", "best_accuracy", "aux_fit_time"],
        &rows,
    );
    write_csv("ablation_k.csv", &["k", "best_accuracy", "aux_fit_seconds"], &rows)?;
    Ok(out)
}

/// A3: Eq. 6 regularizer vs plain Eq. 2 (lambda = 0).
pub fn regularizer(registry: &Registry, o: &AblationOpts) -> Result<Vec<(f32, f64, f64)>> {
    let splits = Splits::synthetic(&SyntheticConfig::preset(o.dataset));
    let tuned = base_cfg(o).hyper.lambda;
    let mut out = Vec::new();
    for lam in [0.0f32, tuned, tuned * 10.0] {
        let mut cfg = base_cfg(o);
        cfg.hyper.lambda = lam;
        let mut run = TrainRun::prepare(registry, &splits, &cfg)?;
        let curve = run.train()?;
        out.push((lam, curve.best_accuracy(), curve.best_log_likelihood()));
    }
    let rows: Vec<Vec<String>> = out
        .iter()
        .map(|(l, acc, ll)| vec![format!("{l}"), format!("{acc:.4}"), format!("{ll:.4}")])
        .collect();
    print_table(
        "Ablation A3: Eq. 6 regularizer strength",
        &["lambda", "best_accuracy", "best_loglik"],
        &rows,
    );
    write_csv("ablation_reg.csv", &["lambda", "best_accuracy", "best_loglik"], &rows)?;
    Ok(out)
}
