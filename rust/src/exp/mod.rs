//! Experiment harness: regenerates every table and figure of the paper
//! (see DESIGN.md §5 for the experiment index E1-E7/A1-A3).

pub mod ablations;
pub mod appendix_a2;
pub mod figure1;
pub mod snr;
pub mod table1;
pub mod tree_quality;

use std::path::{Path, PathBuf};

/// Results directory (created on demand): `$REPRO_RESULTS` or `results/`.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("REPRO_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"));
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Render an aligned plain-text table (paper-style) to stdout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    println!("\n== {title} ==");
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Write rows as CSV under the results dir; returns the path.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> anyhow::Result<PathBuf> {
    let path = results_dir().join(name);
    write_csv_to(&path, header, rows)?;
    Ok(path)
}

/// Write rows as CSV to an explicit path.
pub fn write_csv_to(path: &Path, header: &[&str], rows: &[Vec<String>]) -> anyhow::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_written() {
        let dir = std::env::temp_dir().join("adv_softmax_exp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        write_csv_to(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_prints_without_panic() {
        print_table(
            "test",
            &["col1", "longer-column"],
            &[vec!["x".into(), "y".into()]],
        );
    }
}
