//! E4 — Appendix A.2: full softmax vs plain (uniform) negative sampling on
//! a small dataset where O(NCK) epochs are tractable.
//!
//! Paper's numbers on EURLex-4K: 33.6% (softmax) vs 26.4% (uniform NS).
//! The *shape* to reproduce: softmax beats uniform NS by a clear accuracy
//! margin at convergence.

use super::{print_table, write_csv};
use crate::config::{DatasetPreset, Method, RunConfig, SyntheticConfig};
use crate::data::Splits;
use crate::runtime::Registry;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct A2Opts {
    pub seconds_per_method: f64,
    pub max_steps: usize,
    pub seed: u64,
}

impl Default for A2Opts {
    fn default() -> Self {
        Self { seconds_per_method: 60.0, max_steps: 50_000, seed: 1 }
    }
}

pub struct A2Result {
    pub softmax_acc: f64,
    pub uniform_acc: f64,
    pub softmax_ll: f64,
    pub uniform_ll: f64,
}

pub fn run(registry: &Registry, opts: &A2Opts) -> Result<A2Result> {
    let syn = SyntheticConfig::preset(DatasetPreset::EurlexSim);
    let splits = Splits::synthetic(&syn);

    let mut results = Vec::new();
    for m in [Method::Softmax, Method::Uniform] {
        let mut cfg = RunConfig::new(DatasetPreset::EurlexSim, m);
        cfg.max_seconds = opts.seconds_per_method;
        cfg.max_steps = opts.max_steps;
        cfg.seed = opts.seed;
        eprintln!("[appendix-a2] {} ...", m);
        let mut run = crate::train::TrainRun::prepare(registry, &splits, &cfg)?;
        let curve = run.train()?;
        results.push((m, curve.best_accuracy(), curve.best_log_likelihood()));
    }

    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(m, acc, ll)| vec![m.to_string(), format!("{acc:.4}"), format!("{ll:.4}")])
        .collect();
    print_table(
        "Appendix A.2: softmax vs uniform negative sampling (eurlex-sim)",
        &["method", "best_accuracy", "best_loglik"],
        &rows,
    );
    write_csv("appendix_a2.csv", &["method", "best_accuracy", "best_loglik"], &rows)?;

    Ok(A2Result {
        softmax_acc: results[0].1,
        uniform_acc: results[1].1,
        softmax_ll: results[0].2,
        uniform_ll: results[1].2,
    })
}
