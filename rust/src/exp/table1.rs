//! E1 — Table 1: dataset sizes and tuned hyperparameters.

use super::{print_table, write_csv};
use crate::config::{tuned_hyper, DatasetPreset, Method, SyntheticConfig};
use crate::data::Splits;

/// Regenerate Table 1 for the simulated datasets. Returns the CSV rows.
pub fn run(presets: &[DatasetPreset]) -> anyhow::Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    for &p in presets {
        let cfg = SyntheticConfig::preset(p);
        let splits = Splits::synthetic(&cfg);
        let c_populated = splits.train.populated_classes();
        for m in Method::ALL_SAMPLING {
            let h = tuned_hyper(p, m);
            rows.push(vec![
                p.to_string(),
                splits.train.len().to_string(),
                cfg.num_classes.to_string(),
                c_populated.to_string(),
                cfg.feat_dim.to_string(),
                m.to_string(),
                format!("{}", h.lr),
                format!("{}", h.lambda),
            ]);
        }
    }
    let header = [
        "dataset", "N_train", "C", "C_populated", "K", "method", "rho(lr)", "lambda",
    ];
    print_table("Table 1: dataset sizes and tuned hyperparameters", &header, &rows);
    write_csv("table1.csv", &header, &rows)?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table_has_all_methods() {
        std::env::set_var("REPRO_RESULTS", std::env::temp_dir().join("advsm_t1"));
        let rows = run(&[DatasetPreset::Tiny]).unwrap();
        assert_eq!(rows.len(), Method::ALL_SAMPLING.len());
        assert!(rows.iter().all(|r| r[0] == "tiny"));
    }
}
