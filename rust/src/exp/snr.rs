//! E5 — empirical validation of Theorem 2: the gradient signal-to-noise
//! ratio η̄ = 1/Tr[Cov(ĝ) H⁻¹] is maximal when p_n = p_D.
//!
//! Setup mirrors the theorem's nonparametric limit exactly: G discrete
//! contexts, C labels, scores ξ[g,y] treated directly as parameters, and
//! the optimum ξ* = ln(p_D/p_n) known in closed form (Eq. 11). We compare
//!
//! * the **analytic** η̄ from Eqs. 13-15:
//!     1/η̄ = N Σ_g (|Y| − 2 Σ_y α_{g,y}),  α = p_n p_D/(p_n + p_D);
//! * a **Monte-Carlo** η̄ that estimates Cov[ĝ] from sampled stochastic
//!   gradients at ξ* (what SGD actually sees) and evaluates the trace.
//!
//! over a family of noise distributions interpolating from uniform to the
//! true conditional: p_λ(y|g) ∝ p_D(y|g)^λ, plus the empirical marginal
//! (the word2vec-style frequency baseline). Theorem 2 predicts the maximum
//! at λ = 1 and that α caps at 1/2 per (g,y).

use super::{print_table, write_csv};
use crate::utils::Rng;
use anyhow::Result;

/// One noise distribution's measured SNR.
#[derive(Clone, Debug)]
pub struct SnrPoint {
    pub name: String,
    pub analytic: f64,
    pub monte_carlo: f64,
}

#[derive(Clone, Debug)]
pub struct SnrOpts {
    pub num_contexts: usize,
    pub num_classes: usize,
    /// Concentration of p_D (logit std); larger = peakier conditionals.
    pub temperature: f64,
    pub mc_samples: usize,
    pub seed: u64,
}

impl Default for SnrOpts {
    fn default() -> Self {
        Self {
            num_contexts: 8,
            num_classes: 16,
            temperature: 2.0,
            mc_samples: 200_000,
            seed: 1,
        }
    }
}

/// p_D(y|g) table, row-normalized, [G, C].
fn make_p_d(opts: &SnrOpts, rng: &mut Rng) -> Vec<f64> {
    let (g, c) = (opts.num_contexts, opts.num_classes);
    let mut p = vec![0f64; g * c];
    for row in p.chunks_exact_mut(c) {
        let mut z = 0f64;
        for v in row.iter_mut() {
            *v = (opts.temperature * rng.normal() as f64).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
    p
}

/// Analytic η̄ (Eq. 15 with N = 1): 1/η̄ = Σ_g (C − 2 Σ_y α_{g,y}).
pub fn analytic_snr(p_d: &[f64], p_n: &[f64], g: usize, c: usize) -> f64 {
    let mut inv = 0f64;
    for gi in 0..g {
        let mut asum = 0f64;
        for y in 0..c {
            let pd = p_d[gi * c + y];
            let pn = p_n[gi * c + y];
            if pd + pn > 0.0 {
                asum += pn * pd / (pn + pd);
            }
        }
        inv += c as f64 - 2.0 * asum;
    }
    1.0 / inv
}

/// Monte-Carlo η̄: sample stochastic gradients at the known optimum
/// ξ* = ln(p_D/p_n), estimate Cov[ĝ] (block-diagonal in g by Eq. 14,
/// estimated densely here as a check), and evaluate 1/Tr[Cov H⁻¹].
pub fn monte_carlo_snr(
    p_d: &[f64],
    p_n: &[f64],
    g: usize,
    c: usize,
    samples: usize,
    rng: &mut Rng,
) -> f64 {
    let dim = g * c;
    // ξ* and the Hessian diagonal α
    let mut alpha = vec![0f64; dim];
    let mut sig_pos = vec![0f64; dim]; // σ(-ξ*) = p_n/(p_n+p_D)
    let mut sig_neg = vec![0f64; dim]; // σ(ξ*)  = p_D/(p_n+p_D)
    for i in 0..dim {
        let (pd, pn) = (p_d[i], p_n[i]);
        alpha[i] = pn * pd / (pn + pd);
        sig_pos[i] = pn / (pn + pd);
        sig_neg[i] = pd / (pn + pd);
    }
    // cumulative tables for sampling y ~ p_D(|g), y' ~ p_n(|g)
    let cum = |p: &[f64]| -> Vec<f64> {
        let mut out = vec![0f64; dim];
        for gi in 0..g {
            let mut acc = 0.0;
            for y in 0..c {
                acc += p[gi * c + y];
                out[gi * c + y] = acc;
            }
        }
        out
    };
    let cd = cum(p_d);
    let cn = cum(p_n);
    let draw = |cumrow: &[f64], rng: &mut Rng| -> usize {
        let u = rng.next_f64();
        match cumrow.binary_search_by(|v| v.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(c - 1),
        }
    };

    // E[ĝ ĝᵀ]; at the optimum E[ĝ]=0 so this is Cov. The gradient of one
    // sample has only two nonzero components (Eq. A8, N=1):
    //   ĝ[g,y]  = −σ(−ξ*_{g,y}) ;  ĝ[g,y'] += σ(ξ*_{g,y'})
    let mut cov = vec![0f64; dim * dim];
    for _ in 0..samples {
        let gi = rng.below(g);
        let y = draw(&cd[gi * c..(gi + 1) * c], rng);
        let yp = draw(&cn[gi * c..(gi + 1) * c], rng);
        let iy = gi * c + y;
        let iyp = gi * c + yp;
        let mut gy = -sig_pos[iy];
        let gyp = sig_neg[iyp];
        if iy == iyp {
            gy += gyp;
            cov[iy * dim + iy] += gy * gy;
        } else {
            cov[iy * dim + iy] += gy * gy;
            cov[iyp * dim + iyp] += gyp * gyp;
            cov[iy * dim + iyp] += gy * gyp;
            cov[iyp * dim + iy] += gy * gyp;
        }
    }
    // Tr[Cov H^{-1}] = Σ_i Cov_ii / α_i ; context marginal is uniform so
    // the per-sample gradient already includes the 1/G factor vs Eq. A1 —
    // consistent across noise distributions, so relative η̄ is unaffected.
    let mut tr = 0f64;
    for i in 0..dim {
        tr += cov[i * dim + i] / (samples as f64) / alpha[i];
    }
    // analytic counterpart of this normalization: Tr/G relative to Eq. 15
    1.0 / (tr * g as f64)
}

/// Run the sweep. Returns points ordered as the table prints them.
pub fn run(opts: &SnrOpts) -> Result<Vec<SnrPoint>> {
    let (g, c) = (opts.num_contexts, opts.num_classes);
    let mut rng = Rng::new(opts.seed);
    let p_d = make_p_d(opts, &mut rng);

    // marginal p_D(y) replicated across contexts
    let mut marginal = vec![0f64; g * c];
    for y in 0..c {
        let m: f64 = crate::linalg::sum_f64((0..g).map(|gi| p_d[gi * c + y])) / g as f64;
        for gi in 0..g {
            marginal[gi * c + y] = m;
        }
    }

    let mut family: Vec<(String, Vec<f64>)> = vec![
        ("uniform (lambda=0)".into(), vec![1.0 / c as f64; g * c]),
        ("marginal-freq".into(), marginal),
    ];
    for lam in [0.25, 0.5, 0.75, 1.0] {
        let mut p = vec![0f64; g * c];
        for gi in 0..g {
            let mut z = 0f64;
            for y in 0..c {
                let v = p_d[gi * c + y].powf(lam);
                p[gi * c + y] = v;
                z += v;
            }
            for y in 0..c {
                p[gi * c + y] /= z;
            }
        }
        let name = if lam == 1.0 {
            "adversarial (p_n = p_D)".to_string()
        } else {
            format!("interp lambda={lam}")
        };
        family.push((name, p));
    }

    let mut points = Vec::new();
    for (name, p_n) in &family {
        let analytic = analytic_snr(&p_d, p_n, g, c);
        let mc = monte_carlo_snr(&p_d, p_n, g, c, opts.mc_samples, &mut rng);
        points.push(SnrPoint { name: name.clone(), analytic, monte_carlo: mc });
    }

    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.name.clone(),
                format!("{:.6}", p.analytic),
                format!("{:.6}", p.monte_carlo),
            ]
        })
        .collect();
    print_table(
        "Theorem 2: gradient SNR eta-bar vs noise distribution",
        &["noise p_n", "analytic", "monte-carlo"],
        &rows,
    );
    write_csv("snr.csv", &["noise", "analytic", "monte_carlo"], &rows)?;
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maximum_at_p_d() {
        let opts = SnrOpts { mc_samples: 20_000, ..Default::default() };
        let pts = run(&opts).unwrap();
        let best = pts
            .iter()
            .max_by(|a, b| a.analytic.total_cmp(&b.analytic))
            .unwrap();
        assert!(best.name.contains("adversarial"), "best was {}", best.name);
    }

    #[test]
    fn analytic_monotone_in_lambda() {
        let opts = SnrOpts { mc_samples: 1_000, ..Default::default() };
        let pts = run(&opts).unwrap();
        // entries 2..6 are lambda = 0.25, 0.5, 0.75, 1.0
        let lams: Vec<f64> = pts[2..6].iter().map(|p| p.analytic).collect();
        for w in lams.windows(2) {
            assert!(w[1] > w[0], "{lams:?}");
        }
        // uniform is worst of the family
        assert!(pts[0].analytic < lams[0]);
    }

    #[test]
    fn mc_matches_analytic() {
        let opts = SnrOpts { mc_samples: 400_000, seed: 3, ..Default::default() };
        let mut rng = Rng::new(opts.seed);
        let p_d = make_p_d(&opts, &mut rng);
        let (g, c) = (opts.num_contexts, opts.num_classes);
        let uni = vec![1.0 / c as f64; g * c];
        let a = analytic_snr(&p_d, &uni, g, c);
        let m = monte_carlo_snr(&p_d, &uni, g, c, opts.mc_samples, &mut rng);
        let rel = (a - m).abs() / a;
        assert!(rel < 0.1, "analytic {a} vs mc {m} (rel {rel})");
    }

    #[test]
    fn alpha_sum_capped_at_half() {
        // Jensen bound from the proof: Σ_y α ≤ 1/2 with equality iff p_n=p_D
        let opts = SnrOpts::default();
        let mut rng = Rng::new(9);
        let p_d = make_p_d(&opts, &mut rng);
        let (g, c) = (opts.num_contexts, opts.num_classes);
        for gi in 0..g {
            let asum: f64 = (0..c)
                .map(|y| {
                    let pd = p_d[gi * c + y];
                    pd * pd / (2.0 * pd)
                })
                .sum();
            assert!((asum - 0.5).abs() < 1e-12); // p_n = p_D attains 1/2
        }
        let uni = vec![1.0 / c as f64; g * c];
        for gi in 0..g {
            let asum: f64 = (0..c)
                .map(|y| {
                    let pd = p_d[gi * c + y];
                    let pn = uni[gi * c + y];
                    pn * pd / (pn + pd)
                })
                .sum();
            assert!(asum < 0.5);
        }
    }
}
