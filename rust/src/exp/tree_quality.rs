//! E7 — auxiliary-model quality: fit cost and held-out log-likelihood of
//! the tree vs the unconditional baselines (Sec. 3's claim that the tree
//! is a cheap but genuinely conditional approximation of p_D(y|x)).

use super::{print_table, write_csv};
use crate::config::{DatasetPreset, SyntheticConfig, TreeConfig};
use crate::data::Splits;
use crate::sampler::{AdversarialSampler, FrequencySampler, UniformSampler};
use crate::score::mean_noise_loglik;
use crate::utils::StopWatch;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct TreeQuality {
    pub fit_seconds: f64,
    pub tree_test_ll: f64,
    pub freq_test_ll: f64,
    pub uniform_test_ll: f64,
}

pub fn run(preset: DatasetPreset, aux_dim: usize, seed: u64) -> Result<TreeQuality> {
    let syn = SyntheticConfig::preset(preset);
    let splits = Splits::synthetic(&syn);
    let cfg = TreeConfig { aux_dim, ..Default::default() };

    let t0 = StopWatch::started();
    let (adv, stats) = AdversarialSampler::fit(&splits.train, &cfg, seed);
    let fit_seconds = t0.elapsed_secs();

    let freq = FrequencySampler::from_dataset(&splits.train, 1.0)?;
    let uni = UniformSampler::new(splits.train.num_classes);

    // per-class scoring routed through the shared scoring core
    let q = TreeQuality {
        fit_seconds,
        tree_test_ll: mean_noise_loglik(&adv, &splits.test),
        freq_test_ll: mean_noise_loglik(&freq, &splits.test),
        uniform_test_ll: mean_noise_loglik(&uni, &splits.test),
    };

    let rows = vec![
        vec!["adversarial-tree".into(), format!("{:.4}", q.tree_test_ll),
             format!("{fit_seconds:.2}s")],
        vec!["frequency".into(), format!("{:.4}", q.freq_test_ll), "~0".into()],
        vec!["uniform".into(), format!("{:.4}", q.uniform_test_ll), "0".into()],
    ];
    print_table(
        &format!(
            "Aux model quality on {preset} (k={aux_dim}, {} nodes, {} newton iters)",
            stats.nodes_fitted, stats.newton_iters_total
        ),
        &["noise model", "test mean log p_n(y|x)", "fit time"],
        &rows,
    );
    write_csv(
        &format!("tree_quality_{preset}.csv"),
        &["model", "test_loglik", "fit_seconds"],
        &rows,
    )?;
    Ok(q)
}
