//! E2/E3 — Figure 1: learning curves (predictive log-likelihood and
//! accuracy vs training wallclock) for the proposed method and the five
//! baselines.
//!
//! The paper's claim has a *shape*, not absolute numbers: the adversarial
//! method converges roughly an order of magnitude faster and tops the
//! accuracy panel; on the smaller dataset plain NS may edge out the final
//! log-likelihood (Sec. 5, Results). `summarize` extracts exactly those
//! statistics.

use super::{print_table, results_dir};
use crate::config::{DatasetPreset, Method, RunConfig, SyntheticConfig};
use crate::data::Splits;
use crate::runtime::Registry;
use crate::train::{LearningCurve, TrainRun};
use anyhow::Result;

/// Options for one Figure 1 panel-pair (one dataset, many methods).
#[derive(Clone, Debug)]
pub struct Figure1Opts {
    pub dataset: DatasetPreset,
    pub methods: Vec<Method>,
    /// Per-method training budget in seconds (excl. eval, incl. aux fit).
    pub seconds_per_method: f64,
    pub max_steps: usize,
    pub eval_points: usize,
    pub seed: u64,
}

impl Default for Figure1Opts {
    fn default() -> Self {
        Self {
            dataset: DatasetPreset::WikiSim,
            methods: Method::ALL_SAMPLING.to_vec(),
            seconds_per_method: 60.0,
            max_steps: 200_000,
            eval_points: 2048,
            seed: 1,
        }
    }
}

/// Run all methods on one dataset; returns the curves and writes
/// `results/figure1_<dataset>.csv`.
pub fn run(registry: &Registry, opts: &Figure1Opts) -> Result<Vec<LearningCurve>> {
    let syn = SyntheticConfig::preset(opts.dataset);
    let splits = Splits::synthetic(&syn);
    let csv = results_dir().join(format!("figure1_{}.csv", opts.dataset));
    std::fs::remove_file(&csv).ok();

    let mut curves = Vec::new();
    for &m in &opts.methods {
        let mut cfg = RunConfig::new(opts.dataset, m);
        cfg.max_seconds = opts.seconds_per_method;
        cfg.max_steps = opts.max_steps;
        cfg.eval_points = opts.eval_points;
        cfg.seed = opts.seed;
        eprintln!("[figure1] {} / {} ...", opts.dataset, m);
        let mut run = TrainRun::prepare(registry, &splits, &cfg)?;
        let curve = run.train()?;
        curve.append_csv(&csv)?;
        curves.push(curve);
    }
    summarize(&curves);
    Ok(curves)
}

/// Print the paper-shape summary: best metrics + time-to-accuracy.
pub fn summarize(curves: &[LearningCurve]) {
    // target = 80% of the best accuracy any method reached
    let best_acc = curves.iter().map(|c| c.best_accuracy()).fold(0.0, f64::max);
    let target = 0.8 * best_acc;
    let rows: Vec<Vec<String>> = curves
        .iter()
        .map(|c| {
            vec![
                c.method.to_string(),
                format!("{:.4}", c.best_accuracy()),
                format!("{:.4}", c.best_log_likelihood()),
                c.time_to_accuracy(target)
                    .map(|t| format!("{t:.1}s"))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.1}s", c.aux_fit_seconds),
                c.points
                    .last()
                    .map(|p| p.step.to_string())
                    .unwrap_or_default(),
            ]
        })
        .collect();
    print_table(
        &format!(
            "Figure 1 summary ({}) — time-to-acc target {:.3}",
            curves.first().map(|c| c.dataset.as_str()).unwrap_or("?"),
            target
        ),
        &["method", "best_acc", "best_loglik", "t_to_target", "aux_fit", "steps"],
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_opts_cover_all_sampling_methods() {
        let o = Figure1Opts::default();
        assert_eq!(o.methods.len(), 6);
        assert!(!o.methods.contains(&Method::Softmax));
    }
}
