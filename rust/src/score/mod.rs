//! Shared per-class scoring core.
//!
//! Before this module, the Eq. 5 prediction score
//! `ξ_y(x) + log p_n(y|x)` (Theorem 1) was assembled in three places: the
//! pure-rust reference evaluator's dense sweep, the chunked HLO
//! evaluator's correction-block plumbing, and the experiment harness via
//! the training run. [`Scorer`] is now the one canonical host-side
//! implementation: the dense ξ sweep runs through the tiled
//! [`crate::linalg::affine_dots_tile`] kernel and the correction through
//! the auxiliary sampler's batched activation sweep
//! ([`AdversarialSampler::log_prob_all_block_with`]), in exactly the
//! floating-point order the evaluator always used — so routing the eval
//! and serving paths through the scorer changes no output bit.
//!
//! The serving subsystem ([`crate::serve`]) builds on the same core:
//! [`Scorer::score_candidates_with`] re-ranks a tree-retrieved candidate
//! set with the identical per-score math (canonical [`crate::linalg::dot`]
//! order, root→leaf correction accumulation), so a beam-retrieved
//! candidate's score is bit-identical to the same label's score in the
//! exact O(C) sweep — the property that makes beam + re-rank reproduce
//! the exact oracle's ranking whenever the candidate set covers it.

use crate::data::Dataset;
use crate::linalg::{
    affine_dots_tile, affine_dots_tile_f16, affine_dots_tile_i8, dot, dot_f16, dot_i8,
};
use crate::model::ParamStore;
use crate::sampler::{AdversarialSampler, LpnBlockScratch, NoiseSampler};

/// Classifier row storage for the ξ sweep: full-precision rows, or a
/// quantized serving format decoded on the fly with f32 accumulation.
///
/// Determinism: every variant scores through the canonical [`dot`]
/// operation sequence (the quantized kernels decode inline, documented
/// bit-identical to dequantize-then-[`dot`] in `linalg`), so a candidate
/// re-rank and a dense sweep over the same storage agree bit for bit, and
/// results do not depend on worker count or batching. Quantization itself
/// (`f32 → f16` round-to-nearest-even, `f32 → i8` symmetric per-row
/// scale) happens once at model load, never per query.
#[derive(Clone, Copy)]
pub enum RowStore<'a> {
    /// Row-major `[C, K]` f32 rows (training params, f32 serving).
    F32(&'a [f32]),
    /// IEEE binary16 bit patterns, same layout, half the bytes.
    F16(&'a [u16]),
    /// Symmetric i8 rows with one f32 scale per row, a quarter the bytes.
    I8 { q: &'a [i8], scales: &'a [f32] },
}

impl RowStore<'_> {
    fn len(&self) -> usize {
        match self {
            RowStore::F32(w) => w.len(),
            RowStore::F16(w) => w.len(),
            RowStore::I8 { q, .. } => q.len(),
        }
    }
}

/// Reusable buffers for [`Scorer`] sweeps: the correction block (`m · C`
/// floats, grown once) plus the sampler's projection/activation scratch
/// and a projected-features row for candidate scoring.
#[derive(Default)]
pub struct ScoreScratch {
    lpn: Vec<f32>,
    lpn_blk: LpnBlockScratch,
    proj: Vec<f32>,
}

/// Canonical per-class scorer over a dense affine classifier
/// `ξ_y(x) = w_y·x + b_y`, optionally bias-corrected per Eq. 5 to
/// `ξ_y(x) + log p_n(y|x)`.
///
/// Borrows raw parameter slices so it serves both the live training
/// [`ParamStore`] ([`Scorer::from_params`]) and the optimizer-free
/// [`crate::serve::ServingModel`] snapshot.
pub struct Scorer<'a> {
    rows: RowStore<'a>,
    b: &'a [f32],
    pub num_classes: usize,
    pub feat_dim: usize,
    corrector: Option<&'a AdversarialSampler>,
}

impl<'a> Scorer<'a> {
    /// Scorer over raw row-major `[C, K]` f32 weights and `[C]` biases.
    /// `corrector = Some` applies the Eq. 5 correction to every score.
    pub fn new(
        w: &'a [f32],
        b: &'a [f32],
        feat_dim: usize,
        corrector: Option<&'a AdversarialSampler>,
    ) -> Self {
        Self::over_rows(RowStore::F32(w), b, feat_dim, corrector)
    }

    /// Scorer over any [`RowStore`] — the quantized-serving entry point.
    pub fn over_rows(
        rows: RowStore<'a>,
        b: &'a [f32],
        feat_dim: usize,
        corrector: Option<&'a AdversarialSampler>,
    ) -> Self {
        assert!(feat_dim > 0, "scorer needs a positive feature dim");
        assert_eq!(rows.len(), b.len() * feat_dim, "weight/bias shape mismatch");
        if let RowStore::I8 { scales, .. } = rows {
            assert_eq!(scales.len(), b.len(), "one i8 scale per row");
        }
        if let Some(adv) = corrector {
            assert_eq!(
                adv.tree.num_classes,
                b.len(),
                "corrector label space must match the classifier"
            );
            assert_eq!(
                adv.pca.input_dim, feat_dim,
                "corrector PCA input dim must match the classifier feature dim"
            );
        }
        Self { rows, b, num_classes: b.len(), feat_dim, corrector }
    }

    /// Scorer over a training parameter store.
    pub fn from_params(
        params: &'a ParamStore,
        corrector: Option<&'a AdversarialSampler>,
    ) -> Self {
        Self::new(&params.w, &params.b, params.feat_dim, corrector)
    }

    /// Does this scorer apply the Eq. 5 correction?
    pub fn is_corrected(&self) -> bool {
        self.corrector.is_some()
    }

    /// Fill `out[j * C..(j + 1) * C]` with the scores of all C classes for
    /// an `[m, K]` block of raw feature rows. The ξ sweep runs through the
    /// tiled [`affine_dots_tile`] kernel and the correction through the
    /// sampler's batched activation sweep, both documented bit-identical
    /// per row to their scalar forms — so results do not depend on how
    /// callers block rows. Callers looping over many rows should block at
    /// [`crate::tree::LANES`] to bound the correction scratch (`m·C`
    /// floats) like the eval sweeps do.
    pub fn score_block_with(
        &self,
        xs: &[f32],
        m: usize,
        out: &mut [f32],
        scratch: &mut ScoreScratch,
    ) {
        let c = self.num_classes;
        let k = self.feat_dim;
        debug_assert_eq!(xs.len(), m * k);
        debug_assert_eq!(out.len(), m * c);
        match self.rows {
            RowStore::F32(w) => affine_dots_tile(w, self.b, k, xs, m, out, c, 0),
            RowStore::F16(w) => affine_dots_tile_f16(w, self.b, k, xs, m, out, c, 0),
            RowStore::I8 { q, scales } => {
                affine_dots_tile_i8(q, scales, self.b, k, xs, m, out, c, 0)
            }
        }
        if let Some(adv) = self.corrector {
            if scratch.lpn.len() < m * c {
                scratch.lpn.resize(m * c, 0.0);
            }
            adv.log_prob_all_block_with(xs, m, &mut scratch.lpn[..m * c], &mut scratch.lpn_blk);
            for (s, l) in out.iter_mut().zip(scratch.lpn[..m * c].iter()) {
                *s += *l;
            }
        }
    }

    /// Scores of all C classes for one raw feature row (the m = 1 block).
    pub fn score_all_with(&self, x: &[f32], out: &mut [f32], scratch: &mut ScoreScratch) {
        self.score_block_with(x, 1, out, scratch);
    }

    /// Exact scores for an explicit candidate set (the serving re-rank):
    /// `out[i]` = score of `labels[i]` for raw feature row `x`. Each score
    /// is bit-identical to the same label's entry in a dense
    /// [`Scorer::score_block_with`] sweep — the ξ dot uses the canonical
    /// [`dot`] order [`affine_dots_tile`] uses per score, and the
    /// correction walks the tree root→leaf in the same accumulation order
    /// as the sweep's prefix pass ([`crate::tree::Tree::log_prob`] docs).
    pub fn score_candidates_with(
        &self,
        x: &[f32],
        labels: &[u32],
        out: &mut [f32],
        scratch: &mut ScoreScratch,
    ) {
        if let Some(adv) = self.corrector {
            let ka = adv.aux_dim();
            if scratch.proj.len() < ka {
                scratch.proj.resize(ka, 0.0);
            }
            adv.project(x, &mut scratch.proj[..ka]);
            self.score_candidates_projected(x, &scratch.proj[..ka], labels, out);
        } else {
            self.score_candidates_projected(x, &[], labels, out);
        }
    }

    /// [`Scorer::score_candidates_with`] with a caller-supplied projection
    /// of `x` into the corrector's aux space (`proj` is ignored when the
    /// scorer is uncorrected). The serving beam path projects once for the
    /// tree descent and reuses that projection here, instead of paying the
    /// O(aux_dim · K) PCA projection twice per query.
    pub fn score_candidates_projected(
        &self,
        x: &[f32],
        proj: &[f32],
        labels: &[u32],
        out: &mut [f32],
    ) {
        let k = self.feat_dim;
        debug_assert_eq!(x.len(), k);
        debug_assert_eq!(out.len(), labels.len());
        for (o, &y) in out.iter_mut().zip(labels.iter()) {
            let yu = y as usize;
            debug_assert!(yu < self.num_classes);
            let xi = match self.rows {
                RowStore::F32(w) => dot(&w[yu * k..(yu + 1) * k], x),
                RowStore::F16(w) => dot_f16(&w[yu * k..(yu + 1) * k], x),
                RowStore::I8 { q, scales } => dot_i8(&q[yu * k..(yu + 1) * k], scales[yu], x),
            };
            *o = xi + self.b[yu];
        }
        if let Some(adv) = self.corrector {
            debug_assert_eq!(proj.len(), adv.aux_dim());
            for (o, &y) in out.iter_mut().zip(labels.iter()) {
                *o += adv.tree.log_prob(proj, y);
            }
        }
    }
}

/// Streaming-free log-sum-exp of one dense score row, in the reference
/// evaluator's exact floating-point order (max fold, then the sum of
/// shifted exps in index order).
#[inline]
pub fn row_lse(scores: &[f32]) -> f32 {
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let se: f32 = crate::linalg::sum_f32(scores.iter().map(|s| (s - m).exp()));
    m + se.ln()
}

/// Argmax of one dense score row, in the reference evaluator's exact
/// semantics (ties resolve to the largest index, as `max_by` does).
#[inline]
pub fn row_argmax(scores: &[f32]) -> usize {
    (0..scores.len())
        .max_by(|&a, &b| scores[a].total_cmp(&scores[b]))
        .expect("argmax of an empty score row")
}

/// Insert `(y, s)` into `out`, kept sorted by (score desc, label asc) and
/// truncated to `k` entries. The tie-break makes top-k selection a pure
/// function of the score set — identical at any parallelism and for any
/// insertion order of distinct labels.
pub fn push_topk(out: &mut Vec<(u32, f32)>, k: usize, y: u32, s: f32) {
    if k == 0 {
        return;
    }
    if out.len() == k {
        let (wy, ws) = out[k - 1];
        if !(s > ws || (s == ws && y < wy)) {
            return;
        }
        out.pop();
    }
    let pos = out.partition_point(|&(py, ps)| ps > s || (ps == s && py < y));
    out.insert(pos, (y, s));
}

/// Deterministic top-k over a dense per-class score row: highest score
/// first, ties toward the smaller label id. O(C · k); k is tiny.
pub fn topk_from_scores(scores: &[f32], k: usize, out: &mut Vec<(u32, f32)>) {
    out.clear();
    for (y, &s) in scores.iter().enumerate() {
        push_topk(out, k, y as u32, s);
    }
}

/// [`topk_from_scores`] over sparse (label, score) pairs (the re-rank of a
/// retrieved candidate set). Same ordering semantics.
pub fn topk_from_pairs(
    pairs: impl Iterator<Item = (u32, f32)>,
    k: usize,
    out: &mut Vec<(u32, f32)>,
) {
    out.clear();
    for (y, s) in pairs {
        push_topk(out, k, y, s);
    }
}

/// Mean held-out log-likelihood of a noise model (one `log_prob` per
/// point). The experiment harness's aux-model quality table routes its
/// per-class scoring through here instead of open-coding the sweep.
pub fn mean_noise_loglik(sampler: &dyn NoiseSampler, data: &Dataset) -> f64 {
    let n = data.len();
    assert!(n > 0, "empty evaluation set");
    crate::linalg::sum_f64((0..n).map(|i| sampler.log_prob(data.x(i), data.y(i)) as f64))
        / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetPreset, SyntheticConfig, TreeConfig};
    use crate::data::Splits;
    use crate::utils::Rng;

    fn toy_params(c: usize, k: usize, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut p = ParamStore::zeros(c, k, 0.1);
        p.w.iter_mut().for_each(|v| *v = rng.normal());
        p.b.iter_mut().for_each(|v| *v = 0.1 * rng.normal());
        p
    }

    #[test]
    fn uncorrected_block_matches_naive_dots() {
        let (c, k, m) = (17, 9, 11);
        let p = toy_params(c, k, 1);
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let scorer = Scorer::from_params(&p, None);
        let mut out = vec![0f32; m * c];
        scorer.score_block_with(&xs, m, &mut out, &mut ScoreScratch::default());
        for j in 0..m {
            for y in 0..c {
                let expect =
                    dot(&p.w[y * k..(y + 1) * k], &xs[j * k..(j + 1) * k]) + p.b[y];
                assert_eq!(out[j * c + y].to_bits(), expect.to_bits(), "row {j} label {y}");
            }
        }
    }

    #[test]
    fn corrected_candidates_match_dense_sweep_bitwise() {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        cfg.n_test = 64;
        let splits = Splits::synthetic(&cfg);
        let tcfg = TreeConfig { aux_dim: 6, ..Default::default() };
        let (adv, _) = AdversarialSampler::fit(&splits.train, &tcfg, 5);
        let c = splits.train.num_classes;
        let k = splits.train.feat_dim;
        let p = toy_params(c, k, 3);
        let scorer = Scorer::from_params(&p, Some(&adv));
        let mut scratch = ScoreScratch::default();
        let mut dense = vec![0f32; c];
        let labels: Vec<u32> = (0..c as u32).step_by(7).collect();
        let mut sparse = vec![0f32; labels.len()];
        for i in 0..8 {
            let x = splits.test.x(i);
            scorer.score_all_with(x, &mut dense, &mut scratch);
            scorer.score_candidates_with(x, &labels, &mut sparse, &mut scratch);
            for (s, &y) in sparse.iter().zip(labels.iter()) {
                assert_eq!(
                    s.to_bits(),
                    dense[y as usize].to_bits(),
                    "row {i} label {y}"
                );
            }
        }
    }

    #[test]
    fn block_rows_are_batch_size_invariant() {
        // scoring a row alone or inside a block must agree bit for bit —
        // the contract behind batched-vs-one-at-a-time serving parity
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        cfg.n_test = 40;
        let splits = Splits::synthetic(&cfg);
        let tcfg = TreeConfig { aux_dim: 6, ..Default::default() };
        let (adv, _) = AdversarialSampler::fit(&splits.train, &tcfg, 5);
        let c = splits.train.num_classes;
        let k = splits.train.feat_dim;
        let p = toy_params(c, k, 4);
        let scorer = Scorer::from_params(&p, Some(&adv));
        let mut scratch = ScoreScratch::default();
        let m = 11; // ragged vs the 8-wide tile
        let xs = &splits.test.features[..m * k];
        let mut block = vec![0f32; m * c];
        scorer.score_block_with(xs, m, &mut block, &mut scratch);
        let mut single = vec![0f32; c];
        for j in 0..m {
            scorer.score_all_with(&xs[j * k..(j + 1) * k], &mut single, &mut scratch);
            for y in 0..c {
                assert_eq!(
                    single[y].to_bits(),
                    block[j * c + y].to_bits(),
                    "row {j} label {y}"
                );
            }
        }
    }

    /// The pinned quantize-then-score oracle: scoring through a quantized
    /// [`RowStore`] must equal quantize → dequantize to f32 → score with
    /// the full-precision path, bit for bit, for both storage formats and
    /// both the dense sweep and the candidate re-rank. This is the whole
    /// quantized-serving determinism contract in one test.
    #[test]
    fn quantized_scoring_matches_dequantize_then_score_bitwise() {
        use crate::linalg::{f16_from_f32, f16_to_f32, quantize_row_i8};
        let (c, k, m) = (33, 13, 11); // ragged vs tiles and dot chunks
        let p = toy_params(c, k, 7);
        let mut rng = Rng::new(8);
        let xs: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        // f16 storage and its dequantized f32 oracle
        let w16: Vec<u16> = p.w.iter().map(|&v| f16_from_f32(v)).collect();
        let w16_deq: Vec<f32> = w16.iter().map(|&h| f16_to_f32(h)).collect();
        // i8 storage and its dequantized f32 oracle
        let mut q8 = vec![0i8; c * k];
        let mut scales = vec![0f32; c];
        for y in 0..c {
            scales[y] = quantize_row_i8(&p.w[y * k..(y + 1) * k], &mut q8[y * k..(y + 1) * k]);
        }
        let q8_deq: Vec<f32> =
            q8.iter().enumerate().map(|(t, &q)| q as f32 * scales[t / k]).collect();
        let cases: [(RowStore, &[f32]); 2] = [
            (RowStore::F16(&w16), &w16_deq),
            (RowStore::I8 { q: &q8, scales: &scales }, &q8_deq),
        ];
        let labels: Vec<u32> = (0..c as u32).step_by(3).collect();
        for (rows, deq) in cases {
            let quant = Scorer::over_rows(rows, &p.b, k, None);
            let oracle = Scorer::new(deq, &p.b, k, None);
            let mut got = vec![0f32; m * c];
            let mut want = vec![0f32; m * c];
            quant.score_block_with(&xs, m, &mut got, &mut ScoreScratch::default());
            oracle.score_block_with(&xs, m, &mut want, &mut ScoreScratch::default());
            for t in 0..m * c {
                assert_eq!(got[t].to_bits(), want[t].to_bits(), "sweep entry {t}");
            }
            // candidate re-rank agrees with the dense sweep's entries
            let mut sparse = vec![0f32; labels.len()];
            quant.score_candidates_projected(&xs[..k], &[], &labels, &mut sparse);
            for (s, &y) in sparse.iter().zip(labels.iter()) {
                assert_eq!(s.to_bits(), got[y as usize].to_bits(), "label {y}");
            }
        }
    }

    /// Quantization error is bounded: f16 scores stay close to f32 scores
    /// on unit-scale rows (relative f16 step is 2⁻¹¹ per element).
    #[test]
    fn f16_scores_stay_close_to_f32() {
        use crate::linalg::f16_from_f32;
        let (c, k) = (64, 32);
        let p = toy_params(c, k, 11);
        let mut rng = Rng::new(12);
        let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
        let w16: Vec<u16> = p.w.iter().map(|&v| f16_from_f32(v)).collect();
        let exact = Scorer::new(&p.w, &p.b, k, None);
        let quant = Scorer::over_rows(RowStore::F16(&w16), &p.b, k, None);
        let (mut se, mut sq) = (vec![0f32; c], vec![0f32; c]);
        exact.score_all_with(&x, &mut se, &mut ScoreScratch::default());
        quant.score_all_with(&x, &mut sq, &mut ScoreScratch::default());
        for y in 0..c {
            assert!(
                (se[y] - sq[y]).abs() < 0.05,
                "label {y}: f32 {} vs f16 {}",
                se[y],
                sq[y]
            );
        }
    }

    #[test]
    fn topk_orders_and_breaks_ties_deterministically() {
        let scores = [1.0f32, 3.0, 3.0, -1.0, 2.0];
        let mut out = Vec::new();
        topk_from_scores(&scores, 3, &mut out);
        assert_eq!(out, vec![(1, 3.0), (2, 3.0), (4, 2.0)]);
        // pair form with a different insertion order picks the same set
        let mut out2 = Vec::new();
        topk_from_pairs(
            [(4u32, 2.0f32), (2, 3.0), (0, 1.0), (1, 3.0), (3, -1.0)].into_iter(),
            3,
            &mut out2,
        );
        assert_eq!(out, out2);
        // k larger than the candidate set returns everything, sorted
        let mut all = Vec::new();
        topk_from_scores(&scores, 10, &mut all);
        assert_eq!(all.len(), 5);
        assert_eq!(all[0], (1, 3.0));
        assert_eq!(all[4], (3, -1.0));
    }

    #[test]
    fn row_reductions_match_naive() {
        let mut rng = Rng::new(9);
        let scores: Vec<f32> = (0..33).map(|_| 3.0 * rng.normal()).collect();
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let se: f32 = scores.iter().map(|s| (s - m).exp()).sum();
        assert_eq!(row_lse(&scores).to_bits(), (m + se.ln()).to_bits());
        let am = row_argmax(&scores);
        assert!(scores.iter().all(|&s| s <= scores[am]));
    }

    #[test]
    fn mean_noise_loglik_matches_manual_loop() {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        let splits = Splits::synthetic(&cfg);
        let s = crate::sampler::UniformSampler::new(splits.train.num_classes);
        let got = mean_noise_loglik(&s, &splits.test);
        let expect = -(splits.train.num_classes as f64).ln();
        assert!((got - expect).abs() < 1e-6, "{got} vs {expect}");
    }
}
