//! `repro` — CLI for the adversarial-softmax reproduction.
//!
//! The leader entrypoint: loads the AOT artifacts once, then runs
//! training, evaluation, or any of the paper's experiments (DESIGN.md §5).
//!
//! ```text
//! repro data-stats   --dataset tiny
//! repro tree-fit     --dataset wiki-sim --aux-dim 16 [--save tree.json]
//!                    [--parallelism N]  (parallel PCA + level-sharded fit)
//! repro train        --dataset tiny --method adversarial --seconds 30
//!                    [--parallelism N]  (0 = auto; curves are identical
//!                    at every setting, only wallclock changes)
//!                    [--overlap auto|on|off|pipeline]  (step engine
//!                    depth: double-buffered or the three-deep execute
//!                    pipeline; curves identical at every setting)
//!                    [--timing]  (one-line per-stage wall-time report:
//!                    gather/pack/execute/readback/scatter + occupancy)
//!                    [--save-model model.json]  (serving checkpoint:
//!                    classifier rows + aux tree, no optimizer state)
//! repro serve        --model model.json (--input queries.txt | --eval
//!                    --dataset tiny) [--k 5] [--beam 64] [--exact]
//!                    [--quantize off|f16|i8] [--parallelism N]
//!                    [--out preds.txt]
//!                    (batched top-k: tree-guided beam retrieval + exact
//!                    re-rank; --exact runs the O(C) oracle sweep; --eval
//!                    reports P@1 / recall@k on the held-out test split;
//!                    --quantize — also via REPRO_QUANTIZE — stores the
//!                    classifier rows f16/i8 inside the predictor and
//!                    scores with f32 accumulation, bit-identical to
//!                    quantize-then-score at every worker count)
//! repro serve        --model model.json --daemon [--socket /path.sock]
//!                    [--deadline-ms 50] [--queue 1024] [--max-batch 64]
//!                    [--tiers 16,4] [--worker-timeout-ms 2000]
//!                    [--faults seed=7,panic=0.02,slow=0.05:3,malform=0.05]
//!                    (fault-tolerant long-lived loop over stdin/stdout,
//!                    or a Unix socket with --socket: bounded admission,
//!                    deadline-aware micro-batching, beam degradation
//!                    under overload, supervised predict workers; the
//!                    fault plan — also via REPRO_FAULTS — injects
//!                    reproducible worker panics / slow stages / malformed
//!                    requests for chaos testing)
//! repro predict      --model model.json --input queries.txt [--k 5]
//!                    [--beam 64] [--exact] [--quantize off|f16|i8]
//!                    [--parallelism N]
//!                    (one-at-a-time submission through the request
//!                    batcher; results bit-identical to one big batch)
//! repro coord        [--socket /path.sock] [--clients 2] [--rounds 8]
//!                    [--batches-per-round 8] [--batch 64] [--classes 256]
//!                    [--feat-dim 32] [--lr 0.05] [--seed 1]
//!                    [--lease-ms 1000] [--resend-ms 200]
//!                    [--faults seed=7,drop=0.05,delay=0.05:3,dup=0.03,corrupt=0.02]
//!                    (distributed training rounds: waits for --clients
//!                    workers, assigns each round's batch seqs, applies
//!                    update sets at Witness in seq order — final params
//!                    are bit-identical for any worker count, kill/rejoin
//!                    included; the fault plan — also via REPRO_FAULTS —
//!                    gates inbound frames for chaos testing)
//! repro worker       --connect /path.sock [--name w0]
//!                    [--heartbeat-ms 250] [--resend-ms 200]
//!                    (one training client: joins, mirrors the parameter
//!                    snapshot, computes assigned batches, resends until
//!                    acked; rejoins through Warmup after a lease loss)
//! repro exp table1
//! repro exp figure1  --dataset wiki-sim --seconds 60 [--methods adv,uniform]
//! repro exp appendix-a2 --seconds 60
//! repro exp snr      --mc-samples 200000
//! repro exp tree-quality --dataset wiki-sim
//! repro exp ablation-bias|ablation-k|ablation-reg --dataset tiny
//! ```
//!
//! Query files for serve/predict hold one query per line: `feat_dim`
//! whitespace-separated floats (blank lines skipped). Predictions print
//! one line per query: `label:score` pairs, best first.
//!
//! # Daemon line protocol
//!
//! One request per line (same float format as query files); blank lines
//! are ignored and the line `shutdown` drains the queue and exits. Every
//! request gets exactly one response line, tagged with the client's
//! 0-based request index:
//!
//! ```text
//! <idx> ok <label:score> ...            served at the full beam
//! <idx> degraded beam=<B> <label:score> ...
//!                                       served under overload at reduced
//!                                       beam B (bit-exact for that B)
//! <idx> rejected <queue-full|deadline>  load-shed at admission, or
//!                                       cancelled past its latency budget
//! <idx> error <message>                 malformed request / worker crash
//! ```
//!
//! # Distributed round protocol (coord/worker)
//!
//! One frame per line, every frame prefixed with the protocol version
//! `dist1`; float payloads travel as fixed-width hex bit patterns so
//! parameters survive the wire bit-exactly (see `dist::protocol`).
//! Malformed or misaddressed frames are answered with a typed error
//! frame, `dist1 error tag=<tag> detail=...`, where `<tag>` is one of:
//!
//! ```text
//! bad-version     version token is not dist1
//! bad-frame       unknown frame type / wrong structure / bad payload
//! bad-field       a field is missing or fails to parse
//! bad-length      a vector payload disagrees with its declared count
//! stale-round     frame addresses an already-committed round
//! unknown-client  sender's lease expired (or id never issued) — rejoin
//! ```

use adv_softmax::config::{
    DaemonConfig, DatasetPreset, DistConfig, Method, RunConfig, ServeConfig, SyntheticConfig,
};
use adv_softmax::data::Splits;
use adv_softmax::dist;
use adv_softmax::exp;
use adv_softmax::runtime::Registry;
use adv_softmax::sampler::AdversarialSampler;
use adv_softmax::serve::daemon::{self, Daemon, RealClock};
use adv_softmax::serve::{evaluate_serving, Predictor, RequestBatcher, ServingModel, TopK};
use adv_softmax::train::TrainRun;
use adv_softmax::utils::cli::Args;
use adv_softmax::utils::faults::FaultPlan;
use adv_softmax::utils::{Pool, StopWatch};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const USAGE: &str =
    "usage: repro <data-stats|tree-fit|train|serve|predict|coord|worker|exp> [options]
  global: --artifacts <dir>
  run `repro help` for the full command list (also in rust/src/main.rs)";

fn open_registry(args: &Args) -> Result<Registry> {
    match args.get_opt::<PathBuf>("artifacts")? {
        Some(dir) => Registry::open(&dir),
        None => Registry::open_default(),
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    match args.pos(0) {
        Some("data-stats") => data_stats(&args),
        Some("tree-fit") => tree_fit(&args),
        Some("train") => train(&args),
        Some("serve") => serve(&args),
        Some("predict") => predict(&args),
        Some("coord") => coord(&args),
        Some("worker") => worker(&args),
        Some("exp") => run_exp(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn data_stats(args: &Args) -> Result<()> {
    let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
    args.finish()?;
    let syn = SyntheticConfig::preset(dataset);
    let splits = Splits::synthetic(&syn);
    let counts = splits.train.label_counts();
    let max_c = counts.iter().max().copied().unwrap_or(0);
    println!("dataset          : {dataset}");
    println!(
        "train/valid/test : {} / {} / {}",
        splits.train.len(),
        splits.valid.len(),
        splits.test.len()
    );
    println!("feat dim K       : {}", splits.train.feat_dim);
    println!("classes C        : {}", splits.train.num_classes);
    println!("populated classes: {}", splits.train.populated_classes());
    println!("max label count  : {max_c}");
    Ok(())
}

fn tree_fit(args: &Args) -> Result<()> {
    let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
    let aux_dim: usize = args.get("aux-dim", 16)?;
    let seed: u64 = args.get("seed", 1)?;
    let parallelism: usize = args.get("parallelism", 0)?;
    let save: Option<PathBuf> = args.get_opt("save")?;
    args.finish()?;

    let syn = SyntheticConfig::preset(dataset);
    let splits = Splits::synthetic(&syn);
    let cfg = adv_softmax::config::TreeConfig { aux_dim, ..Default::default() };
    cfg.validate()?;
    let pool = Pool::from_parallelism(parallelism);
    let t0 = StopWatch::started();
    let (adv, stats) = AdversarialSampler::fit_with(&splits.train, &cfg, seed, &pool);
    println!(
        "fitted {} nodes in {:.2}s over {} workers ({} newton iters, {} alternations, {} forced)",
        stats.nodes_fitted,
        t0.elapsed_secs(),
        pool.num_workers(),
        stats.newton_iters_total,
        stats.alternations_total,
        stats.forced_nodes,
    );
    let levels: Vec<String> =
        stats.level_seconds.iter().map(|s| format!("{s:.3}")).collect();
    println!("per-level fit seconds   : [{}]", levels.join(", "));
    println!("train mean log p_n(y|x): {:.4}", stats.train_mean_loglik);
    println!(
        "uniform baseline        : {:.4}",
        -(splits.train.num_classes as f64).ln()
    );
    if let Some(path) = save {
        adv.save(&path)?;
        println!("saved sampler to {path:?}");
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let registry = open_registry(args)?;
    let cfg = match args.get_opt::<PathBuf>("config")? {
        Some(p) => RunConfig::load(&p)?,
        None => {
            let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
            let method: Method = args.get("method", Method::Adversarial)?;
            let mut c = RunConfig::new(dataset, method);
            c.max_seconds = args.get("seconds", 30.0)?;
            c.max_steps = args.get("max-steps", 100_000)?;
            c.seed = args.get("seed", 1)?;
            c.eval_points = args.get("eval-points", 2048)?;
            c.pipelined = !args.flag("no-pipeline")?;
            c.parallelism = args.get("parallelism", 0)?;
            c.overlap = args.get("overlap", c.overlap)?;
            c
        }
    };
    let out: Option<PathBuf> = args.get_opt("out")?;
    let save_model: Option<PathBuf> = args.get_opt("save-model")?;
    let timing = args.flag("timing")?;
    args.finish()?;

    let splits = Splits::synthetic(&SyntheticConfig::preset(cfg.dataset));
    let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
    let curve = run.train()?;
    if timing {
        println!("{}", run.engine().times().report());
    }
    println!("step      wall_s   train_loss   test_loglik   test_acc");
    for p in &curve.points {
        println!(
            "{:>8} {:>8.1} {:>12.4} {:>13.4} {:>10.4}",
            p.step, p.wall_s, p.train_loss, p.log_likelihood, p.accuracy
        );
    }
    if let Some(path) = out {
        curve.append_csv(&path)?;
        println!("curve appended to {path:?}");
    }
    if let Some(path) = save_model {
        run.serving_model().save(&path)?;
        println!("serving model saved to {path:?}");
    }
    Ok(())
}

/// Parse a serve/predict query file: one query per line, `feat_dim`
/// whitespace-separated floats; blank lines are skipped.
fn read_queries(path: &Path, feat_dim: usize) -> Result<(Vec<f32>, usize)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut xs = Vec::new();
    let mut m = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let before = xs.len();
        for tok in line.split_whitespace() {
            let v: f32 = tok
                .parse()
                .map_err(|e| anyhow::anyhow!("line {}: {tok:?}: {e}", lineno + 1))?;
            xs.push(v);
        }
        anyhow::ensure!(
            xs.len() - before == feat_dim,
            "line {}: {} features, model expects {}",
            lineno + 1,
            xs.len() - before,
            feat_dim
        );
        m += 1;
    }
    anyhow::ensure!(m > 0, "no queries in {path:?}");
    Ok((xs, m))
}

fn format_topk(t: &TopK) -> String {
    t.labels
        .iter()
        .zip(t.scores.iter())
        .map(|(y, s)| format!("{y}:{s:.4}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn serve_config_from(args: &Args) -> Result<ServeConfig> {
    let defaults = ServeConfig::default();
    let cfg = ServeConfig {
        beam: args.get("beam", defaults.beam)?,
        k: args.get("k", defaults.k)?,
        exact: args.flag("exact")?,
        quantize: args.get("quantize", defaults.quantize)?,
    };
    cfg.validate()?;
    Ok(cfg)
}

fn serve(args: &Args) -> Result<()> {
    let model_path: PathBuf = args.require("model")?;
    let cfg = serve_config_from(args)?;
    let parallelism: usize = args.get("parallelism", 0)?;
    if args.flag("daemon")? {
        return serve_daemon(args, &model_path, cfg, parallelism);
    }
    let input: Option<PathBuf> = args.get_opt("input")?;
    let do_eval = args.flag("eval")?;
    let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
    let out: Option<PathBuf> = args.get_opt("out")?;
    args.finish()?;
    anyhow::ensure!(
        do_eval || input.is_some(),
        "serve needs --input <queries.txt> and/or --eval (or --daemon)"
    );

    let model = ServingModel::load(&model_path)?;
    let pred = Predictor::new(&model, cfg)?;
    let pool = Pool::from_parallelism(parallelism);
    println!(
        "model: C={} K={} aux={} correction={}  mode={}  k={}  quantize={}",
        model.num_classes,
        model.feat_dim,
        model.aux.is_some(),
        model.correct_bias,
        if cfg.exact { "exact".to_string() } else { format!("beam={}", cfg.beam) },
        pred.k(),
        cfg.quantize,
    );

    if do_eval {
        let splits = Splits::synthetic(&SyntheticConfig::preset(dataset));
        anyhow::ensure!(
            splits.test.feat_dim == model.feat_dim,
            "dataset {dataset} has K={} but the model expects K={}",
            splits.test.feat_dim,
            model.feat_dim
        );
        let t0 = StopWatch::started();
        let metrics = evaluate_serving(&pred, &splits.test, &pool);
        let dt = t0.elapsed_secs();
        println!(
            "eval {dataset} ({} queries): P@1 {:.4}  recall@{} {:.4}  \
             ({:.0} queries/s over {} workers)",
            metrics.n,
            metrics.p_at_1,
            metrics.k,
            metrics.recall_at_k,
            metrics.n as f64 / dt.max(1e-9),
            pool.num_workers(),
        );
    }

    if let Some(path) = input {
        let (xs, m) = read_queries(&path, model.feat_dim)?;
        let t0 = StopWatch::started();
        let preds = pred.predict_batch_with(&xs, m, &pool);
        let dt = t0.elapsed_secs();
        let mut text = String::new();
        for t in &preds {
            text.push_str(&format_topk(t));
            text.push('\n');
        }
        match out {
            Some(p) => {
                std::fs::write(&p, &text)?;
                println!(
                    "{m} predictions written to {p:?} ({:.0} queries/s)",
                    m as f64 / dt.max(1e-9)
                );
            }
            None => print!("{text}"),
        }
    }
    Ok(())
}

/// `repro serve --daemon`: the fault-tolerant long-lived request loop
/// (see the module docs for the line protocol and `serve::daemon` for the
/// robustness contract). Banner and final stats go to stderr — stdout is
/// the response channel in stdin mode.
fn serve_daemon(
    args: &Args,
    model_path: &Path,
    cfg: ServeConfig,
    parallelism: usize,
) -> Result<()> {
    let d = DaemonConfig::default();
    let dcfg = DaemonConfig {
        queue_capacity: args.get("queue", d.queue_capacity)?,
        deadline_ms: args.get("deadline-ms", d.deadline_ms)?,
        max_batch: args.get("max-batch", d.max_batch)?,
        degrade_beams: match args.get_opt::<String>("tiers")? {
            Some(s) => s
                .split(',')
                .map(|t| t.trim().parse())
                .collect::<Result<_, _>>()
                .context("--tiers wants comma-separated beam widths, e.g. 16,4")?,
            None => d.degrade_beams,
        },
        overload_trip: d.overload_trip,
        worker_timeout_ms: args.get("worker-timeout-ms", d.worker_timeout_ms)?,
    };
    let faults = match args.get_opt::<String>("faults")? {
        Some(spec) => Some(FaultPlan::parse(&spec)?),
        None => FaultPlan::from_env()?,
    };
    let socket: Option<PathBuf> = args.get_opt("socket")?;
    args.finish()?;

    let model = Arc::new(ServingModel::load(model_path)?);
    eprintln!(
        "daemon: C={} K={} mode={} k={} queue={} deadline={}ms max-batch={} tiers={:?}",
        model.num_classes,
        model.feat_dim,
        if cfg.exact { "exact".to_string() } else { format!("beam={}", cfg.beam) },
        cfg.k,
        dcfg.queue_capacity,
        dcfg.deadline_ms,
        dcfg.max_batch,
        dcfg.degrade_beams,
    );
    if let Some(plan) = &faults {
        eprintln!("daemon: fault injection active ({})", plan.describe());
    }
    let mut daemon = Daemon::new(
        model,
        cfg,
        dcfg,
        parallelism,
        faults,
        Box::new(RealClock::new()),
    )?;
    let stats = match socket {
        Some(path) => {
            eprintln!("daemon: listening on {path:?} (send \"shutdown\" to stop)");
            daemon::run_socket_daemon(&mut daemon, &path)?
        }
        None => {
            eprintln!("daemon: reading stdin (EOF or \"shutdown\" to stop)");
            daemon::run_stdin_daemon(&mut daemon)?
        }
    };
    eprintln!("daemon: {}", stats.summary());
    Ok(())
}

fn predict(args: &Args) -> Result<()> {
    let model_path: PathBuf = args.require("model")?;
    let input: PathBuf = args.require("input")?;
    let cfg = serve_config_from(args)?;
    let parallelism: usize = args.get("parallelism", 0)?;
    args.finish()?;

    let model = ServingModel::load(&model_path)?;
    let pred = Predictor::new(&model, cfg)?;
    let pool = Pool::from_parallelism(parallelism);
    let (xs, m) = read_queries(&input, model.feat_dim)?;
    // one-at-a-time submission coalesced by the request batcher — results
    // are bit-identical to one big batch and come back in submission order
    let mut batcher = RequestBatcher::new(&pred);
    for j in 0..m {
        batcher.submit(&xs[j * model.feat_dim..(j + 1) * model.feat_dim]);
    }
    for t in batcher.flush_with(&pool) {
        println!("{}", format_topk(&t));
    }
    Ok(())
}

/// `repro coord`: serve distributed training rounds over a Unix socket.
/// Progress goes to stderr; the per-round learning curve and the final
/// parameter checksum (the cross-worker-count parity witness) to stdout.
fn coord(args: &Args) -> Result<()> {
    let d = DistConfig::default();
    let cfg = DistConfig {
        clients: args.get("clients", d.clients)?,
        rounds: args.get("rounds", d.rounds)?,
        batches_per_round: args.get("batches-per-round", d.batches_per_round)?,
        batch_size: args.get("batch", d.batch_size)?,
        num_classes: args.get("classes", d.num_classes)?,
        feat_dim: args.get("feat-dim", d.feat_dim)?,
        lr: args.get("lr", d.lr)?,
        seed: args.get("seed", d.seed)?,
        lease_ms: args.get("lease-ms", d.lease_ms)?,
        resend_ms: args.get("resend-ms", d.resend_ms)?,
    };
    cfg.validate()?;
    let faults = match args.get_opt::<String>("faults")? {
        Some(spec) => Some(FaultPlan::parse(&spec)?),
        None => FaultPlan::from_env()?,
    };
    let socket: PathBuf = args
        .get_opt("socket")?
        .unwrap_or_else(|| PathBuf::from("/tmp/repro-dist.sock"));
    args.finish()?;

    eprintln!(
        "coord: listening on {socket:?} — waiting for {} clients \
         ({} rounds x {} batches of {}, C={} K={} lr={} seed={})",
        cfg.clients,
        cfg.rounds,
        cfg.batches_per_round,
        cfg.batch_size,
        cfg.num_classes,
        cfg.feat_dim,
        cfg.lr,
        cfg.seed,
    );
    if let Some(plan) = &faults {
        eprintln!("coord: fault injection active ({})", plan.describe());
    }
    let coord = dist::run_coord_socket(&cfg, &socket, faults)?;
    println!("round       loss  applied  reassigned  evictions");
    for r in coord.round_stats() {
        println!(
            "{:>5} {:>10.6} {:>8} {:>11} {:>10}",
            r.round,
            r.loss(),
            r.applied,
            r.reassigned,
            r.evictions
        );
    }
    println!("params_checksum {:016x}", dist::params_checksum(coord.params()));
    eprintln!("coord: {}", coord.stats().summary());
    anyhow::ensure!(
        coord.round_stats().iter().all(|r| r.accounted()),
        "round accounting failed: some update was lost or double-applied"
    );
    Ok(())
}

/// `repro worker`: one training client against a coordinator socket.
fn worker(args: &Args) -> Result<()> {
    let socket: PathBuf = args.require("connect")?;
    let name: String = args.get("name", "w0".to_string())?;
    let heartbeat_ms: u64 = args.get("heartbeat-ms", 250)?;
    let resend_ms: u64 = args.get("resend-ms", 200)?;
    args.finish()?;
    let stats = dist::run_worker_socket(&socket, &name, heartbeat_ms, resend_ms)?;
    eprintln!(
        "worker {name}: computed={} resent={} acked={} applies={} resyncs={} rejoins={}",
        stats.computed, stats.resent, stats.acked, stats.applies, stats.resyncs, stats.rejoins,
    );
    Ok(())
}

fn run_exp(args: &Args) -> Result<()> {
    match args.pos(1) {
        Some("table1") => {
            args.finish()?;
            exp::table1::run(&[DatasetPreset::WikiSim, DatasetPreset::AmazonSim])?;
        }
        Some("figure1") => {
            let registry = open_registry(args)?;
            let dataset: DatasetPreset = args.get("dataset", DatasetPreset::WikiSim)?;
            let seconds: f64 = args.get("seconds", 60.0)?;
            let seed: u64 = args.get("seed", 1)?;
            let methods = match args.get_opt::<String>("methods")? {
                Some(s) => s
                    .split(',')
                    .map(|m| m.trim().parse())
                    .collect::<Result<Vec<Method>>>()?,
                None => Method::ALL_SAMPLING.to_vec(),
            };
            args.finish()?;
            let opts = exp::figure1::Figure1Opts {
                dataset,
                methods,
                seconds_per_method: seconds,
                seed,
                ..Default::default()
            };
            exp::figure1::run(&registry, &opts)?;
        }
        Some("appendix-a2") => {
            let registry = open_registry(args)?;
            let opts = exp::appendix_a2::A2Opts {
                seconds_per_method: args.get("seconds", 60.0)?,
                seed: args.get("seed", 1)?,
                ..Default::default()
            };
            args.finish()?;
            let r = exp::appendix_a2::run(&registry, &opts)?;
            println!(
                "\npaper (EURLex-4K): softmax 33.6% vs uniform-NS 26.4%; \
                 here: {:.1}% vs {:.1}%",
                100.0 * r.softmax_acc,
                100.0 * r.uniform_acc
            );
        }
        Some("snr") => {
            let opts = exp::snr::SnrOpts {
                mc_samples: args.get("mc-samples", 200_000)?,
                seed: args.get("seed", 1)?,
                ..Default::default()
            };
            args.finish()?;
            exp::snr::run(&opts)?;
        }
        Some("tree-quality") => {
            let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
            let aux_dim: usize = args.get("aux-dim", 16)?;
            let seed: u64 = args.get("seed", 1)?;
            args.finish()?;
            exp::tree_quality::run(dataset, aux_dim, seed)?;
        }
        Some("ablation-bias") => {
            let registry = open_registry(args)?;
            let opts = ablation_opts(args)?;
            args.finish()?;
            exp::ablations::bias_removal(&registry, &opts)?;
        }
        Some("ablation-k") => {
            let registry = open_registry(args)?;
            let opts = ablation_opts(args)?;
            let ks: Vec<usize> = args
                .get::<String>("ks", "2,4,8,16,32".into())?
                .split(',')
                .map(|s| s.trim().parse())
                .collect::<Result<_, _>>()?;
            args.finish()?;
            exp::ablations::aux_dim_sweep(&registry, &opts, &ks)?;
        }
        Some("ablation-reg") => {
            let registry = open_registry(args)?;
            let opts = ablation_opts(args)?;
            args.finish()?;
            exp::ablations::regularizer(&registry, &opts)?;
        }
        other => bail!("unknown experiment {other:?}\n{USAGE}"),
    }
    Ok(())
}

fn ablation_opts(args: &Args) -> Result<exp::ablations::AblationOpts> {
    Ok(exp::ablations::AblationOpts {
        dataset: args.get("dataset", DatasetPreset::Tiny)?,
        seconds: args.get("seconds", 30.0)?,
        max_steps: args.get("max-steps", 3_000)?,
        seed: args.get("seed", 1)?,
    })
}
