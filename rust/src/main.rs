//! `repro` — CLI for the adversarial-softmax reproduction.
//!
//! The leader entrypoint: loads the AOT artifacts once, then runs
//! training, evaluation, or any of the paper's experiments (DESIGN.md §5).
//!
//! ```text
//! repro data-stats   --dataset tiny
//! repro tree-fit     --dataset wiki-sim --aux-dim 16 [--save tree.json]
//!                    [--parallelism N]  (parallel PCA + level-sharded fit)
//! repro train        --dataset tiny --method adversarial --seconds 30
//!                    [--parallelism N]  (0 = auto; curves are identical
//!                    at every setting, only wallclock changes)
//!                    [--overlap auto|on|off]  (double-buffered step
//!                    engine; curves identical either way)
//! repro exp table1
//! repro exp figure1  --dataset wiki-sim --seconds 60 [--methods adv,uniform]
//! repro exp appendix-a2 --seconds 60
//! repro exp snr      --mc-samples 200000
//! repro exp tree-quality --dataset wiki-sim
//! repro exp ablation-bias|ablation-k|ablation-reg --dataset tiny
//! ```

use adv_softmax::config::{DatasetPreset, Method, RunConfig, SyntheticConfig};
use adv_softmax::data::Splits;
use adv_softmax::exp;
use adv_softmax::runtime::Registry;
use adv_softmax::sampler::AdversarialSampler;
use adv_softmax::train::TrainRun;
use adv_softmax::utils::cli::Args;
use adv_softmax::utils::Pool;
use anyhow::{bail, Result};
use std::path::PathBuf;

const USAGE: &str = "usage: repro <data-stats|tree-fit|train|exp> [options]
  global: --artifacts <dir>
  run `repro help` for the full command list (also in rust/src/main.rs)";

fn open_registry(args: &Args) -> Result<Registry> {
    match args.get_opt::<PathBuf>("artifacts")? {
        Some(dir) => Registry::open(&dir),
        None => Registry::open_default(),
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    match args.pos(0) {
        Some("data-stats") => data_stats(&args),
        Some("tree-fit") => tree_fit(&args),
        Some("train") => train(&args),
        Some("exp") => run_exp(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn data_stats(args: &Args) -> Result<()> {
    let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
    args.finish()?;
    let syn = SyntheticConfig::preset(dataset);
    let splits = Splits::synthetic(&syn);
    let counts = splits.train.label_counts();
    let max_c = counts.iter().max().copied().unwrap_or(0);
    println!("dataset          : {dataset}");
    println!(
        "train/valid/test : {} / {} / {}",
        splits.train.len(),
        splits.valid.len(),
        splits.test.len()
    );
    println!("feat dim K       : {}", splits.train.feat_dim);
    println!("classes C        : {}", splits.train.num_classes);
    println!("populated classes: {}", splits.train.populated_classes());
    println!("max label count  : {max_c}");
    Ok(())
}

fn tree_fit(args: &Args) -> Result<()> {
    let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
    let aux_dim: usize = args.get("aux-dim", 16)?;
    let seed: u64 = args.get("seed", 1)?;
    let parallelism: usize = args.get("parallelism", 0)?;
    let save: Option<PathBuf> = args.get_opt("save")?;
    args.finish()?;

    let syn = SyntheticConfig::preset(dataset);
    let splits = Splits::synthetic(&syn);
    let cfg = adv_softmax::config::TreeConfig { aux_dim, ..Default::default() };
    cfg.validate()?;
    let pool = Pool::from_parallelism(parallelism);
    let t0 = std::time::Instant::now();
    let (adv, stats) = AdversarialSampler::fit_with(&splits.train, &cfg, seed, &pool);
    println!(
        "fitted {} nodes in {:.2}s over {} workers ({} newton iters, {} alternations, {} forced)",
        stats.nodes_fitted,
        t0.elapsed().as_secs_f64(),
        pool.num_workers(),
        stats.newton_iters_total,
        stats.alternations_total,
        stats.forced_nodes,
    );
    let levels: Vec<String> =
        stats.level_seconds.iter().map(|s| format!("{s:.3}")).collect();
    println!("per-level fit seconds   : [{}]", levels.join(", "));
    println!("train mean log p_n(y|x): {:.4}", stats.train_mean_loglik);
    println!(
        "uniform baseline        : {:.4}",
        -(splits.train.num_classes as f64).ln()
    );
    if let Some(path) = save {
        adv.save(&path)?;
        println!("saved sampler to {path:?}");
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let registry = open_registry(args)?;
    let cfg = match args.get_opt::<PathBuf>("config")? {
        Some(p) => RunConfig::load(&p)?,
        None => {
            let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
            let method: Method = args.get("method", Method::Adversarial)?;
            let mut c = RunConfig::new(dataset, method);
            c.max_seconds = args.get("seconds", 30.0)?;
            c.max_steps = args.get("max-steps", 100_000)?;
            c.seed = args.get("seed", 1)?;
            c.eval_points = args.get("eval-points", 2048)?;
            c.pipelined = !args.flag("no-pipeline")?;
            c.parallelism = args.get("parallelism", 0)?;
            c.overlap = args.get("overlap", c.overlap)?;
            c
        }
    };
    let out: Option<PathBuf> = args.get_opt("out")?;
    args.finish()?;

    let splits = Splits::synthetic(&SyntheticConfig::preset(cfg.dataset));
    let mut run = TrainRun::prepare(&registry, &splits, &cfg)?;
    let curve = run.train()?;
    println!("step      wall_s   train_loss   test_loglik   test_acc");
    for p in &curve.points {
        println!(
            "{:>8} {:>8.1} {:>12.4} {:>13.4} {:>10.4}",
            p.step, p.wall_s, p.train_loss, p.log_likelihood, p.accuracy
        );
    }
    if let Some(path) = out {
        curve.append_csv(&path)?;
        println!("curve appended to {path:?}");
    }
    Ok(())
}

fn run_exp(args: &Args) -> Result<()> {
    match args.pos(1) {
        Some("table1") => {
            args.finish()?;
            exp::table1::run(&[DatasetPreset::WikiSim, DatasetPreset::AmazonSim])?;
        }
        Some("figure1") => {
            let registry = open_registry(args)?;
            let dataset: DatasetPreset = args.get("dataset", DatasetPreset::WikiSim)?;
            let seconds: f64 = args.get("seconds", 60.0)?;
            let seed: u64 = args.get("seed", 1)?;
            let methods = match args.get_opt::<String>("methods")? {
                Some(s) => s
                    .split(',')
                    .map(|m| m.trim().parse())
                    .collect::<Result<Vec<Method>>>()?,
                None => Method::ALL_SAMPLING.to_vec(),
            };
            args.finish()?;
            let opts = exp::figure1::Figure1Opts {
                dataset,
                methods,
                seconds_per_method: seconds,
                seed,
                ..Default::default()
            };
            exp::figure1::run(&registry, &opts)?;
        }
        Some("appendix-a2") => {
            let registry = open_registry(args)?;
            let opts = exp::appendix_a2::A2Opts {
                seconds_per_method: args.get("seconds", 60.0)?,
                seed: args.get("seed", 1)?,
                ..Default::default()
            };
            args.finish()?;
            let r = exp::appendix_a2::run(&registry, &opts)?;
            println!(
                "\npaper (EURLex-4K): softmax 33.6% vs uniform-NS 26.4%; \
                 here: {:.1}% vs {:.1}%",
                100.0 * r.softmax_acc,
                100.0 * r.uniform_acc
            );
        }
        Some("snr") => {
            let opts = exp::snr::SnrOpts {
                mc_samples: args.get("mc-samples", 200_000)?,
                seed: args.get("seed", 1)?,
                ..Default::default()
            };
            args.finish()?;
            exp::snr::run(&opts)?;
        }
        Some("tree-quality") => {
            let dataset: DatasetPreset = args.get("dataset", DatasetPreset::Tiny)?;
            let aux_dim: usize = args.get("aux-dim", 16)?;
            let seed: u64 = args.get("seed", 1)?;
            args.finish()?;
            exp::tree_quality::run(dataset, aux_dim, seed)?;
        }
        Some("ablation-bias") => {
            let registry = open_registry(args)?;
            let opts = ablation_opts(args)?;
            args.finish()?;
            exp::ablations::bias_removal(&registry, &opts)?;
        }
        Some("ablation-k") => {
            let registry = open_registry(args)?;
            let opts = ablation_opts(args)?;
            let ks: Vec<usize> = args
                .get::<String>("ks", "2,4,8,16,32".into())?
                .split(',')
                .map(|s| s.trim().parse())
                .collect::<Result<_, _>>()?;
            args.finish()?;
            exp::ablations::aux_dim_sweep(&registry, &opts, &ks)?;
        }
        Some("ablation-reg") => {
            let registry = open_registry(args)?;
            let opts = ablation_opts(args)?;
            args.finish()?;
            exp::ablations::regularizer(&registry, &opts)?;
        }
        other => bail!("unknown experiment {other:?}\n{USAGE}"),
    }
    Ok(())
}

fn ablation_opts(args: &Args) -> Result<exp::ablations::AblationOpts> {
    Ok(exp::ablations::AblationOpts {
        dataset: args.get("dataset", DatasetPreset::Tiny)?,
        seconds: args.get("seconds", 30.0)?,
        max_steps: args.get("max-steps", 3_000)?,
        seed: args.get("seed", 1)?,
    })
}
