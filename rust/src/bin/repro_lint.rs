//! repro-lint CLI: static determinism/safety audit over the source tree.
//!
//! Usage:
//!   repro_lint [--json] [PATH ...]
//!
//! With no PATH arguments, lints this crate's `src/` tree. Each PATH may be
//! a directory (walked recursively for `.rs` files; `target/`, `vendor/`,
//! `lint_fixtures/`, and `.git/` are skipped) or a single file.
//!
//! Output: one `file:line: [rule] message` diagnostic per violation, sorted,
//! followed by a summary line — or, with `--json`, a single JSON object
//! `{"files": N, "violations": [...], "clean": bool}` on stdout.
//!
//! Exit status: 0 when the tree is clean, 1 when violations were found,
//! 2 on usage or I/O errors.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use adv_softmax::lint::{lint_source, lint_tree, Diagnostic, LintConfig, RuleId};
use adv_softmax::utils::json::Json;

fn usage() -> ! {
    eprintln!("usage: repro_lint [--json] [PATH ...]");
    eprintln!("rules: {}", rule_names().join(", "));
    std::process::exit(2);
}

fn rule_names() -> Vec<&'static str> {
    RuleId::ALL.iter().map(|r| r.name()).collect()
}

fn main() -> ExitCode {
    let mut json_mode = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json_mode = true,
            "--help" | "-h" => usage(),
            other if other.starts_with('-') => {
                eprintln!("repro_lint: unknown flag {other:?}");
                usage();
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        paths.push(Path::new(env!("CARGO_MANIFEST_DIR")).join("src"));
    }

    let cfg = LintConfig::default();
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut files = 0usize;
    for path in &paths {
        if path.is_dir() {
            match lint_tree(path, &cfg) {
                Ok((d, n)) => {
                    diags.extend(d);
                    files += n;
                }
                Err(e) => {
                    eprintln!("repro_lint: {e:#}");
                    return ExitCode::from(2);
                }
            }
        } else {
            match std::fs::read_to_string(path) {
                Ok(source) => {
                    files += 1;
                    diags.extend(lint_source(&path.to_string_lossy(), &source, &cfg));
                }
                Err(e) => {
                    eprintln!("repro_lint: reading {}: {e}", path.display());
                    return ExitCode::from(2);
                }
            }
        }
    }
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));

    if json_mode {
        let out = Json::obj(vec![
            ("files", Json::Num(files as f64)),
            (
                "violations",
                Json::Arr(diags.iter().map(|d| d.to_json()).collect()),
            ),
            ("clean", Json::Bool(diags.is_empty())),
        ]);
        println!("{out}");
    } else {
        for d in &diags {
            println!("{d}");
        }
        if diags.is_empty() {
            println!("repro-lint: {files} files clean");
        } else {
            println!(
                "repro-lint: {} violation(s) in {files} file(s) scanned",
                diags.len()
            );
        }
    }
    if diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
