//! Datasets: container, synthetic extreme-classification generator, and a
//! loader for the Extreme Classification Repository sparse format (so real
//! Wikipedia-500K / Amazon-670K / EURLex data can drop in when available).

pub mod synthetic;
pub mod xc_format;

pub use synthetic::generate;

use crate::config::SyntheticConfig;
use crate::utils::Rng;

/// A dense single-label classification dataset.
///
/// Features are row-major `[n, feat_dim]` f32; one label per point (the
/// paper keeps only the first label of each multi-label point, Sec. 5).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
    pub feat_dim: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn new(features: Vec<f32>, labels: Vec<u32>, feat_dim: usize, num_classes: usize) -> Self {
        assert_eq!(features.len() % feat_dim, 0);
        assert_eq!(features.len() / feat_dim, labels.len());
        debug_assert!(labels.iter().all(|&l| (l as usize) < num_classes));
        Self { features, labels, feat_dim, num_classes }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Borrow the feature row of point `i`.
    #[inline]
    pub fn x(&self, i: usize) -> &[f32] {
        &self.features[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    /// Label of point `i`.
    #[inline]
    pub fn y(&self, i: usize) -> u32 {
        self.labels[i]
    }

    /// Empirical label counts (length `num_classes`).
    pub fn label_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.num_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }

    /// Number of labels that actually occur.
    pub fn populated_classes(&self) -> usize {
        self.label_counts().iter().filter(|&&c| c > 0).count()
    }

    /// Random subset of `n` points (without replacement if n <= len).
    pub fn subsample(&self, n: usize, rng: &mut Rng) -> Dataset {
        let n = n.min(self.len());
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(n);
        self.take(&idx)
    }

    /// Materialize the subset given by `idx`.
    pub fn take(&self, idx: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(idx.len() * self.feat_dim);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            features.extend_from_slice(self.x(i));
            labels.push(self.labels[i]);
        }
        Dataset::new(features, labels, self.feat_dim, self.num_classes)
    }
}

/// Train/validation/test triple.
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Dataset,
    pub valid: Dataset,
    pub test: Dataset,
}

impl Splits {
    /// Generate the synthetic splits for a preset config.
    pub fn synthetic(cfg: &SyntheticConfig) -> Splits {
        synthetic::generate(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![0, 2, 1],
            2,
            3,
        )
    }

    #[test]
    fn accessors() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.x(1), &[3.0, 4.0]);
        assert_eq!(d.y(2), 1);
    }

    #[test]
    fn label_counts_sum_to_n() {
        let d = tiny();
        let c = d.label_counts();
        assert_eq!(c.iter().sum::<u64>() as usize, d.len());
        assert_eq!(c, vec![1, 1, 1]);
        assert_eq!(d.populated_classes(), 3);
    }

    #[test]
    fn take_preserves_rows() {
        let d = tiny();
        let s = d.take(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.x(0), &[5.0, 6.0]);
        assert_eq!(s.y(1), 0);
    }

    #[test]
    fn subsample_bounds() {
        let d = tiny();
        let mut rng = Rng::new(1);
        assert_eq!(d.subsample(10, &mut rng).len(), 3);
        assert_eq!(d.subsample(2, &mut rng).len(), 2);
    }

    #[test]
    #[should_panic]
    fn mismatched_rows_panic() {
        Dataset::new(vec![1.0, 2.0, 3.0], vec![0], 2, 1);
    }
}
