//! Loader for the Extreme Classification Repository sparse format.
//!
//! Format (manikvarma.org XC repo):
//!
//! ```text
//! num_points num_features num_labels
//! l1,l2,... f1:v1 f2:v2 ...
//! ```
//!
//! Multi-label points are reduced to single-label by keeping the label with
//! the smallest id (the paper's preprocessing, Sec. 5 / Appendix A.2), and
//! points without labels are dropped. Sparse features are densified into a
//! fixed `feat_dim` via feature hashing (sign-hashed, as in Vowpal Wabbit)
//! so the AOT artifact shapes stay fixed regardless of the source
//! vocabulary. Rows are L2-normalized to keep scales comparable to the
//! synthetic generator.

use super::Dataset;
use anyhow::{bail, Context, Result};
use std::io::BufRead;
use std::path::Path;

/// Hash a source feature index to (bucket, sign).
#[inline]
fn hash_feature(idx: u64, feat_dim: usize) -> (usize, f32) {
    // splitmix64 finalizer as the hash
    let mut z = idx.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let bucket = (z % feat_dim as u64) as usize;
    let sign = if (z >> 63) == 0 { 1.0 } else { -1.0 };
    (bucket, sign)
}

/// Parse an XC-format reader into a dense single-label [`Dataset`].
pub fn parse_xc<R: BufRead>(reader: R, feat_dim: usize) -> Result<Dataset> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .context("xc file is empty (no header on line 1)")?
        .context("line 1: cannot read header")?;
    let mut hp = header.split_whitespace();
    let mut header_field = |name: &str| -> Result<usize> {
        let tok = hp
            .next()
            .with_context(|| format!("line 1: header missing {name} (want \"N F L\")"))?;
        tok.parse()
            .with_context(|| format!("line 1: header {name} {tok:?} is not a count"))
    };
    let n: usize = header_field("N")?;
    let _f: usize = header_field("F")?;
    let l: usize = header_field("L")?;
    if l == 0 {
        bail!("line 1: header declares zero labels");
    }

    let mut features = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut row = vec![0f32; feat_dim];

    // data lines are 1-based line 2 onward (the header is line 1)
    for (lineno, line) in lines.enumerate() {
        let line = line.with_context(|| format!("line {}: cannot read", lineno + 2))?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_field = parts.next().unwrap_or("");
        // keep the smallest label id (paper's "first label" after sorting);
        // tokens that don't parse as labels mean the field is actually a
        // feature (unlabeled line), but every id that *does* parse must be
        // in range — a silent out-of-range duplicate would mask corrupt
        // files (load error, never a downstream panic)
        let mut y: Option<u32> = None;
        for tok in label_field.split(',') {
            let v = match tok.parse::<u32>() {
                Ok(v) => v,
                // an all-digit token that overflows u32 is an out-of-range
                // id, not a feature field — reject it like any other
                // too-large label instead of silently skipping it
                Err(_) if !tok.is_empty() && tok.bytes().all(|b| b.is_ascii_digit()) => {
                    bail!("line {}: label {tok} out of range (L={})", lineno + 2, l)
                }
                Err(_) => continue,
            };
            if v as usize >= l {
                bail!("line {}: label {} out of range (L={})", lineno + 2, v, l);
            }
            y = Some(y.map_or(v, |m| m.min(v)));
        }
        let Some(y) = y else { continue }; // unlabeled -> drop

        row.iter_mut().for_each(|v| *v = 0.0);
        for tok in parts {
            let Some((f, v)) = tok.split_once(':') else {
                bail!("line {}: bad feature token {:?}", lineno + 2, tok);
            };
            let f: u64 = f.parse().with_context(|| {
                format!("line {}: feature index {f:?} is not an integer", lineno + 2)
            })?;
            let v: f32 = v.parse().with_context(|| {
                format!("line {}: feature value {v:?} is not a number", lineno + 2)
            })?;
            let (bucket, sign) = hash_feature(f, feat_dim);
            row[bucket] += sign * v;
        }
        let norm = crate::linalg::sum_f32(row.iter().map(|v| v * v)).sqrt();
        if norm > 0.0 {
            row.iter_mut().for_each(|v| *v /= norm);
        }
        features.extend_from_slice(&row);
        labels.push(y);
    }

    if labels.is_empty() {
        bail!("no labeled points in file (declared N={n})");
    }
    Ok(Dataset::new(features, labels, feat_dim, l))
}

/// Load an XC-format file from disk.
pub fn load_xc(path: &Path, feat_dim: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    parse_xc(std::io::BufReader::new(f), feat_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SAMPLE: &str = "4 100 10\n\
        3,1 0:1.5 7:2.0\n\
        5 1:1.0\n\
        \n\
        2,9,4 50:0.5 51:0.5 52:0.5\n";

    #[test]
    fn parses_and_keeps_smallest_label() {
        let d = parse_xc(Cursor::new(SAMPLE), 16).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.labels, vec![1, 5, 2]);
        assert_eq!(d.feat_dim, 16);
        assert_eq!(d.num_classes, 10);
    }

    #[test]
    fn rows_are_l2_normalized() {
        let d = parse_xc(Cursor::new(SAMPLE), 16).unwrap();
        for i in 0..d.len() {
            let n: f32 = d.x(i).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
        }
    }

    #[test]
    fn hashing_is_deterministic() {
        let a = parse_xc(Cursor::new(SAMPLE), 32).unwrap();
        let b = parse_xc(Cursor::new(SAMPLE), 32).unwrap();
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn drops_unlabeled_points() {
        let s = "2 10 5\n 0:1.0\n3 1:1.0\n";
        let d = parse_xc(Cursor::new(s), 8).unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d.labels, vec![3]);
    }

    #[test]
    fn rejects_label_out_of_range() {
        let s = "1 10 5\n7 0:1.0\n";
        assert!(parse_xc(Cursor::new(s), 8).is_err());
    }

    #[test]
    fn rejects_out_of_range_label_in_any_position() {
        // the smallest label is in range, but a later id is corrupt: must
        // be a load error, not a silently dropped token
        let s = "1 10 5\n2,99 0:1.0\n";
        let err = parse_xc(Cursor::new(s), 8).unwrap_err();
        assert!(err.to_string().contains("99"), "error names the bad id: {err}");
        // upper boundary: L itself is out of range, L-1 is fine
        assert!(parse_xc(Cursor::new("1 10 5\n5 0:1.0\n"), 8).is_err());
        assert!(parse_xc(Cursor::new("1 10 5\n4 0:1.0\n"), 8).is_ok());
        // an id too large for u32 must also be a load error, not a
        // silently skipped token
        let s = "1 10 5\n3,99999999999999999999 0:1.0\n";
        assert!(parse_xc(Cursor::new(s), 8).is_err());
    }

    #[test]
    fn duplicate_labels_in_one_example_collapse_to_one_point() {
        let s = "1 10 5\n3,3,3,1,3 0:1.0\n";
        let d = parse_xc(Cursor::new(s), 8).unwrap();
        assert_eq!(d.len(), 1, "one example, not one per duplicate");
        assert_eq!(d.labels, vec![1], "smallest id wins over duplicates");
    }

    #[test]
    fn tolerates_blank_lines_and_trailing_whitespace() {
        // interior blank line, trailing spaces/tabs, no final newline
        let s = "3 10 5\n\n2 0:1.0   \n\t\n1 1:2.0\t\n4 2:0.5";
        let d = parse_xc(Cursor::new(s), 8).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.labels, vec![2, 1, 4]);
        for i in 0..d.len() {
            let n: f32 = d.x(i).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
        }
    }

    #[test]
    fn file_of_only_blank_lines_is_a_load_error() {
        let s = "2 10 5\n\n   \n\t\n";
        assert!(parse_xc(Cursor::new(s), 8).is_err(), "no labeled points");
    }

    #[test]
    fn rejects_bad_token() {
        let s = "1 10 5\n1 zzz\n";
        assert!(parse_xc(Cursor::new(s), 8).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_xc(Cursor::new(""), 8).is_err());
        assert!(parse_xc(Cursor::new("0 10 5\n"), 8).is_err());
    }

    /// Chained anyhow context, innermost last — where the line number lives.
    fn err_chain(input: &str) -> String {
        format!("{:#}", parse_xc(Cursor::new(input), 8).unwrap_err())
    }

    #[test]
    fn every_parse_error_reports_a_one_based_line_number() {
        // header errors are all "line 1"
        assert!(err_chain("").contains("line 1"), "empty file: {}", err_chain(""));
        for (name, bad_header) in [("N", ""), ("F", "4"), ("L", "4 100")] {
            let s = format!("{bad_header}\nx");
            let msg = err_chain(&s);
            assert!(msg.contains("line 1"), "missing {name}: {msg}");
            assert!(msg.contains(name), "missing {name} named: {msg}");
        }
        for bad_header in ["x 100 10", "4 x 10", "4 100 x"] {
            let msg = err_chain(&format!("{bad_header}\n"));
            assert!(msg.contains("line 1"), "non-numeric header {bad_header:?}: {msg}");
            assert!(msg.contains("\"x\""), "offending token named: {msg}");
        }
        let msg = err_chain("4 100 0\n");
        assert!(msg.contains("line 1"), "zero labels: {msg}");

        // data-line errors name the 1-based physical line (header = line 1,
        // so the first data line is line 2)
        let cases = [
            // (input, expected line tag, expected token mention)
            ("1 10 5\n7 0:1.0\n", "line 2", "7"), // label out of range
            ("2 10 5\n1 0:1.0\n2,9 0:1.0\n", "line 3", "9"), // later line, later position
            ("1 10 5\n3,99999999999999999999 0:1.0\n", "line 2", "99999999999999999999"),
            ("1 10 5\n1 zzz\n", "line 2", "zzz"), // feature token without colon
            ("1 10 5\n1 x:1.0\n", "line 2", "\"x\""), // bad feature index
            ("1 10 5\n1 0:y\n", "line 2", "\"y\""), // bad feature value
        ];
        for (input, line_tag, token) in cases {
            let msg = err_chain(input);
            assert!(msg.contains(line_tag), "{input:?}: wrong line in {msg:?}");
            assert!(msg.contains(token), "{input:?}: token not named in {msg:?}");
        }
    }

    #[test]
    fn hash_buckets_cover_range() {
        let dim = 64;
        let mut seen = vec![false; dim];
        for f in 0..10_000u64 {
            let (b, s) = hash_feature(f, dim);
            assert!(b < dim);
            assert!(s == 1.0 || s == -1.0);
            seen[b] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
