//! Minimal JSON parser/writer (RFC 8259 subset sufficient for our files:
//! the AOT manifest, model checkpoints, run configs).
//!
//! In-tree because the build environment vendors only the `xla` crate's
//! dependency closure (no serde_json). Supports the full JSON value model;
//! numbers are f64 (exact for the f32 payloads we store and for integers
//! up to 2^53, far beyond any shape or count in this library).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------------
    // constructors / accessors
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn arr_u32(xs: &[u32]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "not a usize: {n}");
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "not a u64: {n}");
        Ok(n as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn to_vec_f32(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f32()).collect()
    }

    pub fn to_vec_u32(&self) -> Result<Vec<u32>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_u64()? as u32))
            .collect()
    }

    pub fn to_vec_usize(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ------------------------------------------------------------------
    // parsing
    // ------------------------------------------------------------------

    pub fn parse(s: &str) -> Result<Json> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(pos == bytes.len(), "trailing garbage at byte {pos}");
        Ok(v)
    }

    // ------------------------------------------------------------------
    // writing (via Display; `.to_string()` comes from the blanket impl)
    // ------------------------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    // shortest roundtrip repr rust provides
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "bad literal at byte {pos}"
    );
    *pos += lit.len();
    Ok(v)
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = s
        .parse()
        .with_context(|| format!("bad number {s:?} at byte {start}"))?;
    Ok(Json::Num(n))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    anyhow::ensure!(
        *pos < b.len() && b[*pos] == b'"',
        "expected string at byte {pos}"
    );
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "bad \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16).context("bad \\u hex")?;
                        // (surrogate pairs unsupported; our payloads are ASCII)
                        out.push(char::from_u32(cp).context("bad codepoint")?);
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().context("empty")?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            c => bail!("expected ',' or ']', got {:?}", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len() && b[*pos] == b':', "expected ':'");
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            c => bail!("expected ',' or '}}', got {:?}", c as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("c").unwrap(), &Json::Null);
        assert!(v.get("zzz").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = Json::obj(vec![
            ("nums", Json::arr_f32(&[1.5, -2.25, 0.0])),
            ("name", Json::Str("tree \"x\"\n".into())),
            ("n", Json::Num(42.0)),
            ("flag", Json::Bool(true)),
        ]);
        let text = src.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, src);
    }

    #[test]
    fn f32_payload_roundtrips_exactly() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 * 0.7).sin() * 1e3).collect();
        let text = Json::arr_f32(&xs).to_string();
        let back = Json::parse(&text).unwrap().to_vec_f32().unwrap();
        assert_eq!(back, xs);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
            "artifacts": {
                "ns_grad_B256_K64": {
                    "file": "ns_grad_B256_K64.hlo.txt",
                    "inputs": [{"shape": [256, 64], "dtype": "float32"}]
                }
            },
            "format": "hlo-text",
            "version": 1
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text");
        let arts = v.get("artifacts").unwrap().as_obj().unwrap();
        let a = &arts["ns_grad_B256_K64"];
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .to_vec_usize()
            .unwrap();
        assert_eq!(shape, vec![256, 64]);
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
