//! Deterministic fault injection shared by the serving daemon and the
//! distributed-training layer.
//!
//! Chaos testing is only useful when a failure reproduces: a fault plan is
//! a **pure function of (seed, stage, id)**, so the same plan over the
//! same event stream injects exactly the same faults no matter how the
//! process's threads interleave. Decisions are drawn from counter-based RNG
//! streams ([`crate::utils::Rng::stream`]) — the same keystone the
//! pipelined trainer uses for batch determinism — with one domain salt per
//! fault kind (mixed with an FNV hash of the stage name) so the decisions
//! for an event are independent across kinds and across stages.
//!
//! Fault kinds, matching the two consumers' failure surfaces:
//!
//! * **worker panic** — the daemon's predict worker panics while serving
//!   the batch that contains the poisoned request (supervision/respawn).
//! * **slow stage** — the predict worker sleeps before serving the batch
//!   (deadline cancellation, backpressure and degradation).
//! * **malformed request** — the request line is corrupted before parsing
//!   (the typed `error` response path).
//! * **drop / delay / duplicate / corrupt frame** — transport-level faults
//!   for the dist round protocol (`dist::`): a frame is dropped, held for
//!   `MS` milliseconds, delivered twice, or corrupted in flight
//!   (retransmission, lease expiry, duplicate suppression, typed frame
//!   errors).
//!
//! A plan comes from the `REPRO_FAULTS` environment variable (the CI chaos
//! jobs set it) or a `--faults` spec:
//!
//! ```text
//! seed=7,panic=0.02,slow=0.05:3,malform=0.05,drop=0.1,delay=0.05:4,dup=0.05,corrupt=0.02
//! ```
//!
//! `panic`/`malform`/`drop`/`dup`/`corrupt` are per-event probabilities;
//! `slow=RATE:MS` and `delay=RATE:MS` carry a duration. Omitted keys
//! default to zero (fault disabled), so the daemon's original spec syntax
//! parses unchanged and both subsystems can share one variable — each
//! reads only the kinds that apply to it. [`FaultPlan::describe`] emits
//! the canonical spec, so `parse ∘ describe` is the identity.

use crate::utils::Rng;
use anyhow::{bail, Context, Result};

/// Domain salts separating the per-kind decision streams.
const SALT_PANIC: u64 = 0x70_61_6e; // "pan"
const SALT_SLOW: u64 = 0x73_6c_6f; // "slo"
const SALT_MALFORM: u64 = 0x6d_61_6c; // "mal"
const SALT_DROP: u64 = 0x64_72_6f; // "dro"
const SALT_DELAY: u64 = 0x64_65_6c; // "del"
const SALT_DUP: u64 = 0x64_75_70; // "dup"
const SALT_CORRUPT: u64 = 0x63_6f_72; // "cor"

/// FNV-1a over the stage name: folds the stage into the stream domain so
/// the same event id draws independently at different pipeline stages.
fn stage_salt(stage: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in stage.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A seeded, reproducible fault-injection plan (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-request probability of panicking the predict worker.
    pub panic_rate: f64,
    /// Per-request probability of a slow stage.
    pub slow_rate: f64,
    /// Sleep injected when a slow stage fires (milliseconds).
    pub slow_ms: u64,
    /// Per-request probability of corrupting the request line.
    pub malform_rate: f64,
    /// Per-frame probability of dropping a dist frame in flight.
    pub drop_rate: f64,
    /// Per-frame probability of delaying a dist frame.
    pub delay_rate: f64,
    /// Hold applied when a delay fires (milliseconds).
    pub delay_ms: u64,
    /// Per-frame probability of delivering a dist frame twice.
    pub dup_rate: f64,
    /// Per-frame probability of corrupting a dist frame in flight.
    pub corrupt_rate: f64,
}

impl FaultPlan {
    /// A plan with every fault disabled (useful as a parse base).
    pub fn disabled(seed: u64) -> Self {
        Self {
            seed,
            panic_rate: 0.0,
            slow_rate: 0.0,
            slow_ms: 0,
            malform_rate: 0.0,
            drop_rate: 0.0,
            delay_rate: 0.0,
            delay_ms: 0,
            dup_rate: 0.0,
            corrupt_rate: 0.0,
        }
    }

    /// Parse a `key=value,...` spec (see module docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::disabled(0);
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .with_context(|| format!("fault spec {part:?}: expected key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed = value
                        .trim()
                        .parse()
                        .with_context(|| format!("fault spec seed {value:?}"))?;
                }
                "panic" => {
                    plan.panic_rate = parse_rate("panic", value)?;
                }
                "malform" => {
                    plan.malform_rate = parse_rate("malform", value)?;
                }
                "slow" => {
                    let (rate, ms) = parse_rate_ms("slow", value)?;
                    plan.slow_rate = rate;
                    plan.slow_ms = ms;
                }
                "drop" => {
                    plan.drop_rate = parse_rate("drop", value)?;
                }
                "delay" => {
                    let (rate, ms) = parse_rate_ms("delay", value)?;
                    plan.delay_rate = rate;
                    plan.delay_ms = ms;
                }
                "dup" => {
                    plan.dup_rate = parse_rate("dup", value)?;
                }
                "corrupt" => {
                    plan.corrupt_rate = parse_rate("corrupt", value)?;
                }
                other => bail!(
                    "unknown fault spec key {other:?} \
                     (seed|panic|slow|malform|drop|delay|dup|corrupt)"
                ),
            }
        }
        if plan.slow_rate > 0.0 && plan.slow_ms == 0 {
            bail!("fault spec: slow rate set but duration is 0 ms");
        }
        if plan.delay_rate > 0.0 && plan.delay_ms == 0 {
            bail!("fault spec: delay rate set but duration is 0 ms");
        }
        Ok(plan)
    }

    /// The `REPRO_FAULTS` plan, if the variable is set. An unparsable value
    /// is a hard error rather than a silent no-fault fallback — a CI chaos
    /// leg meant to inject faults must never quietly run clean.
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var("REPRO_FAULTS") {
            Ok(spec) => Ok(Some(
                Self::parse(&spec).with_context(|| format!("invalid REPRO_FAULTS={spec:?}"))?,
            )),
            Err(_) => Ok(None),
        }
    }

    /// True when at least one fault kind can fire.
    pub fn is_active(&self) -> bool {
        self.panic_rate > 0.0
            || self.slow_rate > 0.0
            || self.malform_rate > 0.0
            || self.drop_rate > 0.0
            || self.delay_rate > 0.0
            || self.dup_rate > 0.0
            || self.corrupt_rate > 0.0
    }

    /// Uniform [0,1) draw for `(kind, id)` — pure, order-free.
    fn draw(&self, salt: u64, id: u64) -> f64 {
        Rng::new(self.seed).stream(salt, id).next_f64()
    }

    /// Uniform [0,1) draw for `(kind, stage, id)` — pure, order-free. The
    /// same id draws independently at different stages, so e.g. a frame
    /// dropped client→coordinator is not also dropped on the way back.
    fn stage_draw(&self, salt: u64, stage: &str, id: u64) -> f64 {
        self.draw(salt ^ stage_salt(stage), id)
    }

    /// Should the worker panic while serving the batch containing this
    /// request?
    pub fn worker_panic(&self, request_id: u64) -> bool {
        self.panic_rate > 0.0 && self.draw(SALT_PANIC, request_id) < self.panic_rate
    }

    /// Injected sleep for the batch containing this request, if any.
    pub fn slow_stage(&self, request_id: u64) -> Option<u64> {
        (self.slow_rate > 0.0 && self.draw(SALT_SLOW, request_id) < self.slow_rate)
            .then_some(self.slow_ms)
    }

    /// Should this request's line be corrupted before parsing?
    pub fn malform(&self, request_id: u64) -> bool {
        self.malform_rate > 0.0 && self.draw(SALT_MALFORM, request_id) < self.malform_rate
    }

    /// Should this frame be dropped in flight at `stage`?
    pub fn drop_frame(&self, stage: &str, id: u64) -> bool {
        self.drop_rate > 0.0 && self.stage_draw(SALT_DROP, stage, id) < self.drop_rate
    }

    /// Hold for this frame at `stage`, if a delay fires (milliseconds).
    pub fn delay_frame(&self, stage: &str, id: u64) -> Option<u64> {
        (self.delay_rate > 0.0 && self.stage_draw(SALT_DELAY, stage, id) < self.delay_rate)
            .then_some(self.delay_ms)
    }

    /// Should this frame be delivered twice at `stage`?
    pub fn dup_frame(&self, stage: &str, id: u64) -> bool {
        self.dup_rate > 0.0 && self.stage_draw(SALT_DUP, stage, id) < self.dup_rate
    }

    /// Should this frame be corrupted in flight at `stage`?
    pub fn corrupt_frame(&self, stage: &str, id: u64) -> bool {
        self.corrupt_rate > 0.0 && self.stage_draw(SALT_CORRUPT, stage, id) < self.corrupt_rate
    }

    /// Corrupt a line the way a broken peer would: truncate and append a
    /// non-numeric token, so parsing fails with a typed error.
    pub fn corrupt_line(&self, line: &str) -> String {
        let keep = line.len() / 2;
        format!("{}<corrupt>", &line[..keep.min(line.len())])
    }

    /// The canonical spec for this plan: used in startup banners, and
    /// [`FaultPlan::parse`] round-trips it (`parse(describe(p)) == p`).
    pub fn describe(&self) -> String {
        format!(
            "seed={},panic={},slow={}:{},malform={},drop={},delay={}:{},dup={},corrupt={}",
            self.seed,
            self.panic_rate,
            self.slow_rate,
            self.slow_ms,
            self.malform_rate,
            self.drop_rate,
            self.delay_rate,
            self.delay_ms,
            self.dup_rate,
            self.corrupt_rate
        )
    }
}

fn parse_rate(key: &str, value: &str) -> Result<f64> {
    let rate: f64 = value
        .trim()
        .parse()
        .with_context(|| format!("fault spec {key} rate {value:?}"))?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("fault spec {key} rate {rate} not in [0, 1]");
    }
    Ok(rate)
}

/// Parse a `RATE:MS` value, e.g. `slow=0.05:3`.
fn parse_rate_ms(key: &str, value: &str) -> Result<(f64, u64)> {
    let (rate, ms) = value
        .split_once(':')
        .with_context(|| format!("fault spec {key} {value:?}: expected RATE:MS"))?;
    let rate = parse_rate(key, rate)?;
    let ms = ms
        .trim()
        .parse()
        .with_context(|| format!("fault spec {key} duration {ms:?}"))?;
    Ok((rate, ms))
}

/// The decision for one frame routed through a [`FaultGate`].
#[derive(Clone, Debug, PartialEq)]
pub struct GatedFrame {
    /// Hold before delivery (0 = deliver now).
    pub delay_ms: u64,
    /// The deliveries: empty = dropped, two entries = duplicated; entries
    /// may be corrupted copies of the input.
    pub lines: Vec<String>,
}

/// Frame-level fault application shared by the dist in-memory harness and
/// the socket glue: each frame passing through gets a monotonically
/// increasing id, so retransmissions draw fresh decisions (a resent frame
/// is not deterministically re-dropped forever) while the whole sequence
/// stays a pure function of (plan, stage, delivery order).
#[derive(Clone, Debug)]
pub struct FaultGate {
    plan: Option<FaultPlan>,
    stage: &'static str,
    counter: u64,
}

impl FaultGate {
    pub fn new(plan: Option<FaultPlan>, stage: &'static str) -> Self {
        Self { plan, stage, counter: 0 }
    }

    /// Route one frame through the gate.
    pub fn pass(&mut self, line: &str) -> GatedFrame {
        let id = self.counter;
        self.counter += 1;
        let Some(plan) = &self.plan else {
            return GatedFrame { delay_ms: 0, lines: vec![line.to_string()] };
        };
        if plan.drop_frame(self.stage, id) {
            return GatedFrame { delay_ms: 0, lines: Vec::new() };
        }
        let delivered = if plan.corrupt_frame(self.stage, id) {
            plan.corrupt_line(line)
        } else {
            line.to_string()
        };
        let mut lines = vec![delivered];
        if plan.dup_frame(self.stage, id) {
            lines.push(lines[0].clone());
        }
        let delay_ms = plan.delay_frame(self.stage, id).unwrap_or(0);
        GatedFrame { delay_ms, lines }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let plan = FaultPlan::parse("seed=7,panic=0.02,slow=0.05:3,malform=0.1").unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.panic_rate, 0.02);
        assert_eq!(plan.slow_rate, 0.05);
        assert_eq!(plan.slow_ms, 3);
        assert_eq!(plan.malform_rate, 0.1);
        assert!(plan.is_active());
    }

    /// The daemon's original spec grammar (pre-dist) must keep parsing
    /// byte-for-byte as before: old keys only, new fields all zero.
    #[test]
    fn daemon_spec_syntax_is_back_compatible() {
        let plan = FaultPlan::parse("seed=20260807,panic=0.05,slow=0.03:5,malform=0.05").unwrap();
        assert_eq!(plan.seed, 20260807);
        assert_eq!(plan.panic_rate, 0.05);
        assert_eq!(plan.slow_rate, 0.03);
        assert_eq!(plan.slow_ms, 5);
        assert_eq!(plan.malform_rate, 0.05);
        assert_eq!(plan.drop_rate, 0.0);
        assert_eq!(plan.delay_rate, 0.0);
        assert_eq!(plan.delay_ms, 0);
        assert_eq!(plan.dup_rate, 0.0);
        assert_eq!(plan.corrupt_rate, 0.0);
        // old-kind decisions must be reachable without any new-kind key
        for id in 0..50 {
            let _ = (plan.worker_panic(id), plan.slow_stage(id), plan.malform(id));
            assert!(!plan.drop_frame("c2s", id));
            assert!(plan.delay_frame("c2s", id).is_none());
        }
    }

    #[test]
    fn parses_frame_fault_keys() {
        let plan = FaultPlan::parse("seed=9,drop=0.1,delay=0.05:4,dup=0.02,corrupt=0.01").unwrap();
        assert_eq!(plan.drop_rate, 0.1);
        assert_eq!(plan.delay_rate, 0.05);
        assert_eq!(plan.delay_ms, 4);
        assert_eq!(plan.dup_rate, 0.02);
        assert_eq!(plan.corrupt_rate, 0.01);
        assert!(plan.is_active());
    }

    #[test]
    fn omitted_keys_disable_faults() {
        let plan = FaultPlan::parse("seed=3").unwrap();
        assert_eq!(plan, FaultPlan::disabled(3));
        assert!(!plan.is_active());
        for id in 0..100 {
            assert!(!plan.worker_panic(id));
            assert!(plan.slow_stage(id).is_none());
            assert!(!plan.malform(id));
            assert!(!plan.drop_frame("x", id));
            assert!(plan.delay_frame("x", id).is_none());
            assert!(!plan.dup_frame("x", id));
            assert!(!plan.corrupt_frame("x", id));
        }
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(FaultPlan::parse("panic").is_err(), "missing =");
        assert!(FaultPlan::parse("panic=2.0").is_err(), "rate > 1");
        assert!(FaultPlan::parse("panic=-0.1").is_err(), "rate < 0");
        assert!(FaultPlan::parse("slow=0.5").is_err(), "slow missing :MS");
        assert!(FaultPlan::parse("slow=0.5:0").is_err(), "slow with 0 ms");
        assert!(FaultPlan::parse("delay=0.5").is_err(), "delay missing :MS");
        assert!(FaultPlan::parse("delay=0.5:0").is_err(), "delay with 0 ms");
        assert!(FaultPlan::parse("drop=7").is_err(), "drop rate > 1");
        assert!(FaultPlan::parse("bogus=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("seed=x").is_err(), "bad seed");
    }

    #[test]
    fn decisions_are_pure_functions_of_seed_and_id() {
        let a = FaultPlan::parse("seed=11,panic=0.3,slow=0.3:2,malform=0.3,drop=0.3").unwrap();
        let b = a.clone();
        for id in 0..500 {
            assert_eq!(a.worker_panic(id), b.worker_panic(id));
            assert_eq!(a.slow_stage(id), b.slow_stage(id));
            assert_eq!(a.malform(id), b.malform(id));
            assert_eq!(a.drop_frame("c2s", id), b.drop_frame("c2s", id));
        }
        // query order must not matter
        let forward: Vec<bool> = (0..500).map(|id| a.worker_panic(id)).collect();
        let backward: Vec<bool> = (0..500).rev().map(|id| a.worker_panic(id)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
    }

    #[test]
    fn stages_draw_independently() {
        let plan = FaultPlan::parse("seed=13,drop=0.5").unwrap();
        let a: Vec<bool> = (0..2000).map(|id| plan.drop_frame("c2s", id)).collect();
        let b: Vec<bool> = (0..2000).map(|id| plan.drop_frame("s2c", id)).collect();
        let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        // independent fair coins agree ~50% of the time, never ~100%
        assert!(agree < 1200, "stages c2s/s2c agree on {agree}/2000 draws");
    }

    #[test]
    fn rates_are_roughly_respected_and_kinds_independent() {
        let plan = FaultPlan::parse("seed=5,panic=0.2,slow=0.2:1,malform=0.2,drop=0.2").unwrap();
        let n = 20_000u64;
        let panics = (0..n).filter(|&id| plan.worker_panic(id)).count() as f64;
        let slows = (0..n).filter(|&id| plan.slow_stage(id).is_some()).count() as f64;
        let malforms = (0..n).filter(|&id| plan.malform(id)).count() as f64;
        let drops = (0..n).filter(|&id| plan.drop_frame("net", id)).count() as f64;
        for (kind, count) in
            [("panic", panics), ("slow", slows), ("malform", malforms), ("drop", drops)]
        {
            let frac = count / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "{kind} rate {frac} far from 0.2");
        }
        // kinds do not fire in lockstep (independent streams)
        let both = (0..n)
            .filter(|&id| plan.worker_panic(id) && plan.malform(id))
            .count() as f64;
        let frac = both / n as f64;
        assert!((frac - 0.04).abs() < 0.02, "panic∧malform rate {frac} far from 0.04");
    }

    #[test]
    fn different_seeds_give_different_plans() {
        let a = FaultPlan::parse("seed=1,panic=0.5").unwrap();
        let b = FaultPlan::parse("seed=2,panic=0.5").unwrap();
        let same = (0..256).filter(|&id| a.worker_panic(id) == b.worker_panic(id)).count();
        assert!(same < 200, "seeds 1 and 2 agree on {same}/256 decisions");
    }

    #[test]
    fn corrupt_line_breaks_float_parsing() {
        let plan = FaultPlan::disabled(0);
        let line = "0.5 1.5 2.5 3.5";
        let bad = plan.corrupt_line(line);
        assert!(bad.contains("<corrupt>"));
        assert!(bad.split_whitespace().any(|t| t.parse::<f32>().is_err()));
    }

    #[test]
    fn describe_emits_canonical_parseable_spec() {
        let plan =
            FaultPlan::parse("seed=7,panic=0.02,slow=0.05:3,malform=0.1,drop=0.25,delay=0.5:9")
                .unwrap();
        let reparsed = FaultPlan::parse(&plan.describe()).unwrap();
        assert_eq!(reparsed, plan);
        let disabled = FaultPlan::disabled(42);
        assert_eq!(FaultPlan::parse(&disabled.describe()).unwrap(), disabled);
    }

    #[test]
    fn gate_is_reproducible_and_respects_plan() {
        let plan = FaultPlan::parse("seed=17,drop=0.3,delay=0.2:5,dup=0.2,corrupt=0.2").unwrap();
        let run = |stage: &'static str| -> Vec<GatedFrame> {
            let mut gate = FaultGate::new(Some(plan.clone()), stage);
            (0..200).map(|i| gate.pass(&format!("frame {i}"))).collect()
        };
        assert_eq!(run("c2s"), run("c2s"), "gate must replay identically");
        assert_ne!(run("c2s"), run("s2c"), "stages must draw independently");
        let frames = run("c2s");
        assert!(frames.iter().any(|f| f.lines.is_empty()), "some frames dropped");
        assert!(frames.iter().any(|f| f.lines.len() == 2), "some frames duplicated");
        assert!(frames.iter().any(|f| f.delay_ms == 5), "some frames delayed");
        assert!(
            frames.iter().any(|f| f.lines.first().is_some_and(|l| l.contains("<corrupt>"))),
            "some frames corrupted"
        );
        // a disabled gate is a pass-through
        let mut clean = FaultGate::new(None, "c2s");
        assert_eq!(
            clean.pass("hello"),
            GatedFrame { delay_ms: 0, lines: vec!["hello".to_string()] }
        );
    }
}
