//! The sanctioned clock layer: pausable stopwatch for learning-curve
//! timing plus the injectable millisecond [`Clock`] used by the serving
//! daemon.
//!
//! Figure 1 plots metrics against *training* wallclock; evaluation passes
//! must not count. The trainer pauses the watch around evaluation, exactly
//! like the paper's protocol of shifting curves only by the auxiliary-model
//! fitting time.
//!
//! This module (together with `utils/bench.rs`) is the only place allowed
//! to read `Instant::now` directly — repro-lint's `wall-clock` rule denies
//! it everywhere else, so all time-dependent logic stays virtual-time
//! testable and out of reproducible results.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Accumulating stopwatch that can be paused and resumed.
#[derive(Debug)]
pub struct StopWatch {
    accumulated: Duration,
    started_at: Option<Instant>,
}

impl Default for StopWatch {
    fn default() -> Self {
        Self::new()
    }
}

impl StopWatch {
    /// Create a paused stopwatch at zero.
    pub fn new() -> Self {
        Self { accumulated: Duration::ZERO, started_at: None }
    }

    /// Create and immediately start.
    pub fn started() -> Self {
        let mut s = Self::new();
        s.resume();
        s
    }

    pub fn resume(&mut self) {
        if self.started_at.is_none() {
            self.started_at = Some(Instant::now());
        }
    }

    pub fn pause(&mut self) {
        if let Some(t0) = self.started_at.take() {
            self.accumulated += t0.elapsed();
        }
    }

    /// Total running time so far.
    pub fn elapsed(&self) -> Duration {
        self.accumulated
            + self
                .started_at
                .map(|t0| t0.elapsed())
                .unwrap_or(Duration::ZERO)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Seed the accumulated time (e.g. with the auxiliary-model fit time so
    /// curves start shifted right, as in the paper's Figure 1).
    pub fn preload(&mut self, d: Duration) {
        self.accumulated += d;
    }
}

/// Millisecond clock injected into time-dependent components (the serving
/// daemon's deadline/coalescing logic). Production uses [`RealClock`];
/// tests drive virtual time with a [`ManualClock`].
pub trait Clock: Send {
    fn now_ms(&self) -> u64;
}

/// Wall clock (milliseconds since construction).
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }
}

/// Hand-cranked clock for deterministic tests; clones share the time.
#[derive(Clone, Default)]
pub struct ManualClock(Arc<AtomicU64>);

impl ManualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&self, ms: u64) {
        self.0.fetch_add(ms, Ordering::SeqCst);
    }

    pub fn set(&self, ms: u64) {
        self.0.store(ms, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;

    #[test]
    fn pause_stops_accumulation() {
        let mut w = StopWatch::started();
        sleep(Duration::from_millis(10));
        w.pause();
        let e1 = w.elapsed();
        sleep(Duration::from_millis(20));
        let e2 = w.elapsed();
        assert_eq!(e1, e2);
        assert!(e1 >= Duration::from_millis(9));
    }

    #[test]
    fn resume_continues() {
        let mut w = StopWatch::started();
        sleep(Duration::from_millis(5));
        w.pause();
        w.resume();
        sleep(Duration::from_millis(5));
        assert!(w.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn preload_shifts_origin() {
        let mut w = StopWatch::new();
        w.preload(Duration::from_secs(3));
        assert!(w.elapsed() >= Duration::from_secs(3));
    }

    #[test]
    fn manual_clock_is_shared_and_settable() {
        let c = ManualClock::new();
        let c2 = c.clone();
        c.advance(40);
        assert_eq!(c2.now_ms(), 40);
        c2.set(7);
        assert_eq!(c.now_ms(), 7);
    }

    #[test]
    fn real_clock_is_monotonic() {
        let c = RealClock::new();
        let a = c.now_ms();
        sleep(Duration::from_millis(5));
        assert!(c.now_ms() >= a);
    }

    #[test]
    fn double_resume_is_idempotent() {
        let mut w = StopWatch::started();
        w.resume();
        sleep(Duration::from_millis(5));
        w.pause();
        assert!(w.elapsed() < Duration::from_millis(500));
    }
}
