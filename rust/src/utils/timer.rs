//! Pausable stopwatch for learning-curve timing.
//!
//! Figure 1 plots metrics against *training* wallclock; evaluation passes
//! must not count. The trainer pauses the watch around evaluation, exactly
//! like the paper's protocol of shifting curves only by the auxiliary-model
//! fitting time.

use std::time::{Duration, Instant};

/// Accumulating stopwatch that can be paused and resumed.
#[derive(Debug)]
pub struct StopWatch {
    accumulated: Duration,
    started_at: Option<Instant>,
}

impl Default for StopWatch {
    fn default() -> Self {
        Self::new()
    }
}

impl StopWatch {
    /// Create a paused stopwatch at zero.
    pub fn new() -> Self {
        Self { accumulated: Duration::ZERO, started_at: None }
    }

    /// Create and immediately start.
    pub fn started() -> Self {
        let mut s = Self::new();
        s.resume();
        s
    }

    pub fn resume(&mut self) {
        if self.started_at.is_none() {
            self.started_at = Some(Instant::now());
        }
    }

    pub fn pause(&mut self) {
        if let Some(t0) = self.started_at.take() {
            self.accumulated += t0.elapsed();
        }
    }

    /// Total running time so far.
    pub fn elapsed(&self) -> Duration {
        self.accumulated
            + self
                .started_at
                .map(|t0| t0.elapsed())
                .unwrap_or(Duration::ZERO)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Seed the accumulated time (e.g. with the auxiliary-model fit time so
    /// curves start shifted right, as in the paper's Figure 1).
    pub fn preload(&mut self, d: Duration) {
        self.accumulated += d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;

    #[test]
    fn pause_stops_accumulation() {
        let mut w = StopWatch::started();
        sleep(Duration::from_millis(10));
        w.pause();
        let e1 = w.elapsed();
        sleep(Duration::from_millis(20));
        let e2 = w.elapsed();
        assert_eq!(e1, e2);
        assert!(e1 >= Duration::from_millis(9));
    }

    #[test]
    fn resume_continues() {
        let mut w = StopWatch::started();
        sleep(Duration::from_millis(5));
        w.pause();
        w.resume();
        sleep(Duration::from_millis(5));
        assert!(w.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn preload_shifts_origin() {
        let mut w = StopWatch::new();
        w.preload(Duration::from_secs(3));
        assert!(w.elapsed() >= Duration::from_secs(3));
    }

    #[test]
    fn double_resume_is_idempotent() {
        let mut w = StopWatch::started();
        w.resume();
        sleep(Duration::from_millis(5));
        w.pause();
        assert!(w.elapsed() < Duration::from_millis(500));
    }
}
