//! Deterministic xoshiro256++ RNG.
//!
//! Every stochastic component in the library (data generation, samplers,
//! trainers, experiments) takes an explicit [`Rng`] so runs are exactly
//! reproducible from a single seed. `split` derives independent streams
//! for worker threads via SplitMix64 re-seeding, which keeps pipelined
//! training deterministic regardless of thread interleaving.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any u64 is fine, including 0.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream purely from the **current** state and a
    /// `(domain, index)` pair, *without* advancing this generator.
    ///
    /// This is the keystone of pipelined determinism: batch `t` of a run is
    /// generated from `base.stream(STREAM_BATCH, t)`, which any worker can
    /// recompute, so the batch stream is bit-identical no matter how many
    /// pipeline workers produce it (see `train::batcher`). `domain`
    /// separates independent uses (epoch shuffles vs. per-batch draws) that
    /// share an index space.
    pub fn stream(&self, domain: u64, index: u64) -> Rng {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47);
        sm = sm.wrapping_add(domain.wrapping_mul(0xA076_1D64_78BD_642F));
        let _ = splitmix64(&mut sm); // diffuse domain before mixing index
        sm = sm.wrapping_add(index.wrapping_mul(0xE703_7ED1_A0B4_28DB));
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for worker threads / sub-components).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Unbiased (Lemire's method with rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (uses two uniforms per pair; we keep
    /// it stateless and simply discard the second value).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(3);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn stream_is_pure_and_non_advancing() {
        let mut a = Rng::new(5);
        let b = a.clone();
        let s1: Vec<u64> = (0..8).map(|_| a.stream(1, 42).next_u64()).collect();
        // deriving streams did not advance `a`
        assert_eq!(a.s, b.s);
        // pure function of (state, domain, index)
        let s2: Vec<u64> = (0..8).map(|_| a.stream(1, 42).next_u64()).collect();
        assert_eq!(s1, s2);
        // distinct (domain, index) pairs give distinct streams
        let mut x = a.stream(1, 42);
        let mut y = a.stream(1, 43);
        let mut z = a.stream(2, 42);
        let same_xy = (0..64).filter(|_| x.next_u64() == y.next_u64()).count();
        let same_xz = (0..64).filter(|_| x.next_u64() == z.next_u64()).count();
        assert_eq!(same_xy, 0);
        assert_eq!(same_xz, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(11);
        let n = 10;
        let mut counts = vec![0usize; n];
        let draws = 100_000;
        for _ in 0..draws {
            let v = rng.below(n);
            assert!(v < n);
            counts[v] += 1;
        }
        let expect = draws as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sum2) = (0f64, 0f64);
        for _ in 0..n {
            let v = rng.normal() as f64;
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn uniform_f32_in_unit_interval() {
        let mut rng = Rng::new(17);
        for _ in 0..10_000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
