//! Deterministic xoshiro256++ RNG.
//!
//! Every stochastic component in the library (data generation, samplers,
//! trainers, experiments) takes an explicit [`Rng`] so runs are exactly
//! reproducible from a single seed. `split` derives independent streams
//! for worker threads via SplitMix64 re-seeding, which keeps pipelined
//! training deterministic regardless of thread interleaving.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any u64 is fine, including 0.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream purely from the **current** state and a
    /// `(domain, index)` pair, *without* advancing this generator.
    ///
    /// This is the keystone of pipelined determinism: batch `t` of a run is
    /// generated from `base.stream(STREAM_BATCH, t)`, which any worker can
    /// recompute, so the batch stream is bit-identical no matter how many
    /// pipeline workers produce it (see `train::batcher`). `domain`
    /// separates independent uses (epoch shuffles vs. per-batch draws) that
    /// share an index space.
    pub fn stream(&self, domain: u64, index: u64) -> Rng {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47);
        sm = sm.wrapping_add(domain.wrapping_mul(0xA076_1D64_78BD_642F));
        let _ = splitmix64(&mut sm); // diffuse domain before mixing index
        sm = sm.wrapping_add(index.wrapping_mul(0xE703_7ED1_A0B4_28DB));
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for worker threads / sub-components).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Unbiased (Lemire's method with rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (uses two uniforms per pair; we keep
    /// it stateless and simply discard the second value).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Counter-based per-descent RNG (splitmix64 in counter mode).
///
/// A tree descent draws one uniform per non-forced level. The xoshiro
/// [`Rng`] serializes those draws through 256 bits of mutable state, which
/// is exactly the stage that kept `TreeKernel::sample_batch`'s inner loop
/// scalar: lane `l`'s next state depends on lane `l`'s previous draw.
/// `LaneRng` replaces the sequential state with a pure function of
/// `(key, counter)` — draw `i` of a descent is `lane_mix(key, i)` — so
/// eight lanes can produce their level-`d` uniforms branch-free from
/// stack arrays of keys and counters with no cross-iteration dependency.
///
/// The key is derived by consuming exactly **one** `next_u64` from the
/// caller's [`Rng`] at descent start ([`LaneRng::from_rng`]), so stream
/// bookkeeping (one parent draw per descent) stays with the existing
/// generator and callers' stream layouts are unchanged. This *is* a
/// deliberate stream-format change for the descent draws themselves —
/// see `DETERMINISM.md` for the re-pin policy.
#[derive(Clone, Copy, Debug)]
pub struct LaneRng {
    key: u64,
    ctr: u64,
}

/// Golden-ratio increment shared with [`splitmix64`].
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// splitmix64 finalizer of `key + ctr·GOLDEN`: the counter walks the same
/// state sequence splitmix64 itself would, so draws inherit its diffusion
/// quality while staying a pure (key, ctr) function.
#[inline]
fn lane_mix(key: u64, ctr: u64) -> u64 {
    let mut z = key.wrapping_add(ctr.wrapping_mul(GOLDEN)).wrapping_add(GOLDEN);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl LaneRng {
    /// Counter mode keyed directly; draw `i` is `uniform_at(key, i)`.
    #[inline]
    pub fn new(key: u64) -> Self {
        Self { key, ctr: 0 }
    }

    /// Derive a descent key, consuming exactly one draw from `rng`.
    #[inline]
    pub fn from_rng(rng: &mut Rng) -> Self {
        Self::new(rng.next_u64())
    }

    /// The key this generator was built with (lane staging in the kernel
    /// carries keys/counters in stack arrays rather than `LaneRng`s).
    #[inline]
    pub fn key(&self) -> u64 {
        self.key
    }

    /// Draws consumed so far.
    #[inline]
    pub fn counter(&self) -> u64 {
        self.ctr
    }

    /// Next raw draw; advances the counter.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let v = lane_mix(self.key, self.ctr);
        self.ctr += 1;
        v
    }

    /// Uniform in [0, 1); advances the counter. Same 24-bit mantissa
    /// construction as [`Rng::next_f32`].
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Pure draw at an explicit counter: what `next_f32` would return on
    /// draw `ctr` of a generator keyed with `key`. The kernel's fast path
    /// calls this per lane from stack-held keys/counters — no state
    /// load/store, no cross-lane dependency.
    #[inline]
    pub fn uniform_at(key: u64, ctr: u64) -> f32 {
        (lane_mix(key, ctr) >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(3);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn stream_is_pure_and_non_advancing() {
        let mut a = Rng::new(5);
        let b = a.clone();
        let s1: Vec<u64> = (0..8).map(|_| a.stream(1, 42).next_u64()).collect();
        // deriving streams did not advance `a`
        assert_eq!(a.s, b.s);
        // pure function of (state, domain, index)
        let s2: Vec<u64> = (0..8).map(|_| a.stream(1, 42).next_u64()).collect();
        assert_eq!(s1, s2);
        // distinct (domain, index) pairs give distinct streams
        let mut x = a.stream(1, 42);
        let mut y = a.stream(1, 43);
        let mut z = a.stream(2, 42);
        let same_xy = (0..64).filter(|_| x.next_u64() == y.next_u64()).count();
        let same_xz = (0..64).filter(|_| x.next_u64() == z.next_u64()).count();
        assert_eq!(same_xy, 0);
        assert_eq!(same_xz, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(11);
        let n = 10;
        let mut counts = vec![0usize; n];
        let draws = 100_000;
        for _ in 0..draws {
            let v = rng.below(n);
            assert!(v < n);
            counts[v] += 1;
        }
        let expect = draws as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sum2) = (0f64, 0f64);
        for _ in 0..n {
            let v = rng.normal() as f64;
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn uniform_f32_in_unit_interval() {
        let mut rng = Rng::new(17);
        for _ in 0..10_000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    /// Pin the counter-mode draw sequence for a fixed (seed, stream).
    /// These constants define the descent stream format shipped with the
    /// lane-RNG change; if they move, every pinned sampling artifact moves
    /// with them (see the stream-format-change policy in DETERMINISM.md).
    #[test]
    fn lane_rng_spec_sequence_is_pinned() {
        let base = Rng::new(0xDE_C0DE);
        let mut parent = base.stream(1, 2);
        let mut lane = LaneRng::from_rng(&mut parent);
        assert_eq!(lane.key(), 0x4AE2_68F1_52C0_BD63);
        let expect_u64: [u64; 4] = [
            0x3224_AB69_0D28_762C,
            0x425C_24BB_BBDC_A5D8,
            0x2A41_0A57_957A_910A,
            0x4615_3038_5163_6479,
        ];
        for (i, &e) in expect_u64.iter().enumerate() {
            assert_eq!(lane.counter(), i as u64);
            assert_eq!(lane.next_u64(), e, "draw {i}");
        }
        // f32 construction matches Rng::next_f32's 24-bit mantissa path
        let expect_f32_bits: [u32; 2] = [0x3D9A_2DE0, 0x3E85_6A32];
        for (i, &e) in expect_f32_bits.iter().enumerate() {
            assert_eq!(lane.next_f32().to_bits(), e, "f32 draw {}", i + 4);
        }
    }

    #[test]
    fn lane_rng_uniform_at_is_pure_and_matches_sequential() {
        let mut parent = Rng::new(21);
        for _ in 0..16 {
            let key = parent.next_u64();
            let mut seq = LaneRng::new(key);
            for ctr in 0..32u64 {
                let v = seq.next_f32();
                assert_eq!(v.to_bits(), LaneRng::uniform_at(key, ctr).to_bits());
                assert!((0.0..1.0).contains(&v));
            }
        }
    }

    #[test]
    fn lane_rng_keys_decorrelate_lanes() {
        // eight keys drawn from one parent give eight distinct streams
        let mut parent = Rng::new(23);
        let keys: Vec<u64> = (0..8).map(|_| parent.next_u64()).collect();
        for i in 0..8 {
            for j in (i + 1)..8 {
                let same = (0..64)
                    .filter(|&c| {
                        LaneRng::uniform_at(keys[i], c).to_bits()
                            == LaneRng::uniform_at(keys[j], c).to_bits()
                    })
                    .count();
                assert!(same <= 1, "lanes {i},{j} collide {same}/64 draws");
            }
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
