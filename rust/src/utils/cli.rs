//! Tiny command-line parser (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [<subcommand>...] [--key value|--key=value|--flag]`.
//! Typed access via [`Args::get`] with a default, [`Args::get_opt`], and
//! [`Args::flag`]. Unknown-key detection via [`Args::finish`] keeps typos
//! loud.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed arguments: leading positionals (subcommands) + key/value options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    pub fn parse_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = S>, S: Into<String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = items.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // value is the next token unless it looks like an option
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.options.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Positional `i` (subcommand path).
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get<T: FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get_opt(key)? {
            Some(v) => Ok(v),
            None => Ok(default),
        }
    }

    /// Typed optional option.
    pub fn get_opt<T: FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.borrow_mut().insert(key.to_string());
        match self.options.get(key) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {raw:?}: {e}")),
        }
    }

    /// Boolean flag (present without value, or with true/false).
    pub fn flag(&self, key: &str) -> Result<bool> {
        Ok(self.get_opt::<String>(key)?.map(|v| v != "false").unwrap_or(false))
    }

    /// Error on any option never consumed (catches typos).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .options
            .keys()
            .filter(|k| !consumed.contains(*k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {unknown:?}");
        }
        Ok(())
    }

    /// Required option.
    pub fn require<T: FromStr>(&self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.get_opt(key)?.with_context(|| format!("missing required --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        let a = Args::parse(["exp", "figure1", "--seconds", "30", "--dataset=wiki-sim", "--verbose"]).unwrap();
        assert_eq!(a.pos(0), Some("exp"));
        assert_eq!(a.pos(1), Some("figure1"));
        assert_eq!(a.get::<f64>("seconds", 0.0).unwrap(), 30.0);
        assert_eq!(a.get::<String>("dataset", "".into()).unwrap(), "wiki-sim");
        assert!(a.flag("verbose").unwrap());
        assert!(!a.flag("quiet").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(["x"]).unwrap();
        assert_eq!(a.get::<usize>("n", 7).unwrap(), 7);
        assert!(a.require::<usize>("n").is_err());
    }

    #[test]
    fn unknown_option_detected() {
        let a = Args::parse(["--known", "1", "--typo", "2"]).unwrap();
        let _ = a.get::<usize>("known", 0).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_is_error() {
        let a = Args::parse(["--n", "abc"]).unwrap();
        assert!(a.get::<usize>("n", 0).is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = Args::parse(["--x", "-3.5"]).unwrap();
        assert_eq!(a.get::<f64>("x", 0.0).unwrap(), -3.5);
    }
}
