//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by every `rust/benches/*.rs` target (all `harness = false`).
//! Protocol: warm up, then run timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; report median / mean
//! / p10 / p90 per-iteration latency. Median over many iterations is
//! robust to scheduler noise at the sizes we measure.

use std::time::{Duration, Instant};

/// Per-iteration latency statistics (nanoseconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchStats {
    pub fn median_secs(&self) -> f64 {
        self.median_ns / 1e9
    }

    /// Items-per-second at the median latency for a batch of `items`.
    pub fn throughput(&self, items: usize) -> f64 {
        items as f64 / self.median_secs()
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    warmup_iters: usize,
    min_iters: usize,
    budget: Duration,
}

/// Per-case budget from `REPRO_BENCH_SECONDS`, falling back to `default_secs`.
fn env_budget_secs(default_secs: f64) -> f64 {
    std::env::var("REPRO_BENCH_SECONDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_secs)
}

impl Default for Bench {
    fn default() -> Self {
        Self::with_env_budget(3, 10, 2.0)
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, min_iters: usize, budget_secs: f64) -> Self {
        Self {
            warmup_iters,
            min_iters,
            budget: Duration::from_secs_f64(budget_secs),
        }
    }

    /// Like [`Bench::new`], but `REPRO_BENCH_SECONDS` overrides the budget
    /// (single parser for the knob; `default_budget_secs` applies when the
    /// variable is unset/unparsable). For cases whose per-iteration cost
    /// warrants a different default than [`Bench::default`]'s 2s.
    pub fn with_env_budget(
        warmup_iters: usize,
        min_iters: usize,
        default_budget_secs: f64,
    ) -> Self {
        Self::new(warmup_iters, min_iters, env_budget_secs(default_budget_secs))
    }

    /// Time `f` and print a criterion-style line. Returns the stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters || start.elapsed() < self.budget {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
            if samples.len() >= 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len();
        let pick = |q: f64| samples[((n as f64 - 1.0) * q) as usize];
        let stats = BenchStats {
            iters: n,
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
        };
        println!(
            "bench {name:<44} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p10_ns),
            fmt_ns(stats.p90_ns),
            n
        );
        stats
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let b = Bench::new(1, 20, 0.01);
        let mut acc = 0u64;
        let s = b.run("test_case", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(s.iters >= 20);
        assert!(s.p10_ns <= s.median_ns);
        assert!(s.median_ns <= s.p90_ns);
        assert!(s.median_ns > 0.0);
    }

    #[test]
    fn throughput_sane() {
        let s = BenchStats { iters: 10, mean_ns: 1e6, median_ns: 1e6, p10_ns: 1e6, p90_ns: 1e6 };
        assert!((s.throughput(1000) - 1e9 / 1e6 * 1000.0 / 1000.0 * 1000.0).abs() < 1.0);
    }
}
