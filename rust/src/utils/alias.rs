//! Walker alias method for O(1) sampling from a fixed discrete distribution.
//!
//! Used by the frequency-based negative sampler (the word2vec-style baseline
//! in Sec. 2.2 of the paper): build once from empirical label counts, then
//! each draw costs one uniform + one comparison regardless of C.

use super::rng::Rng;

/// Precomputed alias table over `n` outcomes.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
    log_p: Vec<f32>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized). Empty or
    /// all-zero weights are rejected.
    pub fn new(weights: &[f64]) -> anyhow::Result<Self> {
        let n = weights.len();
        anyhow::ensure!(n > 0, "alias table needs at least one outcome");
        // repro-lint: allow(float-reduce) serial input-order sum (utils must not depend on linalg)
        let total: f64 = weights.iter().sum();
        anyhow::ensure!(
            total > 0.0 && weights.iter().all(|w| *w >= 0.0 && w.is_finite()),
            "alias table weights must be finite, non-negative, not all zero"
        );

        let mut prob: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            // donate mass from l to fill s up to 1
            alias[s as usize] = l;
            prob[l as usize] -= 1.0 - prob[s as usize];
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers are 1.0 up to rounding
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
        }

        let log_p = weights
            .iter()
            .map(|w| {
                if *w > 0.0 {
                    ((*w / total).ln()) as f32
                } else {
                    f32::NEG_INFINITY
                }
            })
            .collect();
        Ok(Self { prob, alias, log_p })
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome in O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    /// log-probability of outcome `i` under the normalized distribution.
    #[inline]
    pub fn log_prob(&self, i: usize) -> f32 {
        self.log_p[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_degenerate_weights() {
        assert!(AliasTable::new(&[]).is_err());
        assert!(AliasTable::new(&[0.0, 0.0]).is_err());
        assert!(AliasTable::new(&[1.0, -1.0]).is_err());
        assert!(AliasTable::new(&[1.0, f64::NAN]).is_err());
    }

    #[test]
    fn matches_target_distribution() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let t = AliasTable::new(&w).unwrap();
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 4];
        let draws = 400_000;
        for _ in 0..draws {
            counts[t.sample(&mut rng)] += 1;
        }
        let total: f64 = w.iter().sum();
        for i in 0..4 {
            let expect = w[i] / total;
            let got = counts[i] as f64 / draws as f64;
            assert!(
                (got - expect).abs() < 0.005,
                "outcome {i}: got {got}, expect {expect}"
            );
        }
    }

    #[test]
    fn log_prob_is_normalized() {
        let t = AliasTable::new(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        let total: f32 = (0..4).map(|i| t.log_prob(i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_outcome_never_sampled() {
        let t = AliasTable::new(&[1.0, 0.0, 1.0]).unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..50_000 {
            assert_ne!(t.sample(&mut rng), 1);
        }
        assert_eq!(t.log_prob(1), f32::NEG_INFINITY);
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::new(&[42.0]).unwrap();
        let mut rng = Rng::new(1);
        assert_eq!(t.sample(&mut rng), 0);
        assert!((t.log_prob(0) - 0.0).abs() < 1e-7);
    }
}
