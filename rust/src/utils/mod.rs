//! Small shared utilities: deterministic RNG, alias tables, the scoped
//! worker pool, timing helpers, fault injection, and the line transport.

pub mod alias;
pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod pool;
pub mod rng;
pub mod timer;
pub mod transport;

pub use alias::AliasTable;
pub use pool::{spawn_named, Pool, SharedMut, PAR_MIN_MERGE_ROWS};
pub use rng::Rng;
pub use timer::StopWatch;
