//! Small shared utilities: deterministic RNG, alias tables, timing helpers.

pub mod alias;
pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;

pub use alias::AliasTable;
pub use rng::Rng;
pub use timer::StopWatch;
