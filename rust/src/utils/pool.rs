//! Persistent worker pool with deterministic sharded parallel-for.
//!
//! The host-side hot path (batch assembly, parameter gather, Adagrad
//! scatter, eval sweeps) is embarrassingly parallel but must stay
//! **bit-deterministic**: results may never depend on thread interleaving.
//! The pool therefore offers only two shapes of parallelism, both with
//! statically determined work assignment:
//!
//! * [`Pool::run_sharded`] — run `f(shard)` for every shard id; the caller
//!   partitions work by a pure function of the data (e.g. `label % shards`)
//!   so each output cell has exactly one writer.
//! * [`Pool::for_each_span`] — split a contiguous output buffer into
//!   per-worker spans aligned to an item size; span bounds depend only on
//!   `(len, workers)`, never on timing.
//! * [`Pool::submit_sharded`] — the asynchronous variant of `run_sharded`
//!   for the double-buffered step engine: the stage runs on the background
//!   workers only, leaving the calling thread free to drive a non-`Send`
//!   stage (the PJRT execute) concurrently; the returned [`StageHandle`]
//!   joins the stage before the next pool dispatch.
//!
//! Workers are spawned **once** at pool construction and parked on a
//! condvar between jobs, so a dispatch costs a lock + wakeup (~a few µs)
//! rather than a thread spawn — the pool is called several times per
//! training step on 10–100 µs units of work, where per-call spawning would
//! eat the entire parallel win. Shard 0 always runs on the calling thread.
//! There is no work stealing and no task queue by design: predictable
//! assignment is what makes parallel training runs reproduce serial ones
//! exactly.
//!
//! Dispatch hands workers a lifetime-erased pointer to the caller's
//! closure; soundness comes from `run_sharded` blocking until every worker
//! has finished the job (the closure provably outlives all uses). Worker
//! panics are caught, forwarded, and re-raised on the calling thread.
//!
//! [`SharedMut`] supports the sharded-scatter pattern: several workers
//! mutating *disjoint* rows of one buffer. Disjointness is the caller's
//! obligation (documented per call site); the wrapper only erases the
//! aliasing rule the borrow checker cannot see across the shard function.
//! The `shared_mut_audit` cargo feature turns that obligation into a
//! machine-checked one: every claim is logged and cross-thread overlaps
//! panic with a diagnostic naming both jobs and ranges.

use std::marker::PhantomData;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Minimum independent-row count for a per-row *merge* dispatch to pay for
/// itself: each row's merge is ~10 flops, so a pool dispatch (a few µs)
/// only wins on large batches. Shared by the chunked evaluator's streaming
/// LSE/argmax merge ([`crate::eval::Evaluator::evaluate_cached_with`]) and
/// the serving metrics merge ([`crate::serve::evaluate_serving`]) so the
/// two floors cannot drift apart.
pub const PAR_MIN_MERGE_ROWS: usize = 4096;

/// Spawn a named OS thread. This is the single sanctioned thread entry
/// point outside the pool's own workers: repro-lint's `thread-spawn` rule
/// denies raw `thread::spawn`/`thread::Builder` everywhere else, so every
/// thread in the process carries a name (visible in panics and debuggers)
/// and is accounted for either here or in [`Pool::new`].
pub fn spawn_named<T, F>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::Builder::new().name(name.to_string()).spawn(f)
}

/// Lifetime-erased pointer to the job closure of the current generation.
/// Only dereferenced by workers between the generation bump and the final
/// `remaining` decrement, an interval during which `run_sharded` keeps the
/// closure alive on the caller's stack.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is Sync (shared-callable from any thread), and the
// dispatch protocol guarantees it outlives every dereference.
unsafe impl Send for JobPtr {}

struct PoolState {
    job: Option<JobPtr>,
    /// Bumped once per dispatched job; workers run each generation once.
    generation: u64,
    /// Workers still running the current generation.
    remaining: usize,
    /// A worker's job panicked (re-raised on the calling thread).
    panicked: bool,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Workers wait here for a new generation (or shutdown).
    work_cv: Condvar,
    /// The dispatching caller waits here for `remaining == 0`.
    done_cv: Condvar,
}

fn worker_loop(inner: Arc<PoolInner>, shard: usize) {
    let mut last_gen = 0u64;
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != last_gen {
                    if let Some(job) = st.job {
                        last_gen = st.generation;
                        break job;
                    }
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: the dispatcher keeps the closure alive until every
        // worker decrements `remaining` for this generation (see below).
        let f = unsafe { &*job.0 };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(shard)));
        let mut st = inner.state.lock().unwrap();
        if result.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            inner.done_cv.notify_all();
        }
    }
}

/// A fixed-width pool of persistent workers (see module docs). Workers are
/// joined on drop.
pub struct Pool {
    workers: usize,
    /// None when serial (1 worker): everything degrades to inline calls.
    inner: Option<Arc<PoolInner>>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Pool with exactly `workers` workers (clamped to at least 1). The
    /// calling thread acts as shard 0; `workers - 1` threads are spawned.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        if workers == 1 {
            return Pool { workers, inner: None, handles: Vec::new() };
        }
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState {
                job: None,
                generation: 0,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..workers)
            .map(|shard| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("pool-{shard}"))
                    .spawn(move || worker_loop(inner, shard))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool { workers, inner: Some(inner), handles }
    }

    /// Single-worker pool: every operation degrades to the serial loop.
    pub fn serial() -> Self {
        Pool::new(1)
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        Pool::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        )
    }

    /// Interpret a `RunConfig::parallelism` knob: 0 = auto-detect, n = n.
    pub fn from_parallelism(parallelism: usize) -> Self {
        if parallelism == 0 {
            Pool::auto()
        } else {
            Pool::new(parallelism)
        }
    }

    pub fn num_workers(&self) -> usize {
        self.workers
    }

    pub fn is_serial(&self) -> bool {
        self.workers == 1
    }

    /// Run `f(shard)` for every `shard in 0..num_workers`; shard 0 runs on
    /// the calling thread, the rest on the persistent workers. Blocks until
    /// all shards finish. `f` decides what belongs to each shard by a pure
    /// function of the data, so the result is identical for every worker
    /// count that uses the same shard map.
    pub fn run_sharded<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let Some(inner) = &self.inner else {
            f(0);
            return;
        };
        let trait_obj: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY (lifetime erasure): this function does not return until
        // `remaining == 0`, i.e. until no worker can touch the pointer.
        let job = JobPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                trait_obj,
            )
        });
        {
            let mut st = inner.state.lock().unwrap();
            assert_eq!(
                st.remaining, 0,
                "pool dispatch while another job or background stage is in flight"
            );
            st.job = Some(job);
            st.generation = st.generation.wrapping_add(1);
            st.remaining = self.workers - 1;
            inner.work_cv.notify_all();
        }
        // The guard waits for all workers even if f(0) unwinds below —
        // the closure must outlive every worker's use of `job`.
        let guard = DispatchGuard { inner: inner.as_ref() };
        f(0);
        drop(guard);
        let mut st = inner.state.lock().unwrap();
        if st.panicked {
            st.panicked = false;
            drop(st);
            panic!("pool worker panicked");
        }
    }

    /// Split `data` (a `[n_items, item_len]` row-major buffer) into one
    /// contiguous span per shard, aligned to `item_len`, and run
    /// `f(first_item_index, span)` on each span in parallel. Span bounds
    /// depend only on the lengths, so output placement is deterministic.
    pub fn for_each_span<T, F>(&self, data: &mut [T], item_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(item_len > 0, "item_len must be positive");
        debug_assert_eq!(data.len() % item_len, 0);
        let n_items = data.len() / item_len;
        if self.is_serial() || n_items <= 1 {
            f(0, data);
            return;
        }
        let per = n_items.div_ceil(self.workers);
        let view = SharedMut::new(data);
        let view = &view;
        self.run_sharded(move |shard| {
            let lo = (shard * per).min(n_items);
            let hi = ((shard + 1) * per).min(n_items);
            if lo >= hi {
                return;
            }
            // SAFETY: spans [lo, hi) are disjoint across shards by
            // construction.
            let span = unsafe { view.slice_mut(lo * item_len, (hi - lo) * item_len) };
            f(lo, span);
        });
    }

    /// Shard count a background stage ([`Pool::submit_sharded`]) runs
    /// with: the spawned workers only — the calling thread is deliberately
    /// not enlisted — so `workers - 1`; 1 for a serial pool, where
    /// submission degrades to an inline call.
    pub fn stage_shards(&self) -> usize {
        if self.inner.is_some() {
            self.workers - 1
        } else {
            1
        }
    }

    /// Dispatch `f(shard)` for every `shard in 0..stage_shards()` on the
    /// background workers and return immediately, leaving the calling
    /// thread free to run a non-`Send` stage — the PJRT execute — while
    /// the pool works. The shard map must be a pure function of the data,
    /// exactly as for [`Pool::run_sharded`].
    ///
    /// The returned [`StageHandle`] owns the closure; call
    /// [`StageHandle::join`] (or drop it) before the next pool dispatch.
    /// Worker panics re-raise at `join`; a dropped-without-join handle
    /// swallows the stage's panic (re-panicking from drop would abort
    /// during an unwind) and leaves the pool clean. On a serial pool
    /// there is no background thread: `f(0)` runs inline before this
    /// returns, so the caller's stage protocol stays valid — there is
    /// simply nothing to overlap.
    pub fn submit_sharded<'p, F>(&'p self, f: F) -> StageHandle<'p>
    where
        F: Fn(usize) + Sync + 'p,
    {
        let Some(inner) = &self.inner else {
            f(0);
            return StageHandle { inner: None, _job: None, joined: true };
        };
        // Workers identify as pool shards 1..workers; shift to stage
        // shards 0..workers-1 so the caller's shard map covers exactly the
        // ids that run.
        let job: Box<dyn Fn(usize) + Sync + 'p> = Box::new(move |shard| f(shard - 1));
        let trait_obj: &(dyn Fn(usize) + Sync) = &*job;
        // SAFETY (lifetime erasure): the handle owns the boxed closure (a
        // stable heap address) and neither `join` nor `drop` returns until
        // `remaining == 0`, i.e. until no worker can touch the pointer.
        let jp = JobPtr(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(
                trait_obj,
            )
        });
        {
            let mut st = inner.state.lock().unwrap();
            assert_eq!(
                st.remaining, 0,
                "pool dispatch while another job or background stage is in flight"
            );
            st.job = Some(jp);
            st.generation = st.generation.wrapping_add(1);
            st.remaining = self.workers - 1;
            inner.work_cv.notify_all();
        }
        StageHandle { inner: Some(inner.as_ref()), _job: Some(job), joined: false }
    }
}

/// A background stage dispatched by [`Pool::submit_sharded`]. Holds the
/// stage closure alive for the workers; joining (or dropping) the handle
/// blocks until every worker has finished, which is what keeps the
/// lifetime-erased job pointer valid for the workers' whole execution.
pub struct StageHandle<'p> {
    /// None for the serial-pool inline fallback (already complete).
    inner: Option<&'p PoolInner>,
    /// Owns the closure the workers dereference (stable boxed address).
    _job: Option<Box<dyn Fn(usize) + Sync + 'p>>,
    joined: bool,
}

impl StageHandle<'_> {
    /// Block until every worker has finished the stage, then re-raise any
    /// worker panic on the calling thread.
    pub fn join(mut self) {
        self.wait();
        self.joined = true;
        if let Some(inner) = self.inner {
            let mut st = inner.state.lock().unwrap();
            if st.panicked {
                st.panicked = false;
                drop(st);
                panic!("pool worker panicked during background stage");
            }
        }
    }

    fn wait(&self) {
        if let Some(inner) = self.inner {
            let mut st = inner.state.lock().unwrap();
            while st.remaining > 0 {
                st = inner.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
    }
}

impl Drop for StageHandle<'_> {
    fn drop(&mut self) {
        // Always wait (soundness); panic propagation happens only in
        // `join` — re-panicking from drop during an unwind would abort.
        // The swallowed panic must also clear the shared flag, or the
        // *next* unrelated dispatcher would re-raise it as its own.
        if !self.joined {
            self.wait();
            if let Some(inner) = self.inner {
                inner.state.lock().unwrap().panicked = false;
            }
        }
    }
}

/// Blocks until the in-flight generation completes; runs even when the
/// dispatching closure unwinds, keeping the lifetime-erased job pointer
/// valid for every worker dereference.
struct DispatchGuard<'p> {
    inner: &'p PoolInner,
}

impl Drop for DispatchGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.inner.done_cv.wait(st).unwrap();
        }
        st.job = None;
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(inner) = &self.inner {
            let mut st = inner.state.lock().unwrap();
            st.shutdown = true;
            inner.work_cv.notify_all();
            drop(st);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Machine-checked disjointness for [`SharedMut`], behind the
/// `shared_mut_audit` cargo feature.
///
/// Every `slice_mut`/`get_mut` call records its claimed index range under
/// the claiming thread ("job"); a claim overlapping a range held by a
/// *different* thread panics immediately, naming both jobs and both
/// ranges. Claims accumulate for the lifetime of the view — every view in
/// this codebase is created for exactly one pool dispatch, so a view's
/// claim log spans one parallel job and the check is precisely the
/// documented disjointness contract. Same-thread re-claims are always
/// fine: borrows on one thread are sequential.
#[cfg(feature = "shared_mut_audit")]
mod audit {
    use std::sync::Mutex;
    use std::thread::ThreadId;

    /// All ranges claimed by one thread, sorted and coalesced.
    struct JobClaims {
        thread: ThreadId,
        /// Thread name at first claim (pool workers are `pool-N`, named
        /// spawns carry their [`super::spawn_named`] name), for diagnostics.
        name: String,
        /// Half-open `[start, end)` ranges, sorted, non-overlapping.
        ranges: Vec<(usize, usize)>,
    }

    /// Claim log for one [`super::SharedMut`] view.
    #[derive(Default)]
    pub struct AuditState {
        jobs: Mutex<Vec<JobClaims>>,
    }

    fn thread_label() -> String {
        let t = std::thread::current();
        match t.name() {
            Some(n) => n.to_string(),
            None => format!("{:?}", t.id()),
        }
    }

    /// Insert `[s, e)` into `ranges`, keeping them sorted and coalesced
    /// (touching or overlapping neighbors merge).
    fn insert_range(ranges: &mut Vec<(usize, usize)>, mut s: usize, mut e: usize) {
        let lo = ranges.partition_point(|&(_, re)| re < s);
        let mut hi = lo;
        while hi < ranges.len() && ranges[hi].0 <= e {
            s = s.min(ranges[hi].0);
            e = e.max(ranges[hi].1);
            hi += 1;
        }
        ranges.splice(lo..hi, [(s, e)]);
    }

    impl AuditState {
        /// Record a mutable claim of `[start, start + len)` by the current
        /// thread; panic if it overlaps any other thread's claim on this
        /// view.
        pub fn claim(&self, start: usize, len: usize) {
            if len == 0 {
                return;
            }
            let end = start + len;
            let me = std::thread::current().id();
            let mut jobs = self.jobs.lock().unwrap();
            for job in jobs.iter() {
                if job.thread == me {
                    continue;
                }
                // first of the other job's ranges ending after our start
                let i = job.ranges.partition_point(|&(_, re)| re <= start);
                if let Some(&(os, oe)) = job.ranges.get(i) {
                    if os < end {
                        panic!(
                            "SharedMut audit: job `{}` claims [{start}, {end}) but it \
                             overlaps [{os}, {oe}) already claimed by job `{}` on the \
                             same buffer — the shard map must give every index exactly \
                             one writer",
                            thread_label(),
                            job.name,
                        );
                    }
                }
            }
            match jobs.iter_mut().find(|j| j.thread == me) {
                Some(job) => insert_range(&mut job.ranges, start, end),
                None => jobs.push(JobClaims {
                    thread: me,
                    name: thread_label(),
                    ranges: vec![(start, end)],
                }),
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn insert_range_coalesces_neighbors() {
            let mut r = vec![(0, 4), (8, 12), (20, 24)];
            insert_range(&mut r, 4, 8); // touches both neighbors
            assert_eq!(r, vec![(0, 12), (20, 24)]);
            insert_range(&mut r, 13, 19); // strictly between
            assert_eq!(r, vec![(0, 12), (13, 19), (20, 24)]);
            insert_range(&mut r, 2, 30); // swallows everything
            assert_eq!(r, vec![(0, 30)]);
        }

        #[test]
        fn same_thread_overlap_is_not_a_violation() {
            let a = AuditState::default();
            a.claim(0, 8);
            a.claim(4, 8); // same thread: sequential borrows, fine
            a.claim(0, 1);
        }
    }
}

/// A mutable slice view shareable across pool workers.
///
/// # Safety contract
///
/// [`SharedMut::slice_mut`] / [`SharedMut::get_mut`] hand out `&mut`
/// aliases without synchronization. Callers must guarantee that concurrent
/// accesses target **disjoint index ranges** — in this codebase, by
/// sharding on `row % num_shards` (or contiguous spans) so each index has
/// exactly one writer.
///
/// Build with `--features shared_mut_audit` to machine-check that contract
/// at runtime: every claim is logged per thread and a cross-thread overlap
/// panics on the spot, naming both jobs and ranges (see [`audit`] and
/// `rust/DETERMINISM.md`).
pub struct SharedMut<'a, T> {
    ptr: *mut T,
    len: usize,
    /// Claim log for the audit feature. One log per view; views are
    /// created per pool dispatch, so the log covers exactly one job.
    #[cfg(feature = "shared_mut_audit")]
    audit: audit::AuditState,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: the wrapper holds only a pointer + length (plus, under the audit
// feature, a Mutex-guarded claim log, itself Send + Sync); sending/sharing
// it is safe because all dereferences go through the unsafe accessors
// whose disjointness contract the caller upholds.
unsafe impl<T: Send> Send for SharedMut<'_, T> {}
unsafe impl<T: Send> Sync for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            #[cfg(feature = "shared_mut_audit")]
            audit: audit::AuditState::default(),
            _marker: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable subslice `[start, start + len)`.
    ///
    /// # Safety
    /// No other thread may access an overlapping range for the lifetime of
    /// the returned borrow.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        #[cfg(feature = "shared_mut_audit")]
        {
            let end = start.checked_add(len).expect("SharedMut range overflows usize");
            assert!(
                end <= self.len,
                "SharedMut::slice_mut range [{start}, {end}) out of bounds (len {})",
                self.len
            );
            self.audit.claim(start, len);
        }
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }

    /// Mutable element reference.
    ///
    /// # Safety
    /// No other thread may access index `i` for the lifetime of the
    /// returned borrow.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        #[cfg(feature = "shared_mut_audit")]
        {
            assert!(
                i < self.len,
                "SharedMut::get_mut index {i} out of bounds (len {})",
                self.len
            );
            self.audit.claim(i, 1);
        }
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline() {
        let hits = AtomicUsize::new(0);
        Pool::serial().run_sharded(|shard| {
            assert_eq!(shard, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn run_sharded_visits_every_shard_once() {
        for workers in [1, 2, 3, 8] {
            let pool = Pool::new(workers);
            let hits: Vec<AtomicUsize> = (0..workers).map(|_| AtomicUsize::new(0)).collect();
            pool.run_sharded(|shard| {
                hits[shard].fetch_add(1, Ordering::Relaxed);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::Relaxed), 1);
            }
        }
    }

    #[test]
    fn repeated_dispatch_reuses_workers() {
        let pool = Pool::new(4);
        let hits = AtomicUsize::new(0);
        for _ in 0..200 {
            pool.run_sharded(|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 200 * 4);
    }

    #[test]
    fn for_each_span_covers_everything_in_order() {
        for workers in [1, 2, 3, 5] {
            let pool = Pool::new(workers);
            let n_items = 13;
            let item_len = 4;
            let mut buf = vec![0u32; n_items * item_len];
            pool.for_each_span(&mut buf, item_len, |first_item, span| {
                for (j, chunk) in span.chunks_exact_mut(item_len).enumerate() {
                    let item = (first_item + j) as u32;
                    for (c, v) in chunk.iter_mut().enumerate() {
                        *v = item * 100 + c as u32;
                    }
                }
            });
            for item in 0..n_items as u32 {
                for c in 0..item_len as u32 {
                    assert_eq!(buf[(item as usize) * item_len + c as usize], item * 100 + c);
                }
            }
        }
    }

    #[test]
    fn sharded_disjoint_writes_through_shared_mut() {
        let n = 997;
        for workers in [2, 4] {
            let pool = Pool::new(workers);
            let mut buf = vec![0usize; n];
            let view = SharedMut::new(&mut buf);
            let view_ref = &view;
            pool.run_sharded(move |shard| {
                for i in 0..n {
                    if i % workers == shard {
                        // SAFETY: index i is written only by shard i % workers.
                        unsafe { *view_ref.get_mut(i) = i * 2 };
                    }
                }
            });
            assert!(buf.iter().enumerate().all(|(i, &v)| v == i * 2));
        }
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = Pool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_sharded(|shard| {
                if shard == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err());
        // pool still usable afterwards
        let hits = AtomicUsize::new(0);
        pool.run_sharded(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn from_parallelism_zero_is_auto() {
        assert!(Pool::from_parallelism(0).num_workers() >= 1);
        assert_eq!(Pool::from_parallelism(3).num_workers(), 3);
    }

    #[test]
    fn submit_sharded_runs_every_stage_shard_once() {
        for workers in [1usize, 2, 3, 8] {
            let pool = Pool::new(workers);
            let n = pool.stage_shards();
            assert_eq!(n, if workers == 1 { 1 } else { workers - 1 });
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let handle = pool.submit_sharded(|shard| {
                hits[shard].fetch_add(1, Ordering::Relaxed);
            });
            handle.join();
            for h in &hits {
                assert_eq!(h.load(Ordering::Relaxed), 1);
            }
        }
    }

    #[test]
    fn submit_sharded_overlaps_with_caller_work() {
        // The stage makes progress while the calling thread is busy with
        // its own (here: trivial) work, and join synchronizes the writes.
        let pool = Pool::new(4);
        let mut buf = vec![0usize; 1000];
        let n = pool.stage_shards();
        {
            let view = SharedMut::new(&mut buf);
            let view_ref = &view;
            let handle = pool.submit_sharded(move |shard| {
                for i in 0..1000 {
                    if i % n == shard {
                        // SAFETY: index i written only by stage shard i % n.
                        unsafe { *view_ref.get_mut(i) = i + 1 };
                    }
                }
            });
            // caller-side "execute" stage
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            assert!(acc > 0);
            handle.join();
        }
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i + 1));
    }

    #[test]
    fn submit_then_run_sharded_sequence_is_clean() {
        // A joined stage leaves the pool ready for synchronous dispatches
        // (the engine's execute → join → scatter sequence).
        let pool = Pool::new(3);
        let hits = AtomicUsize::new(0);
        for _ in 0..50 {
            let h = pool.submit_sharded(|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            h.join();
            pool.run_sharded(|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(hits.load(Ordering::Relaxed), 50 * (2 + 3));
    }

    #[test]
    fn stage_panic_propagates_at_join() {
        let pool = Pool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let h = pool.submit_sharded(|shard| {
                if shard == 0 {
                    panic!("stage boom");
                }
            });
            h.join();
        }));
        assert!(result.is_err());
        // pool still usable afterwards
        let hits = AtomicUsize::new(0);
        pool.run_sharded(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn dropped_panicked_stage_does_not_poison_next_dispatch() {
        // Regression: a StageHandle dropped without join used to leave the
        // shared `panicked` flag set, so the *next* unrelated dispatcher
        // re-raised a panic that wasn't its own.
        let pool = Pool::new(3);
        {
            let h = pool.submit_sharded(|shard| {
                if shard == 0 {
                    panic!("dropped stage boom");
                }
            });
            drop(h); // swallow by design — but must leave the pool clean
        }
        let hits = AtomicUsize::new(0);
        pool.run_sharded(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 3, "next dispatch ran clean");
        // and an explicitly joined panicking stage still propagates
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.submit_sharded(|shard| {
                if shard == 1 {
                    panic!("joined stage boom");
                }
            })
            .join();
        }));
        assert!(result.is_err(), "join still re-raises worker panics");
    }

    #[test]
    fn serial_pool_stage_runs_inline() {
        let pool = Pool::serial();
        let hits = AtomicUsize::new(0);
        let h = pool.submit_sharded(|shard| {
            assert_eq!(shard, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        // inline fallback: complete before join
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        h.join();
    }
}
