//! Unix-socket line transport shared by the serving daemon and the
//! distributed-training coordinator.
//!
//! Both subsystems speak newline-delimited text over a Unix socket with
//! the same shape: one acceptor thread hands each connection an integer
//! id, a named reader thread per connection pumps its lines into one
//! channel, and a writer registry (keyed by connection id, ordered so
//! iteration is deterministic) routes responses back to the connection
//! that asked. [`LineServer`] packages that plumbing; [`LineClient`] is
//! the matching client side. Writers are removed on EOF, and the socket
//! file is removed on [`LineServer::shutdown`].

use std::sync::mpsc::Receiver;

/// One unit of transport input: a line from a connected client, or a
/// shutdown request (e.g. stdin EOF in the daemon's stdin mode).
#[derive(Clone, Debug)]
pub enum Inbound {
    Line { client: usize, line: String },
    Shutdown,
}

/// One receive attempt on a [`LineClient`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Recv {
    Line(String),
    Timeout,
    /// The server hung up (reader thread saw EOF and exited).
    Closed,
}

#[cfg(unix)]
pub use unix_impl::{LineClient, LineServer};

#[cfg(unix)]
mod unix_impl {
    use super::{Inbound, Recv};
    use crate::utils::pool::spawn_named;
    use anyhow::{Context, Result};
    use std::collections::BTreeMap;
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::{UnixListener, UnixStream};
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
    use std::sync::{Arc, Mutex};
    use std::thread::JoinHandle;
    use std::time::Duration;

    /// A line-protocol Unix-socket server: accepts connections on a named
    /// acceptor thread, reads each connection on its own named thread into
    /// one [`Inbound`] channel, and writes responses back through a
    /// per-connection writer registry (removed on EOF).
    pub struct LineServer {
        rx: Receiver<Inbound>,
        writers: Arc<Mutex<BTreeMap<usize, UnixStream>>>,
        stop: Arc<AtomicBool>,
        acceptor: Option<JoinHandle<()>>,
        path: PathBuf,
    }

    impl LineServer {
        /// Bind `path` (removing a stale socket file first) and start the
        /// acceptor. Connection ids count up from 0 in accept order.
        pub fn bind(path: &Path) -> Result<Self> {
            if path.exists() {
                std::fs::remove_file(path)
                    .with_context(|| format!("remove stale socket {path:?}"))?;
            }
            let listener =
                UnixListener::bind(path).with_context(|| format!("bind unix socket {path:?}"))?;
            listener
                .set_nonblocking(true)
                .context("set socket listener non-blocking")?;
            let stop = Arc::new(AtomicBool::new(false));
            let writers: Arc<Mutex<BTreeMap<usize, UnixStream>>> =
                Arc::new(Mutex::new(BTreeMap::new()));
            let (tx, rx) = mpsc::channel();
            let acceptor = {
                let stop = stop.clone();
                let writers = writers.clone();
                spawn_named("socket-accept", move || {
                    let mut next_client = 0usize;
                    while !stop.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let client = next_client;
                                next_client += 1;
                                if let Ok(writer) = stream.try_clone() {
                                    writers.lock().unwrap().insert(client, writer);
                                }
                                let tx = tx.clone();
                                let writers = writers.clone();
                                let _ =
                                    spawn_named(&format!("socket-client-{client}"), move || {
                                        for line in BufReader::new(stream).lines() {
                                            let Ok(line) = line else { break };
                                            let msg = Inbound::Line { client, line };
                                            if tx.send(msg).is_err() {
                                                break;
                                            }
                                        }
                                        writers.lock().unwrap().remove(&client);
                                    });
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(20));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .context("spawn socket acceptor")?
            };
            Ok(Self { rx, writers, stop, acceptor: Some(acceptor), path: path.to_path_buf() })
        }

        /// The inbound line channel (one [`Inbound::Line`] per received
        /// line, across all connections).
        pub fn rx(&self) -> &Receiver<Inbound> {
            &self.rx
        }

        /// Write one response line to a connection. Returns `false` when
        /// the connection is gone (EOF removed its writer) or the write
        /// failed.
        pub fn send(&self, client: usize, line: &str) -> bool {
            let mut writers = self.writers.lock().unwrap();
            match writers.get_mut(&client) {
                Some(w) => writeln!(w, "{line}").is_ok(),
                None => false,
            }
        }

        /// Connected client ids, ascending (deterministic broadcast order).
        pub fn clients(&self) -> Vec<usize> {
            self.writers.lock().unwrap().keys().copied().collect()
        }

        /// Stop accepting, reap the acceptor, and remove the socket file.
        /// Per-connection reader threads exit on their own at EOF.
        pub fn shutdown(mut self) {
            self.stop.store(true, Ordering::SeqCst);
            if let Some(h) = self.acceptor.take() {
                let _ = h.join();
            }
            std::fs::remove_file(&self.path).ok();
        }
    }

    impl Drop for LineServer {
        fn drop(&mut self) {
            // best-effort: unblocks the acceptor if shutdown() was skipped
            self.stop.store(true, Ordering::SeqCst);
        }
    }

    /// A line-protocol Unix-socket client: writes lines synchronously,
    /// receives on a named reader thread feeding a channel.
    pub struct LineClient {
        stream: UnixStream,
        rx: Receiver<String>,
    }

    impl LineClient {
        pub fn connect(path: &Path) -> Result<Self> {
            let stream = UnixStream::connect(path)
                .with_context(|| format!("connect unix socket {path:?}"))?;
            let reader = stream.try_clone().context("clone socket for reading")?;
            let (tx, rx) = mpsc::channel();
            spawn_named("socket-line-reader", move || {
                for line in BufReader::new(reader).lines() {
                    let Ok(line) = line else { break };
                    if tx.send(line).is_err() {
                        break;
                    }
                }
            })
            .context("spawn socket line reader")?;
            Ok(Self { stream, rx })
        }

        /// Poll-connect until the server binds (it may still be starting):
        /// up to `attempts` tries, `sleep_ms` apart.
        pub fn connect_retry(path: &Path, attempts: usize, sleep_ms: u64) -> Result<Self> {
            for _ in 1..attempts.max(1) {
                if let Ok(client) = Self::connect(path) {
                    return Ok(client);
                }
                std::thread::sleep(Duration::from_millis(sleep_ms));
            }
            Self::connect(path)
        }

        /// Write one line (newline appended) and flush.
        pub fn send(&mut self, line: &str) -> Result<()> {
            writeln!(self.stream, "{line}").context("write line to socket")?;
            self.stream.flush().context("flush socket line")
        }

        /// Wait up to `ms` milliseconds for the next line.
        pub fn recv_timeout(&self, ms: u64) -> Recv {
            match self.rx.recv_timeout(Duration::from_millis(ms)) {
                Ok(line) => Recv::Line(line),
                Err(RecvTimeoutError::Timeout) => Recv::Timeout,
                Err(RecvTimeoutError::Disconnected) => Recv::Closed,
            }
        }

        /// Drain any already-received line without waiting.
        pub fn try_recv(&self) -> Option<String> {
            self.rx.try_recv().ok()
        }
    }
}

/// Drain every immediately available message from an inbound channel
/// (non-blocking). Shared by transports that batch their reads.
pub fn drain_ready(rx: &Receiver<Inbound>) -> Vec<Inbound> {
    let mut out = Vec::new();
    while let Ok(msg) = rx.try_recv() {
        out.push(msg);
    }
    out
}

/// Round-trip smoke coverage lives in `serve/daemon.rs` (the socket
/// daemon test) and `tests/dist_parity.rs` (the coordinator socket
/// test); this module's unit tests cover only what needs no socket.
#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_socket(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("repro-transport-{tag}-{}.sock", std::process::id()));
        p
    }

    #[test]
    fn server_binds_reaps_and_removes_socket_file() {
        let path = tmp_socket("bind");
        let server = LineServer::bind(&path).unwrap();
        assert!(path.exists(), "socket file must exist while bound");
        assert!(server.clients().is_empty());
        assert!(!server.send(0, "nobody home"), "send to absent client is false");
        server.shutdown();
        assert!(!path.exists(), "socket file must be removed on shutdown");
    }

    #[test]
    fn stale_socket_file_is_replaced_on_bind() {
        let path = tmp_socket("stale");
        std::fs::write(&path, b"stale").unwrap();
        let server = LineServer::bind(&path).unwrap();
        server.shutdown();
        assert!(!path.exists());
    }

    #[test]
    fn client_line_round_trip() {
        let path = tmp_socket("echo");
        let server = LineServer::bind(&path).unwrap();
        let mut client = LineClient::connect_retry(&path, 50, 10).unwrap();
        client.send("ping").unwrap();
        let got = server
            .rx()
            .recv_timeout(std::time::Duration::from_secs(5))
            .expect("server receives the line");
        match got {
            Inbound::Line { client: id, line } => {
                assert_eq!(line, "ping");
                // the writer registry routes the reply back
                let mut ok = false;
                for _ in 0..100 {
                    if server.send(id, "pong") {
                        ok = true;
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                assert!(ok, "writer registered for the connection");
            }
            other => panic!("expected a line, got {other:?}"),
        }
        assert_eq!(client.recv_timeout(5000), Recv::Line("pong".to_string()));
        server.shutdown();
        // server side gone: the reader thread sees EOF and hangs up
        for _ in 0..200 {
            if client.recv_timeout(10) == Recv::Closed {
                return;
            }
        }
        panic!("client never observed the hangup");
    }
}
