//! Literal construction/extraction helpers.
//!
//! `Literal::create_from_shape_and_untyped_data` copies straight from the
//! host slice (no element-wise conversion), which keeps the hot path's
//! literal creation at memcpy speed. [`LitScratch`] goes one step further
//! for the step engine: step inputs are recycled after execute and the
//! next literal of the same byte size reuses the retired literal's storage
//! in place of a fresh allocation, so steady-state literal creation is
//! allocation-free.

use anyhow::{Context, Result};

/// Check `data`'s element count against `dims` and view it as raw bytes
/// (single home of the validation + unsafe cast for every literal
/// constructor in this module).
fn checked_bytes<T>(data: &[T], dims: &[usize], what: &str) -> Result<&[u8]> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "{what}: {} elements for dims {dims:?}", data.len());
    // SAFETY: any initialized slice is readable as its raw bytes; the
    // length is the slice's exact byte size.
    Ok(unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    })
}

/// f32 literal with the given dims from a host slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = checked_bytes(data, dims, "lit_f32")?;
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .context("create f32 literal")
}

/// i32 literal with the given dims from a host slice.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = checked_bytes(data, dims, "lit_i32")?;
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .context("create i32 literal")
}

/// Copy a literal out as f32s.
pub fn read_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("read f32 literal")
}

/// Copy a literal out as i32s.
pub fn read_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("read i32 literal")
}

/// Copy a literal into an existing f32 buffer (avoids an allocation).
pub fn read_f32_into(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to::<f32>(out).context("copy f32 literal")
}

/// Recycling pool for step-input literals (module docs).
///
/// The step engine returns each step's inputs via [`LitScratch::recycle`]
/// after the execute; [`LitScratch::lit_f32`] / [`LitScratch::lit_i32`]
/// then refill a retired literal of the same byte size in place
/// (`Literal::refill_untyped`, a host-stub extension of the vendored
/// `xla`; against the real crate this degrades to per-call creation).
/// Step shapes repeat every step, so the free list stays tiny and
/// steady-state literal creation performs zero allocations.
#[derive(Default)]
pub struct LitScratch {
    free: Vec<xla::Literal>,
    /// Fresh literal allocations (the fallback when no retired literal of
    /// the right byte size is available). The pipelined step engine's
    /// zero-allocation claim is asserted against this counter: after
    /// warmup, steady-state steps must not advance it.
    created: u64,
}

impl LitScratch {
    pub fn new() -> Self {
        Self { free: Vec::new(), created: 0 }
    }

    /// f32 literal with the given dims, reusing retired storage if a
    /// same-size literal is available.
    pub fn lit_f32(&mut self, data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let bytes = checked_bytes(data, dims, "lit_f32")?;
        self.refill(xla::ElementType::F32, dims, bytes)
    }

    /// i32 literal with the given dims, reusing retired storage if a
    /// same-size literal is available.
    pub fn lit_i32(&mut self, data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let bytes = checked_bytes(data, dims, "lit_i32")?;
        self.refill(xla::ElementType::S32, dims, bytes)
    }

    /// Return a retired literal's storage to the pool.
    pub fn recycle(&mut self, lit: xla::Literal) {
        self.free.push(lit);
    }

    /// Bulk-recycle a donated input set (the literals an
    /// `execute_donated`-style call hands back after the device is done
    /// with them); the next step's refills reuse their storage in place.
    pub fn donate(&mut self, lits: impl IntoIterator<Item = xla::Literal>) {
        self.free.extend(lits);
    }

    /// Retired literals currently available for reuse.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Fresh literal allocations performed so far (refills excluded).
    pub fn created_count(&self) -> u64 {
        self.created
    }

    fn refill(
        &mut self,
        ty: xla::ElementType,
        dims: &[usize],
        bytes: &[u8],
    ) -> Result<xla::Literal> {
        // Exact byte-size match keeps refills at pure memcpy (no regrow);
        // both element types here are 4 bytes wide, so retyping is free.
        let pos = self
            .free
            .iter()
            .position(|l| l.element_count() * l.element_type().byte_size() == bytes.len());
        match pos {
            Some(i) => {
                let mut lit = self.free.swap_remove(i);
                lit.refill_untyped(ty, dims, bytes).context("refill literal")?;
                Ok(lit)
            }
            None => {
                self.created += 1;
                xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
                    .context("create literal")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(read_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(read_i32(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn read_into_buffer() {
        let data = vec![7.0f32; 8];
        let lit = lit_f32(&data, &[8]).unwrap();
        let mut out = vec![0f32; 8];
        read_f32_into(&lit, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn scratch_recycles_same_size_literals() {
        let mut scratch = LitScratch::new();
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let lit = scratch.lit_f32(&a, &[2, 2]).unwrap();
        assert_eq!(read_f32(&lit).unwrap(), a);
        scratch.recycle(lit);
        assert_eq!(scratch.free_count(), 1);
        // same byte size: reuses the retired literal (free list drains)
        let b = vec![9.0f32, 8.0, 7.0, 6.0];
        let lit2 = scratch.lit_f32(&b, &[4]).unwrap();
        assert_eq!(scratch.free_count(), 0);
        assert_eq!(read_f32(&lit2).unwrap(), b);
        assert_eq!(lit2.dims(), &[4]);
        scratch.recycle(lit2);
        // different byte size: fresh creation, free list untouched
        let c = vec![1.0f32; 6];
        let lit3 = scratch.lit_f32(&c, &[6]).unwrap();
        assert_eq!(scratch.free_count(), 1);
        assert_eq!(read_f32(&lit3).unwrap(), c);
    }

    #[test]
    fn scratch_retypes_between_f32_and_i32() {
        let mut scratch = LitScratch::new();
        let lit = scratch.lit_f32(&[1.5f32, -2.5], &[2]).unwrap();
        scratch.recycle(lit);
        let ints = scratch.lit_i32(&[3i32, -4], &[2]).unwrap();
        assert_eq!(scratch.free_count(), 0, "4-byte-wide retype reuses the buffer");
        assert_eq!(read_i32(&ints).unwrap(), vec![3, -4]);
    }

    #[test]
    fn scratch_checks_shapes() {
        let mut scratch = LitScratch::new();
        assert!(scratch.lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(scratch.lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scratch_counts_only_fresh_creations() {
        let mut scratch = LitScratch::new();
        let a = scratch.lit_f32(&[1.0, 2.0], &[2]).unwrap();
        assert_eq!(scratch.created_count(), 1);
        scratch.recycle(a);
        let b = scratch.lit_f32(&[3.0, 4.0], &[2]).unwrap();
        assert_eq!(scratch.created_count(), 1, "refill must not count as a creation");
        scratch.recycle(b);
        let _c = scratch.lit_f32(&[1.0; 5], &[5]).unwrap();
        assert_eq!(scratch.created_count(), 2, "size miss falls back to creation");
    }

    #[test]
    fn donated_then_refilled_matches_fresh_bitwise() {
        // A literal that went through donate -> refill must be
        // byte-identical to one created fresh from the same data.
        let mut scratch = LitScratch::new();
        let step1 = vec![scratch.lit_f32(&[0.5f32; 4], &[4]).unwrap()];
        scratch.donate(step1); // execute(t) hands its inputs back
        assert_eq!(scratch.free_count(), 1);
        let data = vec![1.25f32, -2.5, 3.75, 0.0625];
        let refilled = scratch.lit_f32(&data, &[2, 2]).unwrap();
        assert_eq!(scratch.free_count(), 0, "refill must consume the donated literal");
        assert_eq!(scratch.created_count(), 1, "only the warmup literal was allocated");
        let fresh = lit_f32(&data, &[2, 2]).unwrap();
        assert_eq!(refilled.element_type(), fresh.element_type());
        assert_eq!(refilled.dims(), fresh.dims());
        assert_eq!(
            read_f32(&refilled).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            read_f32(&fresh).unwrap().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }
}
