//! Literal construction/extraction helpers.
//!
//! `Literal::create_from_shape_and_untyped_data` copies straight from the
//! host slice (no element-wise conversion), which keeps the hot path's
//! literal creation at memcpy speed.

use anyhow::{Context, Result};

/// f32 literal with the given dims from a host slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "lit_f32: {} elements for dims {dims:?}", data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .context("create f32 literal")
}

/// i32 literal with the given dims from a host slice.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "lit_i32: {} elements for dims {dims:?}", data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .context("create i32 literal")
}

/// Copy a literal out as f32s.
pub fn read_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("read f32 literal")
}

/// Copy a literal out as i32s.
pub fn read_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("read i32 literal")
}

/// Copy a literal into an existing f32 buffer (avoids an allocation).
pub fn read_f32_into(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to::<f32>(out).context("copy f32 literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(read_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(read_i32(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn read_into_buffer() {
        let data = vec![7.0f32; 8];
        let lit = lit_f32(&data, &[8]).unwrap();
        let mut out = vec![0f32; 8];
        read_f32_into(&lit, &mut out).unwrap();
        assert_eq!(out, data);
    }
}
