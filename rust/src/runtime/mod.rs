//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Python is never involved at runtime; the [`Registry`] compiles every
//! artifact once per process and hands out shape-checked handles.

pub mod literal;
pub mod manifest;

pub use literal::{lit_f32, lit_i32, read_f32, read_f32_into, read_i32, LitScratch};
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One compiled executable plus its manifest metadata.
pub struct Executable {
    pub name: String,
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with shape-checked literals; returns the flattened output
    /// tuple in manifest order.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        #[cfg(debug_assertions)]
        for (i, (lit, spec)) in inputs.iter().zip(self.meta.inputs.iter()).enumerate() {
            let n: usize = spec.shape.iter().product::<usize>().max(1);
            if lit.element_count() != n {
                bail!(
                    "{}: input {i} has {} elements, expected {} (shape {:?})",
                    self.name,
                    lit.element_count(),
                    n,
                    spec.shape
                );
            }
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.name))?;
        let outs = result
            .to_tuple()
            .with_context(|| format!("untuple result of {}", self.name))?;
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name,
                outs.len(),
                self.meta.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Donation-aware [`Executable::run`]: the inputs are handed to the
    /// runtime by value (`PjRtLoadedExecutable::execute_donated`), letting
    /// the device alias their allocations for the outputs instead of
    /// round-tripping fresh buffers. Returns `(outputs, donated)` where
    /// `donated` holds any input literals the runtime handed back for
    /// host-side reuse (empty when the device consumed them — real PJRT
    /// aliases them into the outputs). On an execute error the inputs are
    /// consumed; callers refill their scratch from fresh allocations on
    /// the (non-steady-state) failure path.
    pub fn run_donated(
        &self,
        inputs: Vec<xla::Literal>,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let bufs = self
            .exe
            .execute_donated(inputs)
            .map_err(|(e, _donated)| anyhow::Error::new(e))
            .with_context(|| format!("execute (donated) {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.name))?;
        let outs = result
            .to_tuple()
            .with_context(|| format!("untuple result of {}", self.name))?;
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name,
                outs.len(),
                self.meta.outputs.len()
            );
        }
        Ok((outs, Vec::new()))
    }
}

/// Loads the manifest, compiles all artifacts once, and serves handles.
pub struct Registry {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    // BTreeMap so `names()` and error messages list artifacts in a
    // deterministic (sorted) order, not hash order
    executables: BTreeMap<String, Arc<Executable>>,
    dir: PathBuf,
}

impl Registry {
    /// Open `artifacts/` (or another directory) and compile everything in
    /// its manifest eagerly. Compilation is a one-time per-process cost.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("load manifest from {dir:?} — run `make artifacts`?"))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for (name, meta) in &manifest.artifacts {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {name}"))?;
            executables.insert(
                name.clone(),
                Arc::new(Executable { name: name.clone(), meta: meta.clone(), exe }),
            );
        }
        Ok(Self { client, manifest, executables, dir: dir.to_path_buf() })
    }

    /// Default artifact directory: `$REPRO_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("REPRO_ARTIFACTS") {
            return PathBuf::from(d);
        }
        // walk up from cwd looking for artifacts/manifest.json
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Open the default artifact directory.
    pub fn open_default() -> Result<Self> {
        Self::open(&Self::default_dir())
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Handle for a named artifact.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        self.executables
            .get(name)
            .cloned()
            .with_context(|| {
                format!(
                    "artifact {name:?} not in manifest (have: {:?})",
                    self.executables.keys().collect::<Vec<_>>()
                )
            })
    }

    /// Find the unique artifact whose name starts with `prefix`.
    pub fn get_by_prefix(&self, prefix: &str) -> Result<Arc<Executable>> {
        let mut hits: Vec<&String> = self
            .executables
            .keys()
            .filter(|k| k.starts_with(prefix))
            .collect();
        match hits.len() {
            1 => self.get(hits.pop().unwrap()),
            0 => bail!("no artifact matching prefix {prefix:?}"),
            _ => bail!("ambiguous prefix {prefix:?}: {hits:?}"),
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}
