//! Typed view of `artifacts/manifest.json` written by `aot.py`.

use crate::utils::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Shape + dtype of one tensor operand.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            shape: v.get("shape")?.to_vec_usize()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<Self> {
        let tensors = |key: &str| -> Result<Vec<TensorMeta>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(TensorMeta::from_json)
                .collect()
        };
        Ok(Self {
            file: v.get("file")?.as_str()?.to_string(),
            sha256: v
                .opt("sha256")
                .and_then(|s| s.as_str().ok())
                .unwrap_or("")
                .to_string(),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
        })
    }
}

/// Shape constants the artifacts were lowered with.
#[derive(Clone, Debug, Default)]
pub struct ManifestShapes {
    pub train_b: usize,
    pub eval_b: usize,
    pub feat_k: usize,
    pub aux_k: usize,
    pub eval_c: usize,
    pub eval_ca: usize,
    pub softmax_c: usize,
}

impl ManifestShapes {
    fn from_json(v: &Json) -> Result<Self> {
        let g = |k: &str| -> Result<usize> { v.get(k)?.as_usize() };
        Ok(Self {
            train_b: g("train_b")?,
            eval_b: g("eval_b")?,
            feat_k: g("feat_k")?,
            aux_k: g("aux_k")?,
            eval_ca: v.opt("eval_ca").map(|x| x.as_usize()).transpose()?.unwrap_or(0),
            eval_c: g("eval_c")?,
            softmax_c: g("softmax_c")?,
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format: String,
    pub version: u64,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub shapes: ManifestShapes,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parse manifest.json")?;
        let format = v.get("format")?.as_str()?.to_string();
        anyhow::ensure!(format == "hlo-text", "unsupported format {format:?}");
        let mut artifacts = BTreeMap::new();
        for (name, meta) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactMeta::from_json(meta).with_context(|| format!("artifact {name}"))?,
            );
        }
        Ok(Self {
            format,
            version: v.get("version")?.as_u64()?,
            artifacts,
            shapes: ManifestShapes::from_json(v.get("shapes")?)?,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text",
        "version": 1,
        "artifacts": {
            "ns_grad_B256_K64": {
                "file": "ns_grad_B256_K64.hlo.txt",
                "sha256": "abc",
                "inputs": [{"shape": [256, 64], "dtype": "float32"},
                           {"shape": [1], "dtype": "float32"}],
                "outputs": [{"shape": [256], "dtype": "float32"}]
            }
        },
        "shapes": {"train_b": 256, "eval_b": 256, "feat_k": 64,
                   "aux_k": 16, "eval_c": 2048, "eval_ca": 2048,
                   "softmax_c": 4096}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let a = &m.artifacts["ns_grad_B256_K64"];
        assert_eq!(a.inputs[0].shape, vec![256, 64]);
        assert_eq!(a.inputs[0].num_elements(), 256 * 64);
        assert_eq!(a.outputs.len(), 1);
        assert_eq!(m.shapes.feat_k, 64);
        assert_eq!(m.shapes.eval_ca, 2048);
    }

    #[test]
    fn scalar_tensor_has_one_element() {
        let t = TensorMeta { shape: vec![], dtype: "float32".into() };
        assert_eq!(t.num_elements(), 1);
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_file_fails_gracefully() {
        assert!(Manifest::load(Path::new("/nonexistent/manifest.json")).is_err());
    }
}
