//! Small dense linear algebra used by the auxiliary model and preprocessing.
//!
//! Dimensions here are tiny (k ≤ 64 for the auxiliary model, K ≤ a few
//! hundred for PCA covariances), so plain row-major loops beat any BLAS
//! round-trip; the heavy O(N·C·K) work lives in the HLO artifacts instead.
//!
//! # Canonical reduction order
//!
//! [`dot`] fixes one floating-point reduction order (4 stride-4 lane
//! accumulators, final reduce `(s0+s2)+(s1+s3)`, sequential tail) and the
//! tree's SIMD-width kernels ([`crate::tree::TreeKernel`]) reproduce that
//! exact order per node, so the lane-major batch paths are bit-identical
//! to the retained scalar walkers. The same contract covers the fused
//! sigmoid/log-sigmoid kernels below: [`sig_terms`] / [`log_sigmoid_pair`]
//! and their 8-lane structure-of-arrays twins evaluate the identical
//! per-lane IEEE operation sequence, so scalar and vectorized descents
//! agree to the last bit at every `parallelism` setting.

pub mod pca;
pub mod solve;

pub use pca::Pca;
pub use solve::solve_spd;

/// Dot product in the canonical reduction order: 4 stride-4 accumulators
/// (`s_i` sums terms `t ≡ i (mod 4)`), final reduce `(s0+s2)+(s1+s3)` (the
/// order a 4-wide SIMD horizontal reduce produces), then the `len % 4`
/// tail added sequentially. Every tree activation — scalar walkers and the
/// blocked [`crate::tree::TreeKernel`] paths alike — goes through this
/// order, which is what makes them bit-identical.
///
/// Contract: `a.len() == b.len()`. Checked in debug builds only; a
/// release-mode mismatch truncates to the shorter slice (the iterator
/// form trades the old bounds-check panic for check-free codegen on the
/// hottest loop in the crate).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 += x[0] * y[0];
        s1 += x[1] * y[1];
        s2 += x[2] * y[2];
        s3 += x[3] * y[3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// Canonical sequential f64 sum: one accumulator, strictly in iteration
/// order. This is the reduction order every accumulation outside the hot
/// dot-product path already used (`iter().sum()` is specified to fold
/// left-to-right), centralized here so repro-lint's `float-reduce` rule
/// can deny ad-hoc reductions everywhere else without changing a bit of
/// any existing result.
#[inline]
pub fn sum_f64(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut s = 0f64;
    for x in xs {
        s += x;
    }
    s
}

/// Canonical sequential f32 sum (see [`sum_f64`]).
#[inline]
pub fn sum_f32(xs: impl IntoIterator<Item = f32>) -> f32 {
    let mut s = 0f32;
    for x in xs {
        s += x;
    }
    s
}

/// Sequential-order f64 dot product. Unlike the f32 [`dot`], the f64 dots
/// live on cold control paths (Newton steps, split objectives) whose
/// existing code summed terms strictly left-to-right — this keeps that
/// order, bit for bit.
///
/// Contract: `a.len() == b.len()` (debug-checked; release truncates to the
/// shorter slice, matching [`dot`]).
#[inline]
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f64;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Sequential-order mixed dot `Σ a[i] * (b[i] as f64)` for f64 weight
/// vectors against f32 features (tree-fit Newton/objective paths). Same
/// order contract as [`dot_f64`].
#[inline]
pub fn dot_f64_f32(a: &[f64], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f64;
    for (x, y) in a.iter().zip(b) {
        s += x * (*y as f64);
    }
    s
}

/// Tiled batch of affine row scores: for every row `i` of `w` (`[rows, k]`
/// row-major, `rows = b.len()`) and every example `j` of `xs` (`[m, k]`),
///
/// `out[j * out_stride + out_offset + i] = dot(w_i, x_j) + b[i]`.
///
/// This is the nodes×k · k×m GEMM-like kernel behind the tree's batched
/// activation sweep: examples are tiled in blocks of 8 with the row loop
/// outside, so each weight row is streamed from memory once per 8 examples
/// instead of once per example, while the tile's `x` rows stay L1-resident.
/// Each individual score uses the canonical [`dot`] order, so the result is
/// bit-identical to the naive per-example loop.
#[allow(clippy::too_many_arguments)]
pub fn affine_dots_tile(
    w: &[f32],
    b: &[f32],
    k: usize,
    xs: &[f32],
    m: usize,
    out: &mut [f32],
    out_stride: usize,
    out_offset: usize,
) {
    let rows = b.len();
    debug_assert_eq!(w.len(), rows * k);
    debug_assert_eq!(xs.len(), m * k);
    const EXAMPLE_TILE: usize = 8;
    let mut jt = 0;
    while jt < m {
        let jhi = (jt + EXAMPLE_TILE).min(m);
        for (i, (wr, &bi)) in w.chunks_exact(k).zip(b.iter()).enumerate() {
            for j in jt..jhi {
                out[j * out_stride + out_offset + i] = dot(wr, &xs[j * k..(j + 1) * k]) + bi;
            }
        }
        jt = jhi;
    }
}

// ---------------------------------------------------------------------------
// Quantized row storage (serving hot path)
// ---------------------------------------------------------------------------
//
// Serving carries no optimizer state, so classifier rows can be stored at
// reduced precision and decoded on the fly: half the (memory-bound) bytes
// per O(kC) scoring sweep for f16, a quarter for i8 + per-row scale.
// Accumulation stays f32 in the canonical [`dot`] order.
//
// Determinism contract: the decode-inline kernels below are **bit-identical
// to dequantize-then-score** — `dot_f16(q, x) == dot(decode(q), x)` and
// `dot_i8(q, s, x) == dot(dequant(q, s), x)` exactly, because the decoded
// value enters the identical IEEE operation sequence. The quantize step
// itself (f32 → f16 round-to-nearest-even, f32 → i8 symmetric per-row
// scale) is the only place precision is spent, and it is deterministic and
// platform-independent. `score::Scorer` pins quantize-then-score scalar
// oracles on top of this contract.

/// Decode IEEE 754 binary16 bits to f32. Exact for zeros, subnormals, and
/// normals (the payload shift plus the 2¹¹² magic multiply are power-of-two
/// rescales with no rounding). f16 infinities/NaNs — which
/// [`f16_from_f32`] never produces — decode to large finite values, so the
/// serving path is total on finite rows.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let mag = f32::from_bits(((h & 0x7fff) as u32) << 13) * f32::from_bits(0x7780_0000);
    f32::from_bits(mag.to_bits() | sign)
}

/// Encode f32 as IEEE 754 binary16 bits, round-to-nearest-even. Overflow
/// saturates to ±65504 (f16 max) instead of infinity and NaN maps to the
/// canonical quiet NaN, so `f16_to_f32 ∘ f16_from_f32` is total and
/// monotone on finite inputs. Cold path: runs once per row at model load,
/// never inside a scoring sweep.
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let man = bits & 0x007f_ffff;
    if exp == 128 {
        // NaN → canonical quiet NaN; ±inf saturates like overflow
        return if man != 0 { sign | 0x7e00 } else { sign | 0x7bff };
    }
    if exp > 15 {
        return sign | 0x7bff; // |x| ≥ 2^16: saturate to f16 max
    }
    if exp >= -14 {
        // f16 normal range: drop 13 mantissa bits with round-to-nearest-even
        let mant = man >> 13;
        let rest = man & 0x1fff;
        let mut h = (sign as u32) | (((exp + 15) as u32) << 10) | mant;
        if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
            h += 1; // mantissa carry may bump the exponent — correct RNE
            if (h & 0x7fff) >= 0x7c00 {
                h = (sign as u32) | 0x7bff; // rounded past max: saturate
            }
        }
        h as u16
    } else if exp >= -25 {
        // f16 subnormal range (including values that round up to the
        // smallest subnormal): shift out the implicit bit too
        let man = man | 0x0080_0000;
        let shift = (13 - 14 - exp) as u32;
        let mant = man >> shift;
        let rest = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = (sign as u32) | mant;
        if rest > halfway || (rest == halfway && (mant & 1) == 1) {
            h += 1; // may carry into the normal range — still correct
        }
        h as u16
    } else {
        sign // underflow to signed zero
    }
}

/// Symmetric per-row i8 quantization: `scale = max|row| / 127`, elements
/// round to nearest (ties away from zero, `f32::round`), so
/// `dequant(q, scale) = q as f32 * scale` covers the row's full range.
/// Returns the scale (0.0 for an all-zero row — every element quantizes
/// to 0 and dequantizes exactly). Cold path, once per row at model load.
pub fn quantize_row_i8(row: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), q.len());
    let max_abs = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 {
        q.iter_mut().for_each(|v| *v = 0);
        return 0.0;
    }
    let scale = max_abs / 127.0;
    let inv = 127.0 / max_abs;
    for (qi, &v) in q.iter_mut().zip(row.iter()) {
        *qi = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// [`dot`] with on-the-fly f16 decode of `a`: identical 4-accumulator
/// reduction, each term `f16_to_f32(a[t]) * b[t]`. Bit-identical to
/// `dot(decoded_a, b)`.
#[inline]
pub fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 += f16_to_f32(x[0]) * y[0];
        s1 += f16_to_f32(x[1]) * y[1];
        s2 += f16_to_f32(x[2]) * y[2];
        s3 += f16_to_f32(x[3]) * y[3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += f16_to_f32(*x) * y;
    }
    s
}

/// [`dot`] with on-the-fly i8 dequantization of `a` at per-row `scale`:
/// each term `(a[t] as f32 * scale) * b[t]`, so the result is bit-identical
/// to `dot(dequantized_a, b)`.
#[inline]
pub fn dot_i8(a: &[i8], scale: f32, b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 += (x[0] as f32 * scale) * y[0];
        s1 += (x[1] as f32 * scale) * y[1];
        s2 += (x[2] as f32 * scale) * y[2];
        s3 += (x[3] as f32 * scale) * y[3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += (*x as f32 * scale) * y;
    }
    s
}

/// [`affine_dots_tile`] over f16-stored rows: same example tiling, with
/// each weight row decoded once per tile into a scratch buffer (one Vec
/// allocation per call) and the inner loop running the canonical [`dot`]
/// on the decoded row — bit-identical to `affine_dots_tile` over a fully
/// decoded matrix, at half the bytes streamed per sweep.
#[allow(clippy::too_many_arguments)]
pub fn affine_dots_tile_f16(
    w: &[u16],
    b: &[f32],
    k: usize,
    xs: &[f32],
    m: usize,
    out: &mut [f32],
    out_stride: usize,
    out_offset: usize,
) {
    let rows = b.len();
    debug_assert_eq!(w.len(), rows * k);
    debug_assert_eq!(xs.len(), m * k);
    const EXAMPLE_TILE: usize = 8;
    let mut rowbuf = vec![0f32; k];
    let mut jt = 0;
    while jt < m {
        let jhi = (jt + EXAMPLE_TILE).min(m);
        for (i, (wr, &bi)) in w.chunks_exact(k).zip(b.iter()).enumerate() {
            for (d, &h) in rowbuf.iter_mut().zip(wr.iter()) {
                *d = f16_to_f32(h);
            }
            for j in jt..jhi {
                out[j * out_stride + out_offset + i] =
                    dot(&rowbuf, &xs[j * k..(j + 1) * k]) + bi;
            }
        }
        jt = jhi;
    }
}

/// [`affine_dots_tile`] over i8-stored rows with per-row scales; same
/// structure as [`affine_dots_tile_f16`], bit-identical to the dequantized
/// f32 sweep at a quarter of the bytes.
#[allow(clippy::too_many_arguments)]
pub fn affine_dots_tile_i8(
    w: &[i8],
    scales: &[f32],
    b: &[f32],
    k: usize,
    xs: &[f32],
    m: usize,
    out: &mut [f32],
    out_stride: usize,
    out_offset: usize,
) {
    let rows = b.len();
    debug_assert_eq!(w.len(), rows * k);
    debug_assert_eq!(scales.len(), rows);
    debug_assert_eq!(xs.len(), m * k);
    const EXAMPLE_TILE: usize = 8;
    let mut rowbuf = vec![0f32; k];
    let mut jt = 0;
    while jt < m {
        let jhi = (jt + EXAMPLE_TILE).min(m);
        for (i, (wr, &bi)) in w.chunks_exact(k).zip(b.iter()).enumerate() {
            let scale = scales[i];
            for (d, &qv) in rowbuf.iter_mut().zip(wr.iter()) {
                *d = qv as f32 * scale;
            }
            for j in jt..jhi {
                out[j * out_stride + out_offset + i] =
                    dot(&rowbuf, &xs[j * k..(j + 1) * k]) + bi;
            }
        }
        jt = jhi;
    }
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Scale in place.
#[inline]
pub fn scale(a: &mut [f32], alpha: f32) {
    for v in a.iter_mut() {
        *v *= alpha;
    }
}

/// Numerically stable log(sigma(z)).
#[inline]
pub fn log_sigmoid(z: f32) -> f32 {
    z.min(0.0) - (-z.abs()).exp().ln_1p()
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Logistic sigmoid in f64, branch-stable at both tails. Used where f32
/// rounding is not acceptable — e.g. the tree-fit Newton curvature, whose
/// Armijo check compares against a full-f64 objective (`tree/fit.rs`).
#[inline]
pub fn sigmoid64(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

// ---------------------------------------------------------------------------
// Canonical fused sigmoid / log-sigmoid kernels (tree hot path)
// ---------------------------------------------------------------------------
//
// One branch decision in the auxiliary tree needs all of σ(a), log σ(a) and
// log σ(−a). All three share a single e = exp(−|a|) and a single
// l = ln(1+e), so the fused kernel costs one polynomial exp and one
// polynomial log instead of the two libm exps + one libm log1p of the naive
// formulation — and, unlike libm calls, the polynomial form is pure
// straight-line IEEE arithmetic (mul/add/select/bit ops), which the
// compiler vectorizes across the 8-lane structure-of-arrays variants used
// by `tree::TreeKernel`.
//
// Determinism contract: the scalar helpers below are the per-lane bodies of
// the 8-lane variants, so scalar walkers and SIMD-width kernels execute the
// identical operation sequence per value and agree bitwise (pinned by
// `sig_terms8_bitwise_matches_scalar`). Keep the two shapes in lockstep
// when editing either.
//
// Polynomial accuracy (coefficients after Cephes `expf`/`logf`): max
// absolute error ~1.3e-7 on log σ over |a| ≤ 40, max relative error ~2e-6
// on σ — below f32 round-off of the downstream sums.

/// Round-to-nearest bias: adding then subtracting 1.5·2²³ rounds an f32 in
/// ±2²² to an integer without any float→int conversion.
const EXP_MAGIC: f32 = 12_582_912.0;
/// Below this, exp(−|a|) is ≤ ~1.6e-38 and indistinguishable from 0 in
/// every downstream use (1 + e == 1, ln(1+e) == e); clamping keeps the
/// 2ⁿ exponent construction in the normal range.
const EXP_MIN: f32 = -87.0;
/// ln 2 split for Cody–Waite range reduction (hi holds 11 exact bits).
const LN2_HI: f32 = 0.693_359_375;
const LN2_LO: f32 = -2.121_944_4e-4;
/// Minimax coefficients of (exp(r) − 1 − r)/r² on |r| ≤ ln2/2 (Cephes
/// `expf`; full decimal digits kept so the literals round to the exact
/// floats the kernel was validated with).
#[allow(clippy::excessive_precision)]
const EXP_C: [f32; 6] = [
    1.9875691500e-4,
    1.3981999507e-3,
    8.3334519073e-3,
    4.1665795894e-2,
    1.6666665459e-1,
    5.0000001201e-1,
];
/// Minimax coefficients of (ln(1+t) − t + t²/2)/t³ on the reduced range
/// (Cephes `logf`; full decimal digits, see [`EXP_C`]).
#[allow(clippy::excessive_precision)]
const LN_C: [f32; 9] = [
    7.0376836292e-2,
    -1.1514610310e-1,
    1.1676998740e-1,
    -1.2420140846e-1,
    1.4249322787e-1,
    -1.6668057665e-1,
    2.0000714765e-1,
    -2.4999993993e-1,
    3.3333331174e-1,
];

/// e = exp(−|a|) ∈ (0, 1], canonical polynomial form (one lane of
/// [`exp_neg_abs8`]; keep the op sequences identical).
#[inline]
fn exp_neg_abs(a: f32) -> f32 {
    // NaN would be laundered into a finite value by the clamp below (the
    // libm formulation propagated it); activations are finite by
    // construction, so surface a broken fit here rather than downstream.
    debug_assert!(!a.is_nan(), "NaN activation reached the sigmoid kernel");
    let az = if a < 0.0 { a } else { -a };
    let zc = if az > EXP_MIN { az } else { EXP_MIN };
    let t = zc * std::f32::consts::LOG2_E + EXP_MAGIC;
    let n = t - EXP_MAGIC;
    let r0 = zc - n * LN2_HI;
    let r = r0 - n * LN2_LO;
    let mut q = EXP_C[0];
    q = q * r + EXP_C[1];
    q = q * r + EXP_C[2];
    q = q * r + EXP_C[3];
    q = q * r + EXP_C[4];
    q = q * r + EXP_C[5];
    let poly = q * (r * r) + r + 1.0;
    // t = EXP_MAGIC + n exactly, so n sits in t's low mantissa bits: build
    // the 2ⁿ scale with pure integer ops (no float→int conversion).
    let n_int = (t.to_bits() & 0x007f_ffff) as i32 - 0x0040_0000;
    let scale = f32::from_bits(((n_int + 127) << 23) as u32);
    poly * scale
}

/// ln(1 + e) for e ∈ [0, 1], canonical polynomial form (one lane of
/// [`ln_1p_unit8`]; keep the op sequences identical).
#[inline]
fn ln_1p_unit(e: f32) -> f32 {
    let u = 1.0 + e;
    let big = u > std::f32::consts::SQRT_2;
    let t = if big { 0.5 * u - 1.0 } else { u - 1.0 };
    let z2 = t * t;
    let mut q = LN_C[0];
    q = q * t + LN_C[1];
    q = q * t + LN_C[2];
    q = q * t + LN_C[3];
    q = q * t + LN_C[4];
    q = q * t + LN_C[5];
    q = q * t + LN_C[6];
    q = q * t + LN_C[7];
    q = q * t + LN_C[8];
    let y = (t * z2) * q - 0.5 * z2;
    let r = t + y;
    // r is never -0.0 here (t ≥ -0.293 and t = 0 arrives as +0.0), so the
    // unconditional add of a selected base keeps bit-exactness while
    // staying branch-free for the vectorizer.
    let base = if big { std::f32::consts::LN_2 } else { 0.0 };
    r + base
}

/// Fused (σ(a), log σ(a), log σ(−a)) — the three terms one sampled branch
/// decision consumes — sharing one exp and one log. Scalar shape of the
/// canonical kernel; bit-identical per lane to [`sig_terms8`].
#[inline]
pub fn sig_terms(a: f32) -> (f32, f32, f32) {
    let e = exp_neg_abs(a);
    let l = ln_1p_unit(e);
    let num = if a >= 0.0 { 1.0 } else { e };
    let p = num / (1.0 + e);
    let lsr = (if a < 0.0 { a } else { 0.0 }) - l;
    let lsl = (if -a < 0.0 { -a } else { 0.0 }) - l;
    (p, lsr, lsl)
}

/// Fused (log σ(a), log σ(−a)) for probability-only walks (no draw).
/// Bit-identical per lane to [`log_sigmoid_pair8`], and its two outputs
/// match the corresponding [`sig_terms`] outputs bitwise.
#[inline]
pub fn log_sigmoid_pair(a: f32) -> (f32, f32) {
    let e = exp_neg_abs(a);
    let l = ln_1p_unit(e);
    let lsr = (if a < 0.0 { a } else { 0.0 }) - l;
    let lsl = (if -a < 0.0 { -a } else { 0.0 }) - l;
    (lsr, lsl)
}

/// 8-lane [`exp_neg_abs`]: per-stage loops over fixed-size arrays, the
/// shape the auto-vectorizer turns into SIMD. Each lane runs the scalar
/// helper's exact operation sequence.
#[inline]
fn exp_neg_abs8(a: &[f32; 8], e: &mut [f32; 8]) {
    for (ai, ei) in a.iter().zip(e.iter_mut()) {
        *ei = exp_neg_abs(*ai);
    }
}

/// 8-lane [`ln_1p_unit`]; see [`exp_neg_abs8`].
#[inline]
fn ln_1p_unit8(e: &[f32; 8], l: &mut [f32; 8]) {
    for (ei, li) in e.iter().zip(l.iter_mut()) {
        *li = ln_1p_unit(*ei);
    }
}

/// 8-lane [`sig_terms`]: `(p[i], lsr[i], lsl[i]) = sig_terms(a[i])`,
/// bitwise, with the math staged for SIMD across lanes.
#[inline]
pub fn sig_terms8(a: &[f32; 8], p: &mut [f32; 8], lsr: &mut [f32; 8], lsl: &mut [f32; 8]) {
    let mut e = [0f32; 8];
    let mut l = [0f32; 8];
    exp_neg_abs8(a, &mut e);
    ln_1p_unit8(&e, &mut l);
    for i in 0..8 {
        let ai = a[i];
        let num = if ai >= 0.0 { 1.0 } else { e[i] };
        p[i] = num / (1.0 + e[i]);
        lsr[i] = (if ai < 0.0 { ai } else { 0.0 }) - l[i];
        lsl[i] = (if -ai < 0.0 { -ai } else { 0.0 }) - l[i];
    }
}

/// 8-lane [`log_sigmoid_pair`] (no σ, so no per-lane division).
#[inline]
pub fn log_sigmoid_pair8(a: &[f32; 8], lsr: &mut [f32; 8], lsl: &mut [f32; 8]) {
    let mut e = [0f32; 8];
    let mut l = [0f32; 8];
    exp_neg_abs8(a, &mut e);
    ln_1p_unit8(&e, &mut l);
    for i in 0..8 {
        let ai = a[i];
        lsr[i] = (if ai < 0.0 { ai } else { 0.0 }) - l[i];
        lsl[i] = (if -ai < 0.0 { -ai } else { 0.0 }) - l[i];
    }
}

/// Streaming log-sum-exp merge: combine (m1, s1) and (m2, s2) where each
/// pair represents max and sum(exp(x - max)) over disjoint sets.
#[inline]
pub fn lse_merge(m1: f32, s1: f32, m2: f32, s2: f32) -> (f32, f32) {
    if s1 == 0.0 && m1 == f32::NEG_INFINITY {
        return (m2, s2);
    }
    if s2 == 0.0 && m2 == f32::NEG_INFINITY {
        return (m1, s1);
    }
    let m = m1.max(m2);
    (m, s1 * (m1 - m).exp() + s2 * (m2 - m).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::Rng;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..131).map(|i| (i as f32) * 0.1 - 3.0).collect();
        let b: Vec<f32> = (0..131).map(|i| (i as f32) * -0.05 + 1.0).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn log_sigmoid_stable_at_extremes() {
        assert!(log_sigmoid(100.0).abs() < 1e-6);
        assert!((log_sigmoid(-100.0) + 100.0).abs() < 1e-3);
        assert!(log_sigmoid(0.0) + std::f32::consts::LN_2 < 1e-6);
        assert!(log_sigmoid(-1e30).is_finite() || log_sigmoid(-1e30) == f32::NEG_INFINITY);
    }

    #[test]
    fn sigmoid_symmetry() {
        for z in [-5.0f32, -1.0, 0.0, 2.0, 7.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn sigmoid64_matches_and_exceeds_f32_precision() {
        for z in [-30.0f64, -5.0, -1.0, 0.0, 0.5, 2.0, 7.0, 30.0] {
            assert!((sigmoid64(z) + sigmoid64(-z) - 1.0).abs() < 1e-15, "z={z}");
            assert!((sigmoid64(z) - sigmoid(z as f32) as f64).abs() < 1e-6, "z={z}");
        }
        // tails stay finite where f32 would round to 0/1
        assert!(sigmoid64(-40.0) > 0.0);
        assert!(sigmoid64(30.0) < 1.0 && sigmoid(30.0f32) == 1.0);
        assert!(sigmoid64(-700.0) >= 0.0 && sigmoid64(700.0) <= 1.0);
    }

    #[test]
    fn lse_merge_equals_global() {
        let xs: Vec<f32> = vec![-3.0, 0.5, 2.0, -1.0, 4.0, 4.0, -10.0];
        // global
        let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let s: f32 = xs.iter().map(|x| (x - m).exp()).sum();
        let global = m + s.ln();
        // streamed in two chunks
        let (m1, s1) = {
            let c = &xs[..3];
            let m = c.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            (m, c.iter().map(|x| (x - m).exp()).sum::<f32>())
        };
        let (m2, s2) = {
            let c = &xs[3..];
            let m = c.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            (m, c.iter().map(|x| (x - m).exp()).sum::<f32>())
        };
        let (mm, ss) = lse_merge(m1, s1, m2, s2);
        assert!((mm + ss.ln() - global).abs() < 1e-5);
    }

    /// The determinism contract of the canonical kernels: the 8-lane
    /// structure-of-arrays shapes reproduce the scalar helpers bit for bit
    /// on a dense grid plus the edge cases (±0, clamp boundary, saturated
    /// tails). If this breaks, blocked descents diverge from the oracle.
    #[test]
    fn sig_terms8_bitwise_matches_scalar() {
        let mut inputs: Vec<f32> = Vec::new();
        let mut a = -120.0f32;
        while a < 120.0 {
            inputs.push(a);
            a += 0.037;
        }
        inputs.extend_from_slice(&[
            0.0, -0.0, 1e-20, -1e-20, -86.9, -87.0, -87.1, 86.9, 87.0, 87.1, -500.0, 500.0,
        ]);
        while inputs.len() % 8 != 0 {
            inputs.push(0.25);
        }
        for block in inputs.chunks_exact(8) {
            let lanes: [f32; 8] = block.try_into().unwrap();
            let (mut p8, mut r8, mut l8) = ([0f32; 8], [0f32; 8], [0f32; 8]);
            sig_terms8(&lanes, &mut p8, &mut r8, &mut l8);
            let (mut pr8, mut pl8) = ([0f32; 8], [0f32; 8]);
            log_sigmoid_pair8(&lanes, &mut pr8, &mut pl8);
            for i in 0..8 {
                let (p, lsr, lsl) = sig_terms(lanes[i]);
                let (qr, ql) = log_sigmoid_pair(lanes[i]);
                assert_eq!(p.to_bits(), p8[i].to_bits(), "a={}", lanes[i]);
                assert_eq!(lsr.to_bits(), r8[i].to_bits(), "a={}", lanes[i]);
                assert_eq!(lsl.to_bits(), l8[i].to_bits(), "a={}", lanes[i]);
                assert_eq!(qr.to_bits(), pr8[i].to_bits(), "a={}", lanes[i]);
                assert_eq!(ql.to_bits(), pl8[i].to_bits(), "a={}", lanes[i]);
                // the pair kernel is the terms kernel minus σ
                assert_eq!(qr.to_bits(), lsr.to_bits());
                assert_eq!(ql.to_bits(), lsl.to_bits());
            }
        }
    }

    /// Polynomial accuracy against the f64 reference formulation.
    #[test]
    fn sig_terms_accuracy_vs_reference() {
        let mut a = -40.0f64;
        while a < 40.0 {
            let (p, lsr, lsl) = sig_terms(a as f32);
            let e = (-a.abs()).exp();
            let l = e.ln_1p();
            let p_ref = 1.0 / (1.0 + (-a).exp());
            let lsr_ref = a.min(0.0) - l;
            let lsl_ref = (-a).min(0.0) - l;
            assert!((p as f64 - p_ref).abs() < 3e-6 * p_ref.max(1e-6), "a={a}");
            assert!((lsr as f64 - lsr_ref).abs() < 1e-6 * (1.0 + lsr_ref.abs()), "a={a}");
            assert!((lsl as f64 - lsl_ref).abs() < 1e-6 * (1.0 + lsl_ref.abs()), "a={a}");
            a += 0.0113;
        }
        // consistency identities the training losses rely on
        let (p, lsr, lsl) = sig_terms(0.0);
        assert!((p - 0.5).abs() < 1e-6);
        assert!((lsr - lsl).abs() < 1e-7);
        let (p_hi, lsr_hi, _) = sig_terms(50.0);
        assert!((p_hi - 1.0).abs() < 1e-6 && lsr_hi.abs() < 1e-6);
        let (p_lo, _, lsl_lo) = sig_terms(-50.0);
        assert!(p_lo < 1e-6 && lsl_lo.abs() < 1e-6);
        // saturated tails stay finite and monotone-consistent
        let (_, lsr_tail, _) = sig_terms(-300.0);
        assert!(lsr_tail <= -300.0 + 1.0 && lsr_tail.is_finite());
    }

    #[test]
    fn affine_dots_tile_matches_naive_loop() {
        let mut rng = Rng::new(31);
        for (rows, k, m) in [(5usize, 3usize, 1usize), (8, 16, 8), (13, 7, 11), (1, 1, 9)] {
            let w: Vec<f32> = (0..rows * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..rows).map(|_| rng.normal()).collect();
            let xs: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let stride = rows + 2;
            let off = 1;
            let mut out = vec![0f32; m * stride];
            affine_dots_tile(&w, &b, k, &xs, m, &mut out, stride, off);
            for j in 0..m {
                for i in 0..rows {
                    let expect = dot(&w[i * k..(i + 1) * k], &xs[j * k..(j + 1) * k]) + b[i];
                    assert_eq!(out[j * stride + off + i].to_bits(), expect.to_bits());
                }
            }
        }
    }

    #[test]
    fn lse_merge_identity_element() {
        let (m, s) = lse_merge(f32::NEG_INFINITY, 0.0, 1.5, 2.0);
        assert_eq!((m, s), (1.5, 2.0));
        let (m, s) = lse_merge(1.5, 2.0, f32::NEG_INFINITY, 0.0);
        assert_eq!((m, s), (1.5, 2.0));
    }
}
