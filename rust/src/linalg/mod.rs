//! Small dense linear algebra used by the auxiliary model and preprocessing.
//!
//! Dimensions here are tiny (k ≤ 64 for the auxiliary model, K ≤ a few
//! hundred for PCA covariances), so plain row-major loops beat any BLAS
//! round-trip; the heavy O(N·C·K) work lives in the HLO artifacts instead.

pub mod pca;
pub mod solve;

pub use pca::Pca;
pub use solve::solve_spd;

/// Dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: keeps the compiler auto-vectorizing and
    // reduces sequential FP dependency. See benches/hot_path.rs.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Scale in place.
#[inline]
pub fn scale(a: &mut [f32], alpha: f32) {
    for v in a.iter_mut() {
        *v *= alpha;
    }
}

/// Numerically stable log(sigma(z)).
#[inline]
pub fn log_sigmoid(z: f32) -> f32 {
    z.min(0.0) - (-z.abs()).exp().ln_1p()
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Logistic sigmoid in f64, branch-stable at both tails. Used where f32
/// rounding is not acceptable — e.g. the tree-fit Newton curvature, whose
/// Armijo check compares against a full-f64 objective (`tree/fit.rs`).
#[inline]
pub fn sigmoid64(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Streaming log-sum-exp merge: combine (m1, s1) and (m2, s2) where each
/// pair represents max and sum(exp(x - max)) over disjoint sets.
#[inline]
pub fn lse_merge(m1: f32, s1: f32, m2: f32, s2: f32) -> (f32, f32) {
    if s1 == 0.0 && m1 == f32::NEG_INFINITY {
        return (m2, s2);
    }
    if s2 == 0.0 && m2 == f32::NEG_INFINITY {
        return (m1, s1);
    }
    let m = m1.max(m2);
    (m, s1 * (m1 - m).exp() + s2 * (m2 - m).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..131).map(|i| (i as f32) * 0.1 - 3.0).collect();
        let b: Vec<f32> = (0..131).map(|i| (i as f32) * -0.05 + 1.0).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn log_sigmoid_stable_at_extremes() {
        assert!(log_sigmoid(100.0).abs() < 1e-6);
        assert!((log_sigmoid(-100.0) + 100.0).abs() < 1e-3);
        assert!(log_sigmoid(0.0) + std::f32::consts::LN_2 < 1e-6);
        assert!(log_sigmoid(-1e30).is_finite() || log_sigmoid(-1e30) == f32::NEG_INFINITY);
    }

    #[test]
    fn sigmoid_symmetry() {
        for z in [-5.0f32, -1.0, 0.0, 2.0, 7.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn sigmoid64_matches_and_exceeds_f32_precision() {
        for z in [-30.0f64, -5.0, -1.0, 0.0, 0.5, 2.0, 7.0, 30.0] {
            assert!((sigmoid64(z) + sigmoid64(-z) - 1.0).abs() < 1e-15, "z={z}");
            assert!((sigmoid64(z) - sigmoid(z as f32) as f64).abs() < 1e-6, "z={z}");
        }
        // tails stay finite where f32 would round to 0/1
        assert!(sigmoid64(-40.0) > 0.0);
        assert!(sigmoid64(30.0) < 1.0 && sigmoid(30.0f32) == 1.0);
        assert!(sigmoid64(-700.0) >= 0.0 && sigmoid64(700.0) <= 1.0);
    }

    #[test]
    fn lse_merge_equals_global() {
        let xs: Vec<f32> = vec![-3.0, 0.5, 2.0, -1.0, 4.0, 4.0, -10.0];
        // global
        let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let s: f32 = xs.iter().map(|x| (x - m).exp()).sum();
        let global = m + s.ln();
        // streamed in two chunks
        let (m1, s1) = {
            let c = &xs[..3];
            let m = c.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            (m, c.iter().map(|x| (x - m).exp()).sum::<f32>())
        };
        let (m2, s2) = {
            let c = &xs[3..];
            let m = c.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            (m, c.iter().map(|x| (x - m).exp()).sum::<f32>())
        };
        let (mm, ss) = lse_merge(m1, s1, m2, s2);
        assert!((mm + ss.ln() - global).abs() < 1e-5);
    }

    #[test]
    fn lse_merge_identity_element() {
        let (m, s) = lse_merge(f32::NEG_INFINITY, 0.0, 1.5, 2.0);
        assert_eq!((m, s), (1.5, 2.0));
        let (m, s) = lse_merge(1.5, 2.0, f32::NEG_INFINITY, 0.0);
        assert_eq!((m, s), (1.5, 2.0));
    }
}
