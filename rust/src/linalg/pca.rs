//! PCA by covariance + power iteration with deflation.
//!
//! The paper (Sec. 3, "Technical Details") projects features to a small
//! k-dimensional space before fitting the auxiliary model; sampling then
//! costs O(k log C). Feature dims here are modest (K ≤ a few hundred), so
//! an explicit K×K covariance plus power iteration is exact enough and has
//! no dependencies. Also used to initialize tree-node weights with the
//! dominant eigenvector of per-label sum vectors (paper's init).
//!
//! The O(N·K²) mean/covariance accumulation of [`Pca::fit_with`] is
//! sharded over a worker pool: rows are cut into [`FIT_SHARDS`] fixed
//! slabs (a pure function of N, never of the worker count), each slab
//! accumulates its own f64 partial, and partials reduce in slab order —
//! so the fitted model is bit-identical at every `parallelism` setting.
//!
//! The power-iteration/deflation loop is pool-sharded too (PR 4): each
//! matvec row and each deflation row is an independent computation with a
//! fixed sequential reduction order, sharded into contiguous row slabs
//! whose bounds depend only on `(n, workers)` — bit-identical to the
//! serial loop at every worker count. Below [`PAR_MIN_EIG_DIM`] rows a
//! pool dispatch costs more than the whole O(n²) product, so small
//! matrices (including the default K = 64 presets) stay on the serial
//! path; the parallel path engages for wide feature spaces.

use super::dot;
use crate::utils::json::Json;
use crate::utils::{Pool, Rng, SharedMut};

/// Fixed row-slab count for the parallel mean/covariance accumulation.
/// Must not depend on the worker count (see module docs); 16 slabs bound
/// the partial-buffer memory at 16·K² f64 while still feeding every pool
/// width we run.
const FIT_SHARDS: usize = 16;

/// A fitted PCA projection: x -> (x - mean) @ components^T, [K] -> [k].
#[derive(Clone, Debug)]
pub struct Pca {
    pub mean: Vec<f32>,
    /// k rows of length K, orthonormal.
    pub components: Vec<Vec<f32>>,
    /// Precomputed mean·component per component: `project` runs once per
    /// negative draw, so the mean dot must not be recomputed there.
    pub proj_bias: Vec<f32>,
    pub input_dim: usize,
    pub output_dim: usize,
}

/// Below this row count the power-iteration matvec and the deflation
/// update stay serial: at n = 128 a row slab is only a few thousand
/// multiply-adds per worker, about the cost of the dispatch itself.
const PAR_MIN_EIG_DIM: usize = 128;

/// `out[i] = m[i, :] · v` with the rows sharded into contiguous slabs over
/// the pool. Each row's dot uses the exact serial reduction order, so the
/// result is bit-identical at every worker count (there is no cross-row
/// reduction to re-order).
fn matvec_rows(pool: &Pool, m: &[f64], v: &[f64], out: &mut [f64], n: usize) {
    debug_assert_eq!(v.len(), n);
    debug_assert_eq!(out.len(), n);
    let row_dot = |i: usize| -> f64 {
        m[i * n..(i + 1) * n].iter().zip(v.iter()).map(|(a, b)| a * b).sum()
    };
    if pool.is_serial() || n < PAR_MIN_EIG_DIM {
        for (i, o) in out.iter_mut().enumerate() {
            *o = row_dot(i);
        }
        return;
    }
    pool.for_each_span(out, 1, |first, span| {
        for (j, o) in span.iter_mut().enumerate() {
            *o = row_dot(first + j);
        }
    });
}

/// Dominant eigenvector of a symmetric PSD matrix (row-major n×n) by power
/// iteration. Returns a unit vector; arbitrary unit vector if the matrix is
/// (near) zero.
pub fn dominant_eigenvector(m: &[f64], n: usize, iters: usize, rng: &mut Rng) -> Vec<f32> {
    dominant_eigenvector_with(m, n, iters, rng, &Pool::serial())
}

/// [`dominant_eigenvector`] with each iteration's matvec sharded over a
/// worker pool (module docs) — bit-identical to the serial loop.
pub fn dominant_eigenvector_with(
    m: &[f64],
    n: usize,
    iters: usize,
    rng: &mut Rng,
    pool: &Pool,
) -> Vec<f32> {
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
    let mut tmp = vec![0f64; n];
    for _ in 0..iters {
        matvec_rows(pool, m, &v, &mut tmp, n);
        let nrm = tmp.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm < 1e-30 {
            break;
        }
        for i in 0..n {
            v[i] = tmp[i] / nrm;
        }
    }
    let nrm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if nrm < 1e-30 {
        let mut e = vec![0f32; n];
        e[0] = 1.0;
        return e;
    }
    v.iter().map(|x| (*x / nrm) as f32).collect()
}

impl Pca {
    /// Fit `out_dim` principal components of `data` ([n, in_dim] row-major),
    /// serially.
    ///
    /// Power iteration with deflation; each component gets `iters`
    /// iterations (30 is plenty at these scales — see unit tests, which
    /// check recovery of a planted low-rank structure).
    pub fn fit(data: &[f32], n: usize, in_dim: usize, out_dim: usize, seed: u64) -> Self {
        Self::fit_with(data, n, in_dim, out_dim, seed, &Pool::serial())
    }

    /// [`Pca::fit`] with the O(N·K²) mean/covariance accumulation sharded
    /// over a worker pool. Fixed row slabs + fixed-order partial reduction
    /// make the result bit-identical at every worker count (module docs).
    pub fn fit_with(
        data: &[f32],
        n: usize,
        in_dim: usize,
        out_dim: usize,
        seed: u64,
        pool: &Pool,
    ) -> Self {
        assert!(n > 0 && in_dim > 0 && out_dim > 0 && out_dim <= in_dim);
        assert_eq!(data.len(), n * in_dim);
        let mut rng = Rng::new(seed ^ 0x9ca);
        let workers = pool.num_workers();
        let slab = n.div_ceil(FIT_SHARDS);
        let slab_bounds = |s: usize| ((s * slab).min(n), ((s + 1) * slab).min(n));

        // ---- mean: per-slab f64 partials, reduced in slab order ----
        let mut mean_part = vec![0f64; FIT_SHARDS * in_dim];
        {
            let parts = SharedMut::new(&mut mean_part);
            pool.run_sharded(|shard| {
                for s in (shard..FIT_SHARDS).step_by(workers) {
                    let (lo, hi) = slab_bounds(s);
                    if lo >= hi {
                        continue;
                    }
                    // SAFETY: slab s is processed by exactly one shard.
                    let dst = unsafe { parts.slice_mut(s * in_dim, in_dim) };
                    for row in data[lo * in_dim..hi * in_dim].chunks_exact(in_dim) {
                        for (d, v) in dst.iter_mut().zip(row.iter()) {
                            *d += *v as f64;
                        }
                    }
                }
            });
        }
        let mut mean64 = vec![0f64; in_dim];
        for part in mean_part.chunks_exact(in_dim) {
            for (m, p) in mean64.iter_mut().zip(part.iter()) {
                *m += *p;
            }
        }
        let mean: Vec<f32> = mean64.iter().map(|m| (*m / n as f64) as f32).collect();

        // ---- covariance in f64 (K ≤ few hundred -> K² ≤ ~100k entries):
        // per-slab K×K partials, reduced in slab order ----
        let mut cov_part = vec![0f64; FIT_SHARDS * in_dim * in_dim];
        {
            let parts = SharedMut::new(&mut cov_part);
            let mean_ref = &mean;
            pool.run_sharded(|shard| {
                let mut centered = vec![0f32; in_dim];
                for s in (shard..FIT_SHARDS).step_by(workers) {
                    let (lo, hi) = slab_bounds(s);
                    if lo >= hi {
                        continue;
                    }
                    // SAFETY: slab s is processed by exactly one shard.
                    let dst = unsafe { parts.slice_mut(s * in_dim * in_dim, in_dim * in_dim) };
                    for row in data[lo * in_dim..hi * in_dim].chunks_exact(in_dim) {
                        for (c, (r, m)) in
                            centered.iter_mut().zip(row.iter().zip(mean_ref.iter()))
                        {
                            *c = r - m;
                        }
                        for i in 0..in_dim {
                            let ci = centered[i] as f64;
                            if ci == 0.0 {
                                continue;
                            }
                            let drow = &mut dst[i * in_dim..(i + 1) * in_dim];
                            for (d, c) in drow.iter_mut().zip(centered.iter()) {
                                *d += ci * *c as f64;
                            }
                        }
                    }
                }
            });
        }
        let mut cov = vec![0f64; in_dim * in_dim];
        for part in cov_part.chunks_exact(in_dim * in_dim) {
            for (c, p) in cov.iter_mut().zip(part.iter()) {
                *c += *p;
            }
        }
        for v in cov.iter_mut() {
            *v /= n as f64;
        }

        // power iteration + deflation: each matvec row and each deflation
        // row is independent with a fixed per-row reduction order, so the
        // loop shards over the pool bit-identically (module docs); small
        // matrices stay serial below PAR_MIN_EIG_DIM.
        let mut components: Vec<Vec<f32>> = Vec::with_capacity(out_dim);
        let mut cv = vec![0f64; in_dim];
        for _ in 0..out_dim {
            let v = dominant_eigenvector_with(&cov, in_dim, 50, &mut rng, pool);
            // deflate: cov -= lambda v v^T, lambda = v^T cov v
            let vf: Vec<f64> = v.iter().map(|x| *x as f64).collect();
            matvec_rows(pool, &cov, &vf, &mut cv, in_dim);
            let lambda: f64 = vf.iter().zip(cv.iter()).map(|(a, b)| a * b).sum();
            if pool.is_serial() || in_dim < PAR_MIN_EIG_DIM {
                for i in 0..in_dim {
                    for j in 0..in_dim {
                        cov[i * in_dim + j] -= lambda * vf[i] * vf[j];
                    }
                }
            } else {
                let vf_ref = &vf;
                pool.for_each_span(&mut cov, in_dim, |first_row, span| {
                    for (r, row) in span.chunks_exact_mut(in_dim).enumerate() {
                        let scale = lambda * vf_ref[first_row + r];
                        for (c, x) in row.iter_mut().zip(vf_ref.iter()) {
                            *c -= scale * x;
                        }
                    }
                });
            }
            components.push(v);
        }
        let proj_bias = components.iter().map(|c| dot(&mean, c)).collect();
        Self { mean, components, proj_bias, input_dim: in_dim, output_dim: out_dim }
    }

    /// Project one feature vector into the PCA space.
    pub fn project(&self, x: &[f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.input_dim);
        debug_assert_eq!(out.len(), self.output_dim);
        // (x - mean)·c == x·c - mean·c ; mean·c is `proj_bias`, precomputed
        // at fit/deserialize time — this runs once per negative draw.
        for ((o, c), bias) in out
            .iter_mut()
            .zip(self.components.iter())
            .zip(self.proj_bias.iter())
        {
            *o = dot(x, c) - bias;
        }
    }

    /// Serialize to JSON (model checkpointing).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean", Json::arr_f32(&self.mean)),
            (
                "components",
                Json::Arr(self.components.iter().map(|c| Json::arr_f32(c)).collect()),
            ),
            ("input_dim", Json::Num(self.input_dim as f64)),
            ("output_dim", Json::Num(self.output_dim as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let components: Vec<Vec<f32>> = v
            .get("components")?
            .as_arr()?
            .iter()
            .map(|c| c.to_vec_f32())
            .collect::<anyhow::Result<_>>()?;
        let mean = v.get("mean")?.to_vec_f32()?;
        let input_dim = v.get("input_dim")?.as_usize()?;
        let output_dim = v.get("output_dim")?.as_usize()?;
        anyhow::ensure!(components.len() == output_dim, "component count mismatch");
        anyhow::ensure!(
            components.iter().all(|c| c.len() == input_dim),
            "component dim mismatch"
        );
        anyhow::ensure!(mean.len() == input_dim, "mean dim mismatch");
        // proj_bias is derived, not serialized: recompute on load so old
        // checkpoints stay readable and the value always matches (mean,
        // components) exactly.
        let proj_bias = components.iter().map(|c| dot(&mean, c)).collect();
        Ok(Self { mean, components, proj_bias, input_dim, output_dim })
    }

    /// Project a whole row-major matrix [n, K] -> [n, k].
    pub fn project_all(&self, data: &[f32], n: usize) -> Vec<f32> {
        self.project_all_with(data, n, &Pool::serial())
    }

    /// [`Pca::project_all`] with the per-row loop sharded over a worker
    /// pool. Rows are independent and each output row has one writer, so
    /// the result is identical at any worker count.
    pub fn project_all_with(&self, data: &[f32], n: usize, pool: &Pool) -> Vec<f32> {
        assert_eq!(data.len(), n * self.input_dim);
        let mut out = vec![0f32; n * self.output_dim];
        pool.for_each_span(&mut out, self.output_dim, |first_row, span| {
            for (j, chunk) in span.chunks_exact_mut(self.output_dim).enumerate() {
                let i = first_row + j;
                self.project(&data[i * self.input_dim..(i + 1) * self.input_dim], chunk);
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norm2;

    /// Planted 2-factor data in 8 dims: PCA must put nearly all variance in
    /// the first two components.
    #[test]
    fn recovers_planted_subspace() {
        let (n, kin) = (2000usize, 8usize);
        let mut rng = Rng::new(1);
        let mut data = vec![0f32; n * kin];
        let dir1: Vec<f32> = (0..kin).map(|i| if i < 4 { 0.5 } else { 0.0 }).collect();
        let dir2: Vec<f32> = (0..kin).map(|i| if i >= 4 { 0.5 } else { 0.0 }).collect();
        for r in 0..n {
            let a = 5.0 * rng.normal();
            let b = 3.0 * rng.normal();
            for c in 0..kin {
                data[r * kin + c] = a * dir1[c] + b * dir2[c] + 0.05 * rng.normal() + 1.0;
            }
        }
        let pca = Pca::fit(&data, n, kin, 2, 7);
        // components should be orthonormal
        let c0 = &pca.components[0];
        let c1 = &pca.components[1];
        assert!((norm2(c0) - 1.0).abs() < 1e-4);
        assert!((norm2(c1) - 1.0).abs() < 1e-4);
        assert!(dot(c0, c1).abs() < 1e-3);
        // c0 should align with dir1 (the higher-variance direction)
        let d1n: Vec<f32> = dir1.iter().map(|x| x / norm2(&dir1)).collect();
        assert!(dot(c0, &d1n).abs() > 0.99, "c0 misaligned: {:?}", c0);
        // projection variance along comp0 >= comp1
        let proj = pca.project_all(&data, n);
        let var = |j: usize| -> f32 {
            let m: f32 = (0..n).map(|i| proj[i * 2 + j]).sum::<f32>() / n as f32;
            (0..n).map(|i| (proj[i * 2 + j] - m).powi(2)).sum::<f32>() / n as f32
        };
        assert!(var(0) > var(1));
        assert!(var(0) > 5.0); // ~25 * |dir1|^2
    }

    #[test]
    fn projection_is_centered() {
        let (n, kin) = (500usize, 5usize);
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..n * kin).map(|_| rng.normal() + 10.0).collect();
        let pca = Pca::fit(&data, n, kin, 3, 3);
        let proj = pca.project_all(&data, n);
        for j in 0..3 {
            let m: f32 = (0..n).map(|i| proj[i * 3 + j]).sum::<f32>() / n as f32;
            assert!(m.abs() < 0.2, "component {j} mean {m}");
        }
    }

    #[test]
    fn project_all_parallel_matches_serial() {
        let (n, kin) = (333usize, 6usize);
        let mut rng = Rng::new(4);
        let data: Vec<f32> = (0..n * kin).map(|_| rng.normal()).collect();
        let pca = Pca::fit(&data, n, kin, 3, 9);
        let serial = pca.project_all(&data, n);
        for workers in [2, 3, 5] {
            let par = pca.project_all_with(&data, n, &Pool::new(workers));
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn fit_parallel_bit_identical() {
        let (n, kin) = (1111usize, 7usize);
        let mut rng = Rng::new(6);
        let data: Vec<f32> = (0..n * kin).map(|_| rng.normal()).collect();
        let reference = Pca::fit(&data, n, kin, 3, 11);
        for workers in [2, 3, 5, 32] {
            let p = Pca::fit_with(&data, n, kin, 3, 11, &Pool::new(workers));
            assert_eq!(p.mean, reference.mean, "workers={workers}");
            assert_eq!(p.components, reference.components, "workers={workers}");
            assert_eq!(p.proj_bias, reference.proj_bias, "workers={workers}");
        }
    }

    #[test]
    fn proj_bias_matches_explicit_mean_dot() {
        let (n, kin) = (400usize, 6usize);
        let mut rng = Rng::new(8);
        let data: Vec<f32> = (0..n * kin).map(|_| rng.normal() + 3.0).collect();
        let pca = Pca::fit(&data, n, kin, 2, 5);
        for (bias, c) in pca.proj_bias.iter().zip(pca.components.iter()) {
            assert_eq!(*bias, dot(&pca.mean, c));
        }
        // the JSON roundtrip rebuilds the identical derived bias
        let back = Pca::from_json(&pca.to_json()).unwrap();
        assert_eq!(back.proj_bias, pca.proj_bias);
    }

    #[test]
    fn dominant_eigenvector_of_diagonal() {
        let mut rng = Rng::new(3);
        let m = vec![4.0, 0.0, 0.0, 1.0];
        let v = dominant_eigenvector(&m, 2, 100, &mut rng);
        assert!(v[0].abs() > 0.999, "{v:?}");
    }

    /// Random PSD matrix above the parallel-matvec floor: the pooled power
    /// iteration must reproduce the serial one bit for bit.
    #[test]
    fn dominant_eigenvector_parallel_bit_identical() {
        let n = PAR_MIN_EIG_DIM + 33; // engage the parallel path, ragged spans
        let mut rng = Rng::new(17);
        let g: Vec<f64> = (0..n * n).map(|_| rng.normal() as f64).collect();
        // m = g^T g / n is symmetric PSD
        let mut m = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let s: f64 = (0..n).map(|l| g[l * n + i] * g[l * n + j]).sum();
                m[i * n + j] = s / n as f64;
            }
        }
        let reference = dominant_eigenvector(&m, n, 30, &mut Rng::new(5));
        for workers in [2, 3, 7] {
            let v = dominant_eigenvector_with(&m, n, 30, &mut Rng::new(5), &Pool::new(workers));
            assert_eq!(v, reference, "workers={workers}");
        }
    }

    /// Full fit above the matvec floor (wide feature space): parallel
    /// power iteration + deflation must keep the fit bit-identical.
    #[test]
    fn fit_parallel_bit_identical_above_eig_floor() {
        let (n, kin) = (500usize, PAR_MIN_EIG_DIM + 16);
        let mut rng = Rng::new(19);
        let data: Vec<f32> = (0..n * kin).map(|_| rng.normal()).collect();
        let reference = Pca::fit(&data, n, kin, 3, 23);
        for workers in [2, 5] {
            let p = Pca::fit_with(&data, n, kin, 3, 23, &Pool::new(workers));
            assert_eq!(p.mean, reference.mean, "workers={workers}");
            assert_eq!(p.components, reference.components, "workers={workers}");
            assert_eq!(p.proj_bias, reference.proj_bias, "workers={workers}");
        }
    }
}
