//! Dense symmetric positive-definite solver (Cholesky) for the auxiliary
//! model's Newton steps, (k+1)×(k+1) with k ≤ 64.

/// Solve `A x = b` for symmetric positive-definite `A` (row-major, n×n).
/// Returns `None` if the factorization hits a non-positive pivot (A not
/// SPD within tolerance). `A` and `b` are consumed as scratch copies.
pub fn solve_spd(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    let mut l = a.to_vec();
    // in-place Cholesky: L stored in lower triangle
    for j in 0..n {
        let mut d = l[j * n + j];
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            return None;
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        for i in (j + 1)..n {
            let mut s = l[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    // forward substitution: L y = b
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            y[i] -= l[i * n + k] * y[k];
        }
        y[i] /= l[i * n + i];
    }
    // back substitution: L^T x = y
    let mut x = y;
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            x[i] -= l[k * n + i] * x[k];
        }
        x[i] /= l[i * n + i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matvec(a: &[f64], x: &[f64], n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| (0..n).map(|j| a[i * n + j] * x[j]).sum())
            .collect()
    }

    #[test]
    fn solves_identity() {
        let n = 4;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let x = solve_spd(&a, &b, n).unwrap();
        assert_eq!(x, b);
    }

    #[test]
    fn solves_random_spd() {
        // A = M^T M + I is SPD
        let n = 6;
        let m: Vec<f64> = (0..n * n).map(|i| ((i * 37 % 11) as f64) / 7.0 - 0.6).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += m[k * n + i] * m[k * n + j];
                }
                a[i * n + j] = s;
            }
        }
        let b: Vec<f64> = (0..n).map(|i| i as f64 - 2.0).collect();
        let x = solve_spd(&a, &b, n).unwrap();
        let ax = matvec(&a, &x, n);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-9, "{} vs {}", ax[i], b[i]);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = vec![1.0, 0.0, 0.0, -1.0]; // eigenvalues 1, -1
        assert!(solve_spd(&a, &[1.0, 1.0], 2).is_none());
    }

    #[test]
    fn rejects_nan() {
        let a = vec![f64::NAN, 0.0, 0.0, 1.0];
        assert!(solve_spd(&a, &[1.0, 1.0], 2).is_none());
    }
}
