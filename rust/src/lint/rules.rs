//! The repro-lint rule set.
//!
//! Every rule is **deny by default**: it fires on any match in any file
//! unless the file is exempted by the built-in allowlist
//! ([`crate::lint::LintConfig`]) or the exact line carries an inline
//! pragma naming the rule and a justification, written as
//! `// repro-lint: allow(wall-clock) justification text here` (the
//! justification is mandatory; a bare pragma is itself a violation).
//! Rules that guard *runtime determinism* (wall-clock reads, hash-order
//! iteration, floating-point reductions) skip `#[cfg(test)]` regions —
//! tests assert determinism rather than produce results — while the
//! memory-safety rules (`safety-comment`, `thread-spawn`) apply to test
//! code too.

use super::scan::ScannedLine;
use super::{Diagnostic, LintConfig, RuleId};

/// How many lines above an `unsafe` token a `// SAFETY:` comment may sit.
pub const SAFETY_LOOKBACK: usize = 10;

/// `needle` present in `hay` with non-identifier characters on both sides.
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let at = start + pos;
        let before_ok = at == 0
            || !hay[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = at + needle.len();
        let after_ok = after >= hay.len()
            || !hay[after..]
                .chars()
                .next()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        start = at + needle.len();
    }
    false
}

fn brace_delta(code: &str) -> i64 {
    let opens = code.matches('{').count() as i64;
    let closes = code.matches('}').count() as i64;
    opens - closes
}

/// Mark every line belonging to a `#[cfg(test)]` item (attribute line,
/// header, and braced body). Works for the repo convention of a trailing
/// `#[cfg(test)] mod tests { … }` as well as individually gated items.
fn test_regions(lines: &[ScannedLine]) -> Vec<bool> {
    let mut out = vec![false; lines.len()];
    let mut pending = false;
    let mut active = false;
    let mut depth: i64 = 0;
    for (i, l) in lines.iter().enumerate() {
        if active {
            out[i] = true;
            depth += brace_delta(&l.code);
            if depth <= 0 {
                active = false;
            }
            continue;
        }
        let code = l.code.trim();
        if pending {
            if code.is_empty() {
                out[i] = true; // comment/blank line between attribute and item
                continue;
            }
            out[i] = true;
            if l.code.contains('{') {
                let d = brace_delta(&l.code);
                if d > 0 {
                    active = true;
                    depth = d;
                }
                pending = false;
            } else if code.ends_with(';') {
                pending = false; // bodyless item, e.g. `#[cfg(test)] use …;`
            }
            // other attribute lines (`#[test]`, `#[allow(…)]`) keep pending
            continue;
        }
        if code.starts_with("#[cfg(test)]") {
            pending = true;
            out[i] = true;
            // the attribute and item may share one line
            if l.code.contains('{') {
                let d = brace_delta(&l.code);
                if d > 0 {
                    active = true;
                    depth = d;
                }
                pending = false;
            }
        }
    }
    out
}

/// Inline pragmas parsed from one line's comment text. `bad` is set when a
/// pragma is present but malformed or missing its justification.
#[derive(Default)]
struct Pragmas {
    allows: Vec<RuleId>,
    bad: bool,
}

fn parse_pragmas(comment: &str) -> Pragmas {
    let mut out = Pragmas::default();
    let mut rest = comment;
    while let Some(pos) = rest.find("repro-lint:") {
        rest = &rest[pos + "repro-lint:".len()..];
        let body = rest.trim_start();
        let Some(args) = body.strip_prefix("allow(") else {
            out.bad = true;
            continue;
        };
        let Some(close) = args.find(')') else {
            out.bad = true;
            continue;
        };
        let rule_name = args[..close].trim();
        let reason = args[close + 1..].trim();
        match RuleId::from_name(rule_name) {
            Some(rule) if !reason.is_empty() => out.allows.push(rule),
            _ => out.bad = true, // unknown rule or missing justification
        }
        rest = &args[close + 1..];
    }
    out
}

/// Split a code line into identifier and single-character punctuation
/// tokens (whitespace dropped). Enough structure for binding extraction.
fn tokens(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut ident = String::new();
    for c in code.chars() {
        if c.is_alphanumeric() || c == '_' {
            ident.push(c);
        } else {
            if !ident.is_empty() {
                out.push(std::mem::take(&mut ident));
            }
            if !c.is_whitespace() {
                out.push(c.to_string());
            }
        }
    }
    if !ident.is_empty() {
        out.push(ident);
    }
    out
}

fn is_ident_token(tok: &str) -> bool {
    tok.chars()
        .next()
        .is_some_and(|c| c.is_alphabetic() || c == '_')
}

/// Names bound to `HashMap`/`HashSet` anywhere in the file: variables,
/// parameters, and struct fields (`name: HashMap<…>` or `name = HashMap::…`,
/// possibly behind `&`, `mut`, or wrapper generics like `Arc<Mutex<…>>`).
fn hash_bindings(lines: &[ScannedLine]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for l in lines {
        let toks = tokens(&l.code);
        for (idx, t) in toks.iter().enumerate() {
            if t != "HashMap" && t != "HashSet" {
                continue;
            }
            // walk left across type-ish tokens to the binding separator
            let mut j = idx;
            let sep = loop {
                if j == 0 {
                    break None;
                }
                j -= 1;
                match toks[j].as_str() {
                    ":" | "=" => break Some(j),
                    "&" | "mut" | "<" | ">" | "," => continue,
                    tok if is_ident_token(tok) => continue,
                    _ => break None,
                }
            };
            let Some(sep) = sep else { continue };
            // `::` path segment (e.g. `collections::HashMap`) is no binding
            if sep >= 1 && toks[sep] == ":" && toks[sep - 1] == ":" {
                continue;
            }
            if sep >= 1 && is_ident_token(&toks[sep - 1]) {
                let name = toks[sep - 1].clone();
                if name != "let" && !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    names
}

const ITER_METHODS: [&str; 10] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".retain(",
];

/// `name` followed by an iteration method, or used as a `for … in`
/// iterable, anywhere in `code`.
fn iterates_binding(code: &str, name: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(name) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = &code[at + name.len()..];
        if before_ok && ITER_METHODS.iter().any(|m| after.starts_with(m)) {
            return true;
        }
        start = at + name.len();
    }
    if let Some(pos) = code.find(" in ") {
        if code[..pos].contains("for ") || code[..pos].trim_end().ends_with("for") {
            let iterable = code[pos + 4..].split('{').next().unwrap_or("");
            if contains_word(iterable, name)
                && !iterable.contains(&format!("{name}["))
                && !iterable.contains(&format!("{name}.get"))
            {
                return true;
            }
        }
    }
    false
}

fn has_float_evidence(ctx: &str) -> bool {
    if ctx.contains("f32") || ctx.contains("f64") {
        return true;
    }
    let b = ctx.as_bytes();
    b.windows(3)
        .any(|w| w[0].is_ascii_digit() && w[1] == b'.' && w[2].is_ascii_digit())
}

/// Run every rule over one scanned file. `path` must be '/'-normalized;
/// it is matched against the config's per-rule file allowlist.
pub fn check_file(path: &str, lines: &[ScannedLine], cfg: &LintConfig) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let in_test = test_regions(lines);
    let pragmas: Vec<Pragmas> = lines.iter().map(|l| parse_pragmas(&l.comment)).collect();
    let bindings = hash_bindings(lines);

    let allowed_inline = |rule: RuleId, i: usize| -> bool {
        pragmas[i].allows.contains(&rule)
            || (i > 0 && pragmas[i - 1].allows.contains(&rule))
    };
    let mut push = |rule: RuleId, i: usize, msg: String| {
        diags.push(Diagnostic {
            file: path.to_string(),
            line: i + 1,
            rule,
            message: msg,
        });
    };

    // rolling statement context for the float-reduce rule: code since the
    // last `;`, so a multi-line `let x: f64 = …\n.sum();` keeps its type
    // annotation in view
    let mut stmt = String::new();

    for (i, l) in lines.iter().enumerate() {
        let code = &l.code;

        if pragmas[i].bad {
            push(
                RuleId::Pragma,
                i,
                "repro-lint allow pragma is malformed or missing its justification \
                 (expected `// repro-lint: allow(<rule>) <reason>`)"
                    .to_string(),
            );
        }

        // --- safety-comment: every `unsafe` needs a nearby SAFETY note ---
        if contains_word(code, "unsafe")
            && !cfg.file_allowed(RuleId::SafetyComment, path)
            && !allowed_inline(RuleId::SafetyComment, i)
        {
            let lo = i.saturating_sub(SAFETY_LOOKBACK);
            let documented = lines[lo..=i]
                .iter()
                .any(|p| p.comment.contains("SAFETY") || p.comment.contains("# Safety"));
            if !documented {
                push(
                    RuleId::SafetyComment,
                    i,
                    format!(
                        "`unsafe` without a `// SAFETY:` (or `# Safety` doc) comment \
                         within the preceding {SAFETY_LOOKBACK} lines"
                    ),
                );
            }
        }

        // --- thread-spawn: all threads come from the pool layer ---
        if (code.contains("thread::spawn") || code.contains("thread::Builder"))
            && !cfg.file_allowed(RuleId::ThreadSpawn, path)
            && !allowed_inline(RuleId::ThreadSpawn, i)
        {
            push(
                RuleId::ThreadSpawn,
                i,
                "raw thread spawn outside utils/pool.rs — route it through \
                 `Pool` or `utils::pool::spawn_named`"
                    .to_string(),
            );
        }

        let stmt_ctx = |line_code: &str| -> String {
            let mut ctx = stmt.clone();
            ctx.push(' ');
            ctx.push_str(line_code);
            ctx
        };

        if !in_test[i] {
            // --- wall-clock: time reads live behind Clock/StopWatch ---
            if (code.contains("Instant::now") || contains_word(code, "SystemTime"))
                && !cfg.file_allowed(RuleId::WallClock, path)
                && !allowed_inline(RuleId::WallClock, i)
            {
                push(
                    RuleId::WallClock,
                    i,
                    "direct wall-clock read outside utils/timer.rs / utils/bench.rs — \
                     use `StopWatch` or the `Clock` trait so time is injectable"
                        .to_string(),
                );
            }

            // --- hash-iteration: hash order must not leak into results ---
            if !cfg.file_allowed(RuleId::HashIteration, path)
                && !allowed_inline(RuleId::HashIteration, i)
            {
                for name in &bindings {
                    if iterates_binding(code, name) {
                        push(
                            RuleId::HashIteration,
                            i,
                            format!(
                                "iteration over hash-ordered container `{name}` in a \
                                 deterministic module — hash order leaks into results; \
                                 use a BTreeMap/sorted keys or keep to point lookups"
                            ),
                        );
                        break;
                    }
                }
            }

            // --- float-reduce: FP reductions go through linalg kernels ---
            if !cfg.file_allowed(RuleId::FloatReduce, path)
                && !allowed_inline(RuleId::FloatReduce, i)
            {
                let mut flagged = false;
                for op in [".sum(", ".sum::<", ".fold("] {
                    let mut from = 0;
                    while let Some(pos) = code[from..].find(op) {
                        let at = from + pos;
                        from = at + op.len();
                        if flagged {
                            continue;
                        }
                        let after = &code[at..];
                        if op == ".fold(" && (after.contains("::max") || after.contains("::min"))
                        {
                            continue; // order-insensitive min/max fold
                        }
                        if has_float_evidence(&stmt_ctx(code)) {
                            push(
                                RuleId::FloatReduce,
                                i,
                                format!(
                                    "floating-point `{}` reduction outside linalg's \
                                     canonical-order kernels — route through \
                                     `linalg::{{dot, dot_f64, sum_f64, sum_f32}}` or \
                                     justify with a repro-lint allow",
                                    op.trim_end_matches(['(', ':', '<'])
                                ),
                            );
                            flagged = true;
                        }
                    }
                }
            }
        }

        // update the statement buffer: keep code after the last statement
        // or block boundary, so one item's types can't leak float evidence
        // into the next
        match code.rfind([';', '{', '}']) {
            Some(pos) => {
                stmt.clear();
                stmt.push_str(&code[pos + 1..]);
            }
            None => {
                stmt.push(' ');
                stmt.push_str(code);
                // bound pathological statement growth
                if stmt.len() > 4096 {
                    let cut = stmt.len() - 2048;
                    stmt.drain(..cut);
                }
            }
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{lint_source, LintConfig};

    fn diags(src: &str) -> Vec<(usize, RuleId)> {
        lint_source("some/module.rs", src, &LintConfig::default())
            .into_iter()
            .map(|d| (d.line, d.rule))
            .collect()
    }

    #[test]
    fn unsafe_without_safety_fires() {
        let src = "fn f() {\n    unsafe { danger() };\n}\n";
        assert_eq!(diags(src), vec![(2, RuleId::SafetyComment)]);
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let src = "fn f() {\n    // SAFETY: exclusive owner of the cell.\n    unsafe { danger() };\n}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn unsafe_fn_with_safety_doc_passes() {
        let src = "/// Does a thing.\n///\n/// # Safety\n/// Caller keeps i in bounds.\npub unsafe fn get(i: usize) {}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn unsafe_in_string_or_comment_ignored() {
        let src = "fn f() {\n    let s = \"unsafe\";\n    // unsafe in prose is fine\n}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn wall_clock_fires_and_allowlist_exempts() {
        let src = "fn f() {\n    let t = std::time::Instant::now();\n}\n";
        assert_eq!(diags(src), vec![(2, RuleId::WallClock)]);
        let cfg = LintConfig::default();
        let d = lint_source("src/utils/timer.rs", src, &cfg);
        assert!(d.is_empty(), "timer.rs is the sanctioned clock layer");
    }

    #[test]
    fn system_time_fires() {
        let src = "fn f() {\n    let t = std::time::SystemTime::now();\n}\n";
        assert_eq!(diags(src), vec![(2, RuleId::WallClock)]);
    }

    #[test]
    fn hash_iteration_fires_on_tracked_binding() {
        let src = "use std::collections::HashMap;\nfn f(route: &HashMap<u64, u64>) {\n    for (k, v) in route.iter() {\n        drop((k, v));\n    }\n}\n";
        assert_eq!(diags(src), vec![(3, RuleId::HashIteration)]);
    }

    #[test]
    fn hash_lookup_passes() {
        let src = "use std::collections::HashMap;\nfn f(route: &mut HashMap<u64, u64>) {\n    route.insert(1, 2);\n    let _ = route.get(&1);\n    route.remove(&1);\n}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn hash_for_loop_fires() {
        let src = "use std::collections::HashMap;\nfn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n    for k in &m {\n        drop(k);\n    }\n}\n";
        assert_eq!(diags(src), vec![(4, RuleId::HashIteration)]);
    }

    #[test]
    fn wrapped_binding_is_tracked() {
        let src = "fn f() {\n    let writers: Arc<Mutex<HashMap<usize, u8>>> = make();\n    let n = writers.keys();\n}\n";
        assert_eq!(diags(src), vec![(3, RuleId::HashIteration)]);
    }

    #[test]
    fn float_sum_fires_int_sum_passes() {
        let f = "fn f(xs: &[f64]) {\n    let s: f64 = xs.iter().sum();\n}\n";
        assert_eq!(diags(f), vec![(2, RuleId::FloatReduce)]);
        let i = "fn f(xs: &[u64]) {\n    let s: u64 = xs.iter().sum();\n}\n";
        assert!(diags(i).is_empty());
    }

    #[test]
    fn multiline_float_sum_fires() {
        let src = "fn f(xs: &[f64]) {\n    let s: f64 = xs\n        .iter()\n        .sum();\n}\n";
        assert_eq!(diags(src), vec![(4, RuleId::FloatReduce)]);
    }

    #[test]
    fn max_fold_is_exempt() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().cloned().fold(0.0, f64::max)\n}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn additive_float_fold_fires() {
        let src = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().fold(0.0, |a, b| a + b)\n}\n";
        assert_eq!(diags(src), vec![(2, RuleId::FloatReduce)]);
    }

    #[test]
    fn linalg_is_exempt_from_float_reduce() {
        let src = "fn f(xs: &[f64]) {\n    let s: f64 = xs.iter().sum();\n}\n";
        let d = lint_source("src/linalg/mod.rs", src, &LintConfig::default());
        assert!(d.is_empty());
    }

    #[test]
    fn thread_spawn_fires_outside_pool() {
        let src = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(diags(src), vec![(2, RuleId::ThreadSpawn)]);
        let b = "fn f() {\n    std::thread::Builder::new();\n}\n";
        assert_eq!(diags(b), vec![(2, RuleId::ThreadSpawn)]);
        let d = lint_source(
            "src/utils/pool.rs",
            "fn f() { std::thread::spawn(|| {}); }\n",
            &LintConfig::default(),
        );
        assert!(d.is_empty());
    }

    #[test]
    fn pragma_with_reason_suppresses() {
        let src = "fn f(xs: &[f64]) {\n    // repro-lint: allow(float-reduce) serial input-order sum\n    let s: f64 = xs.iter().sum();\n}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn pragma_without_reason_is_a_violation() {
        let src = "fn f(xs: &[f64]) {\n    // repro-lint: allow(float-reduce)\n    let s: f64 = xs.iter().sum();\n}\n";
        let got = diags(src);
        assert!(got.contains(&(2, RuleId::Pragma)), "bare pragma flagged: {got:?}");
        assert!(
            got.contains(&(3, RuleId::FloatReduce)),
            "bare pragma must not suppress: {got:?}"
        );
    }

    #[test]
    fn test_modules_are_skipped_for_determinism_rules() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        let t0 = std::time::Instant::now();\n        let s: f64 = [1.0f64].iter().sum();\n    }\n}\n";
        assert!(diags(src).is_empty());
    }

    #[test]
    fn safety_rule_applies_inside_tests_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        unsafe { danger() };\n    }\n}\n";
        assert_eq!(diags(src), vec![(4, RuleId::SafetyComment)]);
    }

    #[test]
    fn safety_lookback_is_bounded() {
        let mut src = String::from("// SAFETY: too far away.\n");
        for _ in 0..SAFETY_LOOKBACK {
            src.push_str("fn pad() {}\n");
        }
        src.push_str("fn f() { unsafe { danger() }; }\n");
        let got = lint_source("some/module.rs", &src, &LintConfig::default());
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rule, RuleId::SafetyComment);
    }
}
