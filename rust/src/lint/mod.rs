//! repro-lint — a static determinism/safety audit over this repo's sources.
//!
//! The reproduction's core claims (variance reduction, M-workers == 1-worker
//! bit parity, replayable serving) all rest on invariants that used to live
//! in comments: no stray wall-clock reads, no hash-order iteration, one
//! canonical floating-point reduction order, every `unsafe` justified, all
//! threads owned by the pool layer. This module turns those conventions
//! into deny-by-default lint rules with file:line diagnostics, an explicit
//! allowlist for the few sanctioned sites, and inline pragmas (e.g.
//! `// repro-lint: allow(float-reduce) why this site is sound`) for
//! justified one-offs.
//!
//! Run it locally with `cargo run --bin repro_lint` (add `--json` for
//! machine-readable output); CI runs it on every PR. The rule semantics are
//! documented in [`rules`] and the full contract in `rust/DETERMINISM.md`.

mod rules;
mod scan;

pub use rules::SAFETY_LOOKBACK;

use std::fmt;
use std::path::Path;

use crate::utils::json::Json;
use anyhow::{Context, Result};

/// Identifier of one lint rule. `name()` is the stable string used in
/// diagnostics, JSON output, allow pragmas, and fixture markers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// `unsafe` without a nearby `// SAFETY:` / `# Safety` comment.
    SafetyComment,
    /// `Instant::now` / `SystemTime` outside the sanctioned clock layer.
    WallClock,
    /// Iteration over a `HashMap`/`HashSet` binding (hash order leaks).
    HashIteration,
    /// Floating-point `.sum()`/`.fold()` outside linalg's canonical kernels.
    FloatReduce,
    /// `thread::spawn`/`thread::Builder` outside `utils/pool.rs`.
    ThreadSpawn,
    /// Malformed allow pragma (unknown rule or missing justification).
    Pragma,
}

impl RuleId {
    pub const ALL: [RuleId; 6] = [
        RuleId::SafetyComment,
        RuleId::WallClock,
        RuleId::HashIteration,
        RuleId::FloatReduce,
        RuleId::ThreadSpawn,
        RuleId::Pragma,
    ];

    pub fn name(self) -> &'static str {
        match self {
            RuleId::SafetyComment => "safety-comment",
            RuleId::WallClock => "wall-clock",
            RuleId::HashIteration => "hash-iteration",
            RuleId::FloatReduce => "float-reduce",
            RuleId::ThreadSpawn => "thread-spawn",
            RuleId::Pragma => "pragma",
        }
    }

    pub fn from_name(name: &str) -> Option<RuleId> {
        RuleId::ALL.into_iter().find(|r| r.name() == name)
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One rule violation at a source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// '/'-normalized path as given to the linter (relative under a tree walk).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: RuleId,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

impl Diagnostic {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("file", Json::Str(self.file.clone())),
            ("line", Json::Num(self.line as f64)),
            ("rule", Json::Str(self.rule.name().to_string())),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// Per-rule file allowlist. Entries ending in `/` exempt a whole directory
/// (matched anywhere in the path); other entries match by path suffix.
pub struct LintConfig {
    file_allow: Vec<(RuleId, &'static str)>,
}

impl Default for LintConfig {
    fn default() -> Self {
        LintConfig {
            file_allow: vec![
                // The clock layer and the benchmark harness are the only
                // sanctioned wall-clock readers.
                (RuleId::WallClock, "utils/timer.rs"),
                (RuleId::WallClock, "utils/bench.rs"),
                // linalg owns the canonical reduction orders; bench timing
                // statistics are not part of any reproducible result.
                (RuleId::FloatReduce, "linalg/"),
                (RuleId::FloatReduce, "utils/bench.rs"),
                // All threads are born in the pool layer.
                (RuleId::ThreadSpawn, "utils/pool.rs"),
                // Hash containers in the bench harness only feed reports.
                (RuleId::HashIteration, "utils/bench.rs"),
            ],
        }
    }
}

impl LintConfig {
    pub fn file_allowed(&self, rule: RuleId, path: &str) -> bool {
        self.file_allow.iter().any(|&(r, pat)| {
            r == rule
                && if pat.ends_with('/') {
                    path.contains(pat)
                } else {
                    path.ends_with(pat)
                }
        })
    }
}

/// Lint one file's source text. `path` is used for allowlist matching and
/// diagnostics; backslashes are normalized to `/` first.
pub fn lint_source(path: &str, source: &str, cfg: &LintConfig) -> Vec<Diagnostic> {
    let norm = path.replace('\\', "/");
    let lines = scan::scan(source);
    rules::check_file(&norm, &lines, cfg)
}

/// Directories never linted under a tree walk: build output, vendored
/// third-party code, the deliberate-violation corpus, and VCS metadata.
const SKIP_DIRS: [&str; 4] = ["target", "vendor", "lint_fixtures", ".git"];

/// Recursively lint every `.rs` file under `root` (sorted walk, so output
/// order is stable). Returns the diagnostics plus the number of files seen.
pub fn lint_tree(root: &Path, cfg: &LintConfig) -> Result<(Vec<Diagnostic>, usize)> {
    let mut diags = Vec::new();
    let mut files = 0usize;
    walk(root, root, cfg, &mut diags, &mut files)?;
    Ok((diags, files))
}

fn walk(
    root: &Path,
    dir: &Path,
    cfg: &LintConfig,
    diags: &mut Vec<Diagnostic>,
    files: &mut usize,
) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("reading directory {}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if SKIP_DIRS.contains(&name.as_str()) {
                continue;
            }
            walk(root, &path, cfg, diags, files)?;
        } else if name.ends_with(".rs") {
            *files += 1;
            let source = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            diags.extend(lint_source(&rel, &source, cfg));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;
    use std::path::PathBuf;

    fn fixtures_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("lint_fixtures")
    }

    /// Parse the `//~ ERROR <rule>` markers a fixture annotates itself with.
    fn expected_markers(source: &str) -> BTreeSet<(usize, String)> {
        let mut out = BTreeSet::new();
        for (i, line) in source.lines().enumerate() {
            let mut rest = line;
            while let Some(pos) = rest.find("//~ ERROR ") {
                rest = &rest[pos + "//~ ERROR ".len()..];
                let rule = rest
                    .split_whitespace()
                    .next()
                    .expect("marker names a rule")
                    .to_string();
                assert!(
                    RuleId::from_name(&rule).is_some(),
                    "fixture marker names unknown rule `{rule}`"
                );
                out.insert((i + 1, rule));
            }
        }
        out
    }

    #[test]
    fn every_fixture_matches_its_markers_exactly() {
        let dir = fixtures_dir();
        let cfg = LintConfig::default();
        let mut entries: Vec<_> = std::fs::read_dir(&dir)
            .expect("lint_fixtures directory exists")
            .map(|e| e.expect("readable entry").path())
            .filter(|p| p.extension().is_some_and(|e| e == "rs"))
            .collect();
        entries.sort();
        assert!(
            entries.len() >= 6,
            "expected a corpus of fixtures, found {}",
            entries.len()
        );
        for path in entries {
            let source = std::fs::read_to_string(&path).expect("readable fixture");
            let name = path.file_name().unwrap().to_string_lossy().into_owned();
            let expected = expected_markers(&source);
            let got: BTreeSet<(usize, String)> = lint_source(&name, &source, &cfg)
                .into_iter()
                .map(|d| (d.line, d.rule.name().to_string()))
                .collect();
            assert_eq!(
                got, expected,
                "fixture {name}: lint output must match its //~ ERROR markers"
            );
        }
    }

    #[test]
    fn violation_fixtures_fail_and_clean_fixture_passes() {
        let dir = fixtures_dir();
        let cfg = LintConfig::default();
        for (file, should_fail) in [
            ("bad_unsafe.rs", true),
            ("bad_time.rs", true),
            ("bad_hash_iter.rs", true),
            ("bad_float_reduce.rs", true),
            ("bad_thread_spawn.rs", true),
            ("bad_exec_thread.rs", true),
            ("bad_pragma.rs", true),
            ("clean.rs", false),
        ] {
            let path = dir.join(file);
            let source = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("fixture {file} missing: {e}"));
            let diags = lint_source(file, &source, &cfg);
            if should_fail {
                assert!(!diags.is_empty(), "fixture {file} must trip the lint");
            } else {
                assert!(diags.is_empty(), "fixture {file} must be clean: {diags:?}");
            }
        }
    }

    #[test]
    fn lint_src_tree_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let (diags, files) = lint_tree(&src, &LintConfig::default()).expect("tree walk");
        assert!(files > 20, "walk visited the real tree ({files} files)");
        assert!(
            diags.is_empty(),
            "repo source tree must be repro-lint clean:\n{}",
            diags
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn tree_walk_skips_fixture_and_vendor_dirs() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let (_, files) = lint_tree(root, &LintConfig::default()).expect("tree walk");
        let (_, src_files) =
            lint_tree(&root.join("src"), &LintConfig::default()).expect("src walk");
        // the root walk adds tests/ and benches/, but no vendor or fixture files
        assert!(files >= src_files, "root walk covers at least src/");
        let fixture_count = std::fs::read_dir(root.join("lint_fixtures"))
            .expect("fixtures present")
            .count();
        assert!(fixture_count >= 6);
    }

    #[test]
    fn diagnostic_formats_as_file_line_rule() {
        let d = Diagnostic {
            file: "src/foo.rs".into(),
            line: 42,
            rule: RuleId::WallClock,
            message: "msg".into(),
        };
        assert_eq!(d.to_string(), "src/foo.rs:42: [wall-clock] msg");
        let j = d.to_json().to_string();
        assert!(j.contains("\"rule\":\"wall-clock\""), "{j}");
        assert!(j.contains("\"line\":42"), "{j}");
    }

    #[test]
    fn rule_names_roundtrip() {
        for r in RuleId::ALL {
            assert_eq!(RuleId::from_name(r.name()), Some(r));
        }
        assert_eq!(RuleId::from_name("no-such-rule"), None);
    }
}
