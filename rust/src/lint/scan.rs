//! Lexical line scanner for repro-lint.
//!
//! The rule engine must never fire on text inside comments or string
//! literals (a doc sentence mentioning `HashMap` iteration is not a
//! violation), and conversely must be able to *read* comments (the
//! `// SAFETY:` rule and the allow pragmas live there). This module
//! therefore splits every physical source line into two channels:
//!
//! * `code` — the line's characters outside comments, with string and
//!   char literal *contents* blanked out (the delimiting quotes remain,
//!   so token shapes like `("…")` survive for statement tracking);
//! * `comment` — the concatenated text of every comment overlapping the
//!   line (line, block, and doc comments alike).
//!
//! The scanner is a small character-level state machine, not a full
//! lexer: it understands nested block comments, escapes in string/char
//! literals, raw and byte strings (`r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`),
//! and the lifetime-vs-char-literal ambiguity of `'`. That is exactly the
//! subset needed to classify characters; everything else stays verbatim.

/// One physical source line, split into code and comment channels.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScannedLine {
    pub code: String,
    pub comment: String,
}

enum State {
    Code,
    LineComment,
    /// Nested depth of `/* … */`.
    BlockComment(usize),
    /// Inside `"…"`; escapes respected.
    Str,
    /// Inside a raw string closed by `"` followed by this many `#`s.
    RawStr(usize),
    /// Inside a char literal, after the opening `'`.
    Char,
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Try to recognize a raw/byte string opener at `chars[i]` (one of `r"`,
/// `r#…#"`, `b"`, `br"`, `br#…#"`). Returns `(next_index, state)` past the
/// opening quote on success.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, State)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        // plain byte string b"…"
        return if j > i { Some((j + 1, State::Str)) } else { None };
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j + 1, State::RawStr(hashes)))
    } else {
        None
    }
}

/// Split `source` into per-line code/comment channels (see module docs).
pub fn scan(source: &str) -> Vec<ScannedLine> {
    let chars: Vec<char> = source.chars().collect();
    let mut lines = Vec::new();
    let mut cur = ScannedLine::default();
    let mut state = State::Code;
    // last code character emitted, to keep `r`/`b` inside identifiers
    // (e.g. `attr`, `curb`) from being mistaken for raw-string prefixes
    let mut prev_code: char = ' ';
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(1);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    cur.code.push('"');
                    prev_code = '"';
                    state = State::Str;
                    i += 1;
                    continue;
                }
                if (c == 'r' || c == 'b') && !is_ident_char(prev_code) {
                    if let Some((next, st)) = raw_string_open(&chars, i) {
                        cur.code.push('"');
                        prev_code = '"';
                        state = st;
                        i = next;
                        continue;
                    }
                }
                if c == '\'' {
                    // char literal iff it closes as one; otherwise lifetime
                    if chars.get(i + 1) == Some(&'\\') {
                        cur.code.push('\'');
                        prev_code = '\'';
                        state = State::Char;
                        i += 1;
                        continue;
                    }
                    if chars.get(i + 2) == Some(&'\'')
                        && chars.get(i + 1).is_some_and(|&n| n != '\'' && n != '\n')
                    {
                        cur.code.push('\'');
                        cur.code.push('\'');
                        prev_code = '\'';
                        i += 3;
                        continue;
                    }
                    cur.code.push('\'');
                    prev_code = '\'';
                    i += 1;
                    continue;
                }
                cur.code.push(c);
                prev_code = c;
                i += 1;
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2; // skip escaped char (contents are blanked anyway)
                } else if c == '"' {
                    cur.code.push('"');
                    prev_code = '"';
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let closed = (1..=hashes).all(|h| chars.get(i + h) == Some(&'#'));
                    if closed {
                        cur.code.push('"');
                        prev_code = '"';
                        state = State::Code;
                        i += 1 + hashes;
                        continue;
                    }
                }
                i += 1;
            }
            State::Char => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    cur.code.push('\'');
                    prev_code = '\'';
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    // final line without trailing newline
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        scan(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_split_off() {
        let ls = scan("let x = 1; // trailing note\n// full line\nlet y = 2;\n");
        assert_eq!(ls[0].code, "let x = 1; ");
        assert_eq!(ls[0].comment, " trailing note");
        assert_eq!(ls[1].code, "");
        assert_eq!(ls[1].comment, " full line");
        assert_eq!(ls[2].code, "let y = 2;");
    }

    #[test]
    fn string_contents_blanked() {
        let ls = scan("let s = \"Instant::now // not code\";\n");
        assert_eq!(ls[0].code, "let s = \"\";");
        assert_eq!(ls[0].comment, "");
    }

    #[test]
    fn raw_and_byte_strings() {
        assert_eq!(codes("let s = r#\"a \"quoted\" b\"#;\n")[0], "let s = \"\";");
        assert_eq!(codes("let s = r\"plain\";\n")[0], "let s = \"\";");
        assert_eq!(codes("let s = b\"bytes\";\n")[0], "let s = \"\";");
        assert_eq!(codes("let s = br#\"raw bytes\"#;\n")[0], "let s = \"\";");
        // identifier ending in r followed by a string is not a raw string
        assert_eq!(codes("var\"x\"\n")[0], "var\"\"");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        assert_eq!(codes("fn f<'a>(x: &'a str) {}\n")[0], "fn f<'a>(x: &'a str) {}");
        assert_eq!(codes("let c = 'x';\n")[0], "let c = '';");
        assert_eq!(codes("let c = '\\n';\n")[0], "let c = '';");
        assert_eq!(codes("let c = '\\'';\n")[0], "let c = '';");
    }

    #[test]
    fn nested_block_comments() {
        let ls = scan("a /* one /* two */ still */ b\n");
        assert_eq!(ls[0].code, "a  b");
        assert_eq!(ls[0].comment, " one  two  still ");
    }

    #[test]
    fn multiline_string_keeps_state() {
        let ls = scan("let s = \"line one\nline two\";\nlet t = 1;\n");
        assert_eq!(ls[0].code, "let s = \"");
        assert_eq!(ls[1].code, "\";");
        assert_eq!(ls[2].code, "let t = 1;");
    }

    #[test]
    fn block_comment_spans_lines() {
        let ls = scan("before /* comment\nspanning */ after\n");
        assert_eq!(ls[0].code, "before ");
        assert_eq!(ls[0].comment, " comment");
        assert_eq!(ls[1].code, " after");
        assert_eq!(ls[1].comment, "spanning ");
    }
}
