//! Chunked streaming evaluation with optional Eq. 5 bias removal.
//!
//! Scoring a batch against all C labels runs through the `eval_chunk*` HLO
//! artifacts: each call scores one [B, Cc] label chunk on the MXU-shaped
//! Pallas kernel and reduces it to four [B] vectors (chunk max, argmax,
//! sum-exp partial, true-label score). Rust merges chunks with the
//! streaming log-sum-exp rule, so metrics over C = 10^4..10^6 labels never
//! materialize a [B, C] matrix on the host.
//!
//! For the proposed method, prediction scores are ξ_y(x) + log p_n(y|x)
//! (Theorem 1 / Eq. 5); the correction matrix is produced per chunk by the
//! auxiliary tree's activation sweep. All host-side per-class score math
//! lives in the shared [`crate::score::Scorer`] core (the reference
//! evaluator below is orchestration over it); this module adds only the
//! HLO-chunk plumbing — literal packing, correction-block slicing, and
//! the streaming LSE merge across chunks.

use crate::data::Dataset;
use crate::linalg::lse_merge;
use crate::model::ParamStore;
use crate::runtime::{lit_f32, lit_i32, read_f32, read_i32, Executable, Registry};
use crate::sampler::AdversarialSampler;
use crate::score::{ScoreScratch, Scorer};
use crate::utils::{Pool, PAR_MIN_MERGE_ROWS};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Score floor used for padded label slots (must underflow exp()).
const PAD_BIAS: f32 = -1.0e30;
/// Sentinel the eval artifact returns for "true label not in this chunk".
const NEG_INF_SENTINEL: f32 = -1.0e30;

/// Aggregate predictive metrics over an evaluation set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    /// Mean predictive log-likelihood per test point (Figure 1, panels 1&3).
    pub log_likelihood: f64,
    /// Top-1 predictive accuracy (Figure 1, panels 2&4).
    pub accuracy: f64,
    /// Points evaluated.
    pub n: usize,
}

/// Precomputed Eq. 5 correction matrix log p_n(y|x) for a fixed
/// (auxiliary model, evaluation set) pair.
///
/// The auxiliary tree is frozen during training (Sec. 2.2: the generator
/// stays constant while the discriminator trains), so the correction for
/// a fixed eval subset never changes — computing the O(N_eval · C · k)
/// sweep once instead of per checkpoint removed ~80 s of real time per
/// Figure-1 run (EXPERIMENTS.md §Perf, iteration 4).
pub struct LpnCache {
    /// Row-major [num_rows, num_classes].
    pub rows: Vec<f32>,
    pub num_rows: usize,
    pub num_classes: usize,
}

impl LpnCache {
    /// Build from the tree's activation sweep over every data row.
    pub fn build(adv: &AdversarialSampler, data: &Dataset) -> Self {
        Self::build_with(adv, data, &Pool::serial())
    }

    /// [`LpnCache::build`] with the O(N·C·k) per-example sweep sharded
    /// over a worker pool. Rows are independent with one writer each, so
    /// the cache is identical at any worker count. Within each shard, rows
    /// run through the kernel's batched activation sweep in blocks of 8
    /// ([`AdversarialSampler::log_prob_all_block`]), which amortizes every
    /// node-weight load across the block.
    pub fn build_with(adv: &AdversarialSampler, data: &Dataset, pool: &Pool) -> Self {
        let c = data.num_classes;
        let n = data.len();
        let kf = data.feat_dim;
        let mut rows = vec![0f32; n * c];
        pool.for_each_span(&mut rows, c, |first_row, span| {
            let span_rows = span.len() / c;
            let mut scratch = crate::sampler::LpnBlockScratch::default();
            let mut j = 0;
            while j < span_rows {
                let hi = (j + crate::tree::LANES).min(span_rows);
                // feature rows are contiguous in the dataset, so the block
                // is a direct slice — no copy
                let lo_i = first_row + j;
                let hi_i = first_row + hi;
                adv.log_prob_all_block_with(
                    &data.features[lo_i * kf..hi_i * kf],
                    hi - j,
                    &mut span[j * c..hi * c],
                    &mut scratch,
                );
                j = hi;
            }
        });
        Self { rows, num_rows: n, num_classes: c }
    }
}

/// Chunked evaluator bound to the AOT artifact shapes.
pub struct Evaluator {
    exec_plain: Arc<Executable>,
    exec_corrected: Arc<Executable>,
    pub eval_b: usize,
    pub eval_c: usize,
}

impl Evaluator {
    pub fn new(registry: &Registry) -> Result<Self> {
        let exec_plain = registry.get_by_prefix("eval_chunk_plain_")?;
        let exec_corrected = registry.get_by_prefix("eval_chunk_B")?;
        let shapes = &registry.manifest.shapes;
        Ok(Self {
            exec_plain,
            exec_corrected,
            eval_b: shapes.eval_b,
            eval_c: shapes.eval_c,
        })
    }

    /// Evaluate `params` on `data`. When `corrector` is given, scores are
    /// bias-corrected per Eq. 5 (ξ + log p_n); the correction matrix is
    /// recomputed per call — prefer [`Evaluator::evaluate_cached`] with an
    /// [`LpnCache`] when the same (tree, eval set) pair is scored
    /// repeatedly (the tree is frozen during training, so the cache is
    /// exact).
    pub fn evaluate(
        &self,
        params: &ParamStore,
        data: &Dataset,
        corrector: Option<&AdversarialSampler>,
    ) -> Result<EvalResult> {
        let cache = corrector.map(|adv| LpnCache::build(adv, data));
        self.evaluate_cached(params, data, cache.as_ref())
    }

    /// Evaluate with a prebuilt Eq. 5 correction cache (None = raw ξ).
    pub fn evaluate_cached(
        &self,
        params: &ParamStore,
        data: &Dataset,
        lpn_cache: Option<&LpnCache>,
    ) -> Result<EvalResult> {
        self.evaluate_cached_with(params, data, lpn_cache, &Pool::serial())
    }

    /// [`Evaluator::evaluate_cached`] with the host-side per-chunk work —
    /// the `[B, Cc]` correction-block slicing and the per-row streaming
    /// LSE/argmax merge — sharded over a worker pool. Rows are merged
    /// independently with one writer each (contiguous spans), so the
    /// result is bit-identical at any worker count; PJRT execution stays
    /// on the calling thread.
    pub fn evaluate_cached_with(
        &self,
        params: &ParamStore,
        data: &Dataset,
        lpn_cache: Option<&LpnCache>,
        pool: &Pool,
    ) -> Result<EvalResult> {
        anyhow::ensure!(!data.is_empty(), "empty evaluation set");
        anyhow::ensure!(
            params.feat_dim == data.feat_dim,
            "feature dim mismatch: params K={} vs data K={}",
            params.feat_dim,
            data.feat_dim
        );
        let b = self.eval_b;
        let cc = self.eval_c;
        let c = params.num_classes;
        let k = params.feat_dim;
        let n_chunks = c.div_ceil(cc);

        // pre-pad label chunks once per evaluate() call
        let chunks: Vec<(Vec<f32>, Vec<f32>)> = (0..n_chunks)
            .map(|ci| {
                let lo = ci * cc;
                let hi = ((ci + 1) * cc).min(c);
                let mut wc = vec![0f32; cc * k];
                let mut bc = vec![PAD_BIAS; cc];
                wc[..(hi - lo) * k].copy_from_slice(&params.w[lo * k..hi * k]);
                bc[..hi - lo].copy_from_slice(&params.b[lo..hi]);
                (wc, bc)
            })
            .collect();
        let chunk_lits: Vec<(xla::Literal, xla::Literal)> = chunks
            .iter()
            .map(|(wc, bc)| Ok((lit_f32(wc, &[cc, k])?, lit_f32(bc, &[cc])?)))
            .collect::<Result<_>>()?;

        if let Some(cache) = lpn_cache {
            anyhow::ensure!(
                cache.num_rows == data.len() && cache.num_classes == c,
                "LpnCache shape mismatch: cache ({}, {}) vs data ({}, {})",
                cache.num_rows,
                cache.num_classes,
                data.len(),
                c
            );
        }
        let mut sum_loglik = 0f64;
        let mut correct = 0usize;
        let mut total = 0usize;

        let n = data.len();
        let mut batch_x = vec![0f32; b * k];
        // correction-block scratch, reused across batches and chunks
        let mut lpn_blk = vec![0f32; b * cc];
        let mut merge = vec![RowMerge::default(); b];

        for batch_lo in (0..n).step_by(b) {
            let batch_hi = (batch_lo + b).min(n);
            let valid = batch_hi - batch_lo;
            // pad the batch by repeating the first row (excluded from metrics)
            for j in 0..b {
                let src = if j < valid { batch_lo + j } else { batch_lo };
                batch_x[j * k..(j + 1) * k].copy_from_slice(data.x(src));
            }
            let x_lit = lit_f32(&batch_x, &[b, k])?;

            // streaming merge state per row
            merge.iter_mut().for_each(|r| *r = RowMerge::default());

            for (ci, (wc_lit, bc_lit)) in chunk_lits.iter().enumerate() {
                let lo = ci * cc;
                let hi = ((ci + 1) * cc).min(c);
                let y_rel: Vec<i32> = (0..b)
                    .map(|j| {
                        let src = if j < valid { batch_lo + j } else { batch_lo };
                        let y = data.y(src) as usize;
                        if (lo..hi).contains(&y) {
                            (y - lo) as i32
                        } else {
                            -1
                        }
                    })
                    .collect();
                let y_lit = lit_i32(&y_rel, &[b])?;

                let outs = if let Some(cache) = lpn_cache {
                    // slice the [B, Cc] correction block, rows sharded over
                    // the pool (pad cols get 0; their bias PAD_BIAS keeps
                    // them irrelevant; padded batch rows reuse row
                    // `batch_lo` like the features)
                    pool.for_each_span(&mut lpn_blk, cc, |first_row, span| {
                        for (t, dst) in span.chunks_exact_mut(cc).enumerate() {
                            let j = first_row + t;
                            let src = if j < valid { batch_lo + j } else { batch_lo };
                            dst[..hi - lo]
                                .copy_from_slice(&cache.rows[src * c + lo..src * c + hi]);
                            dst[hi - lo..].iter_mut().for_each(|v| *v = 0.0);
                        }
                    });
                    let lpn_lit = lit_f32(&lpn_blk, &[b, cc])?;
                    self.exec_corrected
                        .run(&[
                            x_lit.clone(),
                            wc_lit.clone(),
                            bc_lit.clone(),
                            lpn_lit,
                            y_lit,
                        ])
                        .context("eval_chunk")?
                } else {
                    self.exec_plain
                        .run(&[x_lit.clone(), wc_lit.clone(), bc_lit.clone(), y_lit])
                        .context("eval_chunk_plain")?
                };

                let cmax = read_f32(&outs[0])?;
                let cargmax = read_i32(&outs[1])?;
                let csum = read_f32(&outs[2])?;
                let ctrue = read_f32(&outs[3])?;
                // per-row chunk merge: rows are independent with one writer
                // each (contiguous spans), so the merged state is identical
                // at any worker count; tiny batches skip the dispatch
                let do_merge = |first: usize, span: &mut [RowMerge]| {
                    for (t, row) in span.iter_mut().enumerate() {
                        let j = first + t;
                        if cmax[j] > row.best_score {
                            row.best_score = cmax[j];
                            row.best_label = (lo + cargmax[j] as usize) as u32;
                        }
                        let (m, s) = lse_merge(row.run_max, row.run_sum, cmax[j], csum[j]);
                        row.run_max = m;
                        row.run_sum = s;
                        if ctrue[j] > NEG_INF_SENTINEL {
                            row.true_score = ctrue[j];
                        }
                    }
                };
                if pool.is_serial() || b < PAR_MIN_MERGE_ROWS {
                    do_merge(0, &mut merge);
                } else {
                    pool.for_each_span(&mut merge, 1, do_merge);
                }
            }

            for (j, row) in merge.iter().enumerate().take(valid) {
                let src = batch_lo + j;
                let lse = row.run_max + row.run_sum.ln();
                sum_loglik += (row.true_score - lse) as f64;
                if row.best_label == data.y(src) {
                    correct += 1;
                }
                total += 1;
            }
        }

        Ok(EvalResult {
            log_likelihood: sum_loglik / total as f64,
            accuracy: correct as f64 / total as f64,
            n: total,
        })
    }
}

/// Per-row streaming merge state of the chunked evaluator.
#[derive(Clone, Copy)]
struct RowMerge {
    best_score: f32,
    best_label: u32,
    run_max: f32,
    run_sum: f32,
    true_score: f32,
}

impl Default for RowMerge {
    fn default() -> Self {
        RowMerge {
            best_score: f32::NEG_INFINITY,
            best_label: 0,
            run_max: f32::NEG_INFINITY,
            run_sum: 0.0,
            true_score: f32::NEG_INFINITY,
        }
    }
}

/// Pure-rust reference evaluator (no PJRT) used by unit/integration tests
/// to cross-check the chunked HLO path, and by the SNR experiment where C
/// is tiny.
pub fn evaluate_reference(
    params: &ParamStore,
    data: &Dataset,
    corrector: Option<&AdversarialSampler>,
) -> EvalResult {
    evaluate_reference_with(params, data, corrector, &Pool::serial())
}

/// [`evaluate_reference`] with the O(N·C·K) per-example sweep sharded over
/// a worker pool. Per-shard partial sums are reduced in shard order, so the
/// result is deterministic for a given worker count (the f64 summation
/// order — and thus the last ulp of `log_likelihood` — can differ between
/// worker counts; `accuracy` and `n` are exact everywhere).
///
/// Within each shard, examples run in 8-row blocks through the canonical
/// [`Scorer`] (the tiled ξ sweep plus the Eq. 5 correction via the tree
/// kernel's batched activation sweep — see [`crate::score`]); per-example
/// results are bit-identical to the naive per-row loops, and this function
/// predates the scorer, so its outputs are unchanged bit for bit.
pub fn evaluate_reference_with(
    params: &ParamStore,
    data: &Dataset,
    corrector: Option<&AdversarialSampler>,
    pool: &Pool,
) -> EvalResult {
    let c = params.num_classes;
    let k = params.feat_dim;
    let n = data.len();
    let shards = pool.num_workers();
    let per = n.div_ceil(shards.max(1)).max(1);
    let scorer = Scorer::from_params(params, corrector);
    let mut partials = vec![(0f64, 0usize); shards];
    {
        let partials_view = crate::utils::SharedMut::new(&mut partials);
        let partials_ref = &partials_view;
        let scorer_ref = &scorer;
        pool.run_sharded(move |shard| {
            let lo = (shard * per).min(n);
            let hi = ((shard + 1) * per).min(n);
            let mut sum_loglik = 0f64;
            let mut correct = 0usize;
            let tile = crate::tree::LANES;
            let mut scores_blk = vec![0f32; tile * c];
            let mut scratch = ScoreScratch::default();
            let mut blo = lo;
            while blo < hi {
                let bhi = (blo + tile).min(hi);
                let mb = bhi - blo;
                let x_blk = &data.features[blo * k..bhi * k];
                scorer_ref.score_block_with(x_blk, mb, &mut scores_blk[..mb * c], &mut scratch);
                for j in 0..mb {
                    let scores = &scores_blk[j * c..(j + 1) * c];
                    let lse = crate::score::row_lse(scores);
                    let y = data.y(blo + j) as usize;
                    sum_loglik += (scores[y] - lse) as f64;
                    if crate::score::row_argmax(scores) == y {
                        correct += 1;
                    }
                }
                blo = bhi;
            }
            // SAFETY: slot `shard` is written only by this shard.
            unsafe { *partials_ref.get_mut(shard) = (sum_loglik, correct) };
        });
    }
    let sum_loglik: f64 = crate::linalg::sum_f64(partials.iter().map(|p| p.0));
    let correct: usize = partials.iter().map(|p| p.1).sum();
    EvalResult {
        log_likelihood: sum_loglik / n as f64,
        accuracy: correct as f64 / n as f64,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::Rng;

    fn toy(c: usize, k: usize, n: usize) -> (ParamStore, Dataset) {
        let mut rng = Rng::new(1);
        let mut p = ParamStore::zeros(c, k, 0.1);
        for v in p.w.iter_mut() {
            *v = rng.normal();
        }
        for v in p.b.iter_mut() {
            *v = 0.1 * rng.normal();
        }
        let feats: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        let labels: Vec<u32> = (0..n).map(|_| rng.below(c) as u32).collect();
        (p, Dataset::new(feats, labels, k, c))
    }

    #[test]
    fn reference_eval_perfect_model() {
        // params whose row y = one-hot(y)*BIG classify e_y features perfectly
        let c = 8;
        let k = 8;
        let mut p = ParamStore::zeros(c, k, 0.1);
        for y in 0..c {
            p.w[y * k + y] = 20.0;
        }
        let mut feats = vec![0f32; c * k];
        let labels: Vec<u32> = (0..c as u32).collect();
        for y in 0..c {
            feats[y * k + y] = 1.0;
        }
        let data = Dataset::new(feats, labels, k, c);
        let r = evaluate_reference(&p, &data, None);
        assert_eq!(r.accuracy, 1.0);
        assert!(r.log_likelihood > -0.01);
    }

    #[test]
    fn reference_eval_zero_model_is_uniform() {
        let (mut p, data) = toy(16, 4, 50);
        p.w.iter_mut().for_each(|v| *v = 0.0);
        p.b.iter_mut().for_each(|v| *v = 0.0);
        let r = evaluate_reference(&p, &data, None);
        assert!((r.log_likelihood + (16f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn parallel_reference_matches_serial() {
        let (p, data) = toy(16, 4, 131); // not a multiple of any shard count
        let serial = evaluate_reference(&p, &data, None);
        for workers in [2, 3, 4] {
            let par = evaluate_reference_with(&p, &data, None, &Pool::new(workers));
            assert_eq!(par.n, serial.n, "workers={workers}");
            assert_eq!(par.accuracy, serial.accuracy, "workers={workers}");
            assert!(
                (par.log_likelihood - serial.log_likelihood).abs() < 1e-9,
                "workers={workers}: {} vs {}",
                par.log_likelihood,
                serial.log_likelihood
            );
        }
    }

    #[test]
    fn lpn_cache_parallel_matches_serial() {
        use crate::config::{DatasetPreset, SyntheticConfig, TreeConfig};
        use crate::data::Splits;
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        cfg.n_test = 257;
        let splits = Splits::synthetic(&cfg);
        let tcfg = TreeConfig { aux_dim: 6, ..Default::default() };
        let (adv, _) = AdversarialSampler::fit(&splits.train, &tcfg, 5);
        let serial = LpnCache::build(&adv, &splits.test);
        for workers in [2, 4] {
            let par = LpnCache::build_with(&adv, &splits.test, &Pool::new(workers));
            assert_eq!(par.rows, serial.rows, "workers={workers}");
        }
    }

    #[test]
    fn loglik_upper_bound_zero() {
        let (p, data) = toy(10, 6, 64);
        let r = evaluate_reference(&p, &data, None);
        assert!(r.log_likelihood < 0.0);
        assert!(r.accuracy <= 1.0);
        assert_eq!(r.n, 64);
    }
}
