//! Typed configuration system: dataset presets, method hyperparameters and
//! experiment settings, serializable to/from JSON so runs are fully
//! reproducible from a config file (`repro train --config cfg.json`).
//!
//! The preset hyperparameters mirror the paper's Table 1 tuning grid
//! (Adagrad learning rate rho, regularizer lambda, auxiliary dimension
//! k=16, aux regularizer lambda_n=0.1), re-tuned for the simulated
//! datasets (see EXPERIMENTS.md E1).

use crate::utils::json::Json;
use std::fmt;
use std::path::Path;
use std::str::FromStr;

/// The seven training methods of Sec. 5 (proposed + baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Proposed: adversarial negative sampling + Eq. 5 bias removal.
    Adversarial,
    /// Baseline (i): uniform negative sampling.
    Uniform,
    /// Baseline (ii): empirical label-frequency negative sampling.
    Frequency,
    /// Baseline (iii): NCE with the tree as base distribution.
    Nce,
    /// Baseline (iv): Augment & Reduce (sampled softmax bound).
    AugmentReduce,
    /// Baseline (v): One-vs-Each.
    OneVsEach,
    /// Full softmax (Eq. 1); small label sets only (Appendix A.2).
    Softmax,
}

impl Method {
    pub const ALL_SAMPLING: [Method; 6] = [
        Method::Adversarial,
        Method::Uniform,
        Method::Frequency,
        Method::Nce,
        Method::AugmentReduce,
        Method::OneVsEach,
    ];

    /// Does this method need the fitted auxiliary tree?
    pub fn needs_tree(self) -> bool {
        matches!(self, Method::Adversarial | Method::Nce)
    }

    /// Does prediction apply the Eq. 5 bias correction (+ log p_n(y|x))?
    pub fn corrects_bias(self) -> bool {
        matches!(self, Method::Adversarial)
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Adversarial => "adversarial",
            Method::Uniform => "uniform",
            Method::Frequency => "frequency",
            Method::Nce => "nce",
            Method::AugmentReduce => "augment-reduce",
            Method::OneVsEach => "one-vs-each",
            Method::Softmax => "softmax",
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "adversarial" | "adv" => Method::Adversarial,
            "uniform" => Method::Uniform,
            "frequency" | "freq" => Method::Frequency,
            "nce" => Method::Nce,
            "augment-reduce" | "ar" => Method::AugmentReduce,
            "one-vs-each" | "ove" => Method::OneVsEach,
            "softmax" => Method::Softmax,
            other => anyhow::bail!(
                "unknown method {other:?} (adv|uniform|freq|nce|ar|ove|softmax)"
            ),
        })
    }
}

/// Per-method optimizer hyperparameters (the paper's Table 1 columns).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    /// Adagrad learning rate (paper's rho).
    pub lr: f32,
    /// Regularizer strength (paper's lambda; Eq. 6 for NS-family,
    /// L2-on-scores elsewhere).
    pub lambda: f32,
    /// Negatives per positive for AugmentReduce (importance weight
    /// (C-1)/S); 1 everywhere else.
    pub num_negatives: usize,
}

impl Default for Hyper {
    fn default() -> Self {
        Self { lr: 0.01, lambda: 1e-3, num_negatives: 1 }
    }
}

/// Step-overlap protocol (PR 4 double buffering, PR 10 three-deep
/// pipeline): run step t+1's host stages — parameter gather, literal
/// packing — on the worker pool while step t executes on the PJRT
/// runtime, with conflict-aware row leasing keeping the learning curve
/// bit-identical to the serial protocol (see `train` / `model` module
/// docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapMode {
    /// Overlap whenever it can help: pool has background workers and the
    /// method is not the dense softmax baseline (whose "gather" is the
    /// whole parameter matrix — every row conflicts).
    Auto,
    /// Force the double-buffered protocol (still a no-op for softmax and
    /// on a serial pool, where the stages degrade to inline calls).
    On,
    /// Strictly serial gather → execute → scatter (the reference
    /// protocol; bit-identical results either way).
    Off,
    /// Three-slot pipeline: executes run back-to-back on a dedicated
    /// thread while the coordinator drains readback→scatter for step t
    /// and the pool builds step t+2's gather/literals (still a no-op for
    /// softmax; bit-identical results at every depth).
    Pipeline,
}

impl OverlapMode {
    /// Default for newly constructed configs: the `REPRO_OVERLAP` env var
    /// (`auto|on|off|pipeline`, used by CI to run the test suite under
    /// every protocol) or [`OverlapMode::Auto`]. An unparsable value panics
    /// with a clear message rather than silently falling back — a CI leg
    /// meant to force one protocol must never quietly run the other.
    pub fn env_default() -> Self {
        match std::env::var("REPRO_OVERLAP") {
            Ok(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid REPRO_OVERLAP={v:?}: {e:#}")),
            Err(_) => OverlapMode::Auto,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OverlapMode::Auto => "auto",
            OverlapMode::On => "on",
            OverlapMode::Off => "off",
            OverlapMode::Pipeline => "pipeline",
        }
    }
}

impl fmt::Display for OverlapMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for OverlapMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => OverlapMode::Auto,
            "on" | "true" | "1" => OverlapMode::On,
            "off" | "false" | "0" => OverlapMode::Off,
            "pipeline" | "3" => OverlapMode::Pipeline,
            other => anyhow::bail!("unknown overlap mode {other:?} (auto|on|off|pipeline)"),
        })
    }
}

/// Hard cap on the auxiliary (PCA) dimension k: the samplers project raw
/// features into fixed-size stack buffers of this many floats on the
/// per-negative-draw hot path (`sampler::AdversarialSampler`), so larger
/// values must be rejected when a config is loaded, not discovered as a
/// slice panic mid-training.
pub const MAX_AUX_DIM: usize = 64;

/// Auxiliary-model (Sec. 3) settings.
#[derive(Clone, Copy, Debug)]
pub struct TreeConfig {
    /// PCA dimension k (paper: 16). At most [`MAX_AUX_DIM`].
    pub aux_dim: usize,
    /// Node regularizer lambda_n (paper: 0.1).
    pub lambda_n: f64,
    /// Max Newton iterations per continuous phase.
    pub newton_iters: usize,
    /// Max (continuous, discrete) alternations per node.
    pub max_alternations: usize,
    /// Optional cap on training points used for fitting (0 = all).
    pub fit_subsample: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        Self {
            aux_dim: 16,
            lambda_n: 0.1,
            newton_iters: 8,
            max_alternations: 4,
            fit_subsample: 0,
        }
    }
}

impl TreeConfig {
    /// Reject knob values that would otherwise fail deep in the fit or
    /// sampling path. Called whenever a config is loaded from JSON; callers
    /// constructing a `TreeConfig` directly can invoke it themselves.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.aux_dim >= 1, "aux_dim must be at least 1");
        anyhow::ensure!(
            self.aux_dim <= MAX_AUX_DIM,
            "aux_dim {} exceeds the supported maximum {} (the samplers \
             project into a fixed {}-float stack buffer)",
            self.aux_dim,
            MAX_AUX_DIM,
            MAX_AUX_DIM
        );
        anyhow::ensure!(self.newton_iters >= 1, "newton_iters must be at least 1");
        anyhow::ensure!(self.max_alternations >= 1, "max_alternations must be at least 1");
        Ok(())
    }
}

/// Quantized classifier-row storage for serving (`ServeConfig.quantize`):
/// serving carries no optimizer state, so rows can be stored at reduced
/// precision — half the memory-bound bytes per scoring sweep for f16, a
/// quarter for i8 — with f32 accumulation and deterministic decode
/// (see `score::RowStore`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// Full-precision f32 rows (the reference path).
    Off,
    /// IEEE binary16 rows, round-to-nearest-even at model load.
    F16,
    /// Symmetric i8 rows + one f32 scale per row.
    I8,
}

impl QuantMode {
    /// Default for newly constructed configs: the `REPRO_QUANTIZE` env var
    /// (`off|f16|i8`, used by CI to run the serving suite under a
    /// quantized leg) or [`QuantMode::Off`]. An unparsable value panics
    /// with a clear message rather than silently falling back — a CI leg
    /// meant to force one format must never quietly run another.
    pub fn env_default() -> Self {
        match std::env::var("REPRO_QUANTIZE") {
            Ok(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid REPRO_QUANTIZE={v:?}: {e:#}")),
            Err(_) => QuantMode::Off,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::F16 => "f16",
            QuantMode::I8 => "i8",
        }
    }
}

impl fmt::Display for QuantMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for QuantMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "off" | "f32" | "none" => QuantMode::Off,
            "f16" | "half" => QuantMode::F16,
            "i8" | "int8" => QuantMode::I8,
            other => anyhow::bail!("unknown quantize mode {other:?} (off|f16|i8)"),
        })
    }
}

/// Serving knobs for `repro serve` / `repro predict` (the serving twin of
/// [`RunConfig`]): beam width of the tree-guided candidate retrieval,
/// predictions returned per query, the exact-oracle toggle, and the
/// classifier-row storage format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Beam width B of the tree descent: frontier nodes kept per level.
    /// The final level expands to up to 2B leaf candidates, which the
    /// scorer re-ranks exactly. Ignored when `exact` is set.
    pub beam: usize,
    /// Top-k predictions returned per query (clamped to C).
    pub k: usize,
    /// Score all C classes (the O(C) oracle sweep) instead of beam
    /// retrieval. Exact but ~C/(B·log C) times more work per query.
    pub exact: bool,
    /// Classifier-row storage format (`repro serve --quantize`). Changes
    /// which scores are computed (quantized rows score slightly
    /// differently), but every mode is itself bit-deterministic across
    /// worker counts and batching.
    pub quantize: QuantMode,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { beam: 64, k: 5, exact: false, quantize: QuantMode::env_default() }
    }
}

impl ServeConfig {
    /// Reject knob values that would otherwise fail inside the predictor.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.beam >= 1, "beam width must be at least 1");
        anyhow::ensure!(self.k >= 1, "top-k must be at least 1");
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("beam", Json::Num(self.beam as f64)),
            ("k", Json::Num(self.k as f64)),
            ("exact", Json::Bool(self.exact)),
            ("quantize", Json::Str(self.quantize.to_string())),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let mut cfg = Self {
            beam: v.get("beam")?.as_usize()?,
            k: v.get("k")?.as_usize()?,
            exact: v.get("exact")?.as_bool()?,
            ..Self::default()
        };
        // optional for configs saved before the quantize knob existed
        if let Some(q) = v.opt("quantize") {
            cfg.quantize = q.as_str()?.parse()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Daemon knobs for `repro serve --daemon` (see `serve::daemon`): bounded
/// admission, deadline-aware micro-batching, and the graceful-degradation
/// beam ladder.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DaemonConfig {
    /// Admission queue bound; requests past it get a typed `rejected`
    /// response (load shedding, never a silent drop).
    pub queue_capacity: usize,
    /// Per-request latency budget in milliseconds: requests still queued
    /// past it are cancelled with a typed `rejected` response, and a
    /// quarter of it is the micro-batch coalescing window.
    pub deadline_ms: u64,
    /// Hard cap on requests coalesced into one predict batch.
    pub max_batch: usize,
    /// Degradation ladder: beam widths stepped through (left to right)
    /// under sustained overload, restored as the queue drains. Each must
    /// be narrower than the previous (and than the serving beam). Empty
    /// disables degradation. Ignored on the exact path.
    pub degrade_beams: Vec<usize>,
    /// Consecutive overloaded flushes (queue at least half full after a
    /// batch) before stepping one tier down the ladder.
    pub overload_trip: usize,
    /// Supervisor patience: a predict batch not answered within this many
    /// milliseconds abandons the worker and respawns it.
    pub worker_timeout_ms: u64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            queue_capacity: 1024,
            deadline_ms: 50,
            max_batch: 64,
            degrade_beams: vec![16, 4],
            overload_trip: 3,
            worker_timeout_ms: 2000,
        }
    }
}

impl DaemonConfig {
    /// Coalescing window: wait at most this long for co-batchable
    /// requests before flushing (a quarter of the latency budget, so
    /// queue wait + batch compute fit inside the deadline).
    pub fn coalesce_ms(&self) -> u64 {
        (self.deadline_ms / 4).max(1)
    }

    /// Queue depth treated as "overloaded" after a flush.
    pub fn shed_highwater(&self) -> usize {
        (self.queue_capacity / 2).max(1)
    }

    /// Reject knob values that would otherwise wedge or crash the daemon.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.queue_capacity >= 1, "queue capacity must be at least 1");
        anyhow::ensure!(self.deadline_ms >= 1, "deadline must be at least 1 ms");
        anyhow::ensure!(self.max_batch >= 1, "max batch must be at least 1");
        anyhow::ensure!(self.overload_trip >= 1, "overload trip must be at least 1");
        anyhow::ensure!(
            self.worker_timeout_ms >= self.deadline_ms,
            "worker timeout {} ms below the request deadline {} ms",
            self.worker_timeout_ms,
            self.deadline_ms
        );
        for (i, &b) in self.degrade_beams.iter().enumerate() {
            anyhow::ensure!(b >= 1, "degradation tier {i} has beam 0");
            if i > 0 {
                anyhow::ensure!(
                    b < self.degrade_beams[i - 1],
                    "degradation tiers must narrow strictly: tier {i} beam {b} \
                     not below tier {} beam {}",
                    i - 1,
                    self.degrade_beams[i - 1]
                );
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_capacity", Json::Num(self.queue_capacity as f64)),
            ("deadline_ms", Json::Num(self.deadline_ms as f64)),
            ("max_batch", Json::Num(self.max_batch as f64)),
            ("degrade_beams", Json::arr_usize(&self.degrade_beams)),
            ("overload_trip", Json::Num(self.overload_trip as f64)),
            ("worker_timeout_ms", Json::Num(self.worker_timeout_ms as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let cfg = Self {
            queue_capacity: v.get("queue_capacity")?.as_usize()?,
            deadline_ms: v.get("deadline_ms")?.as_u64()?,
            max_batch: v.get("max_batch")?.as_usize()?,
            degrade_beams: v.get("degrade_beams")?.to_vec_usize()?,
            overload_trip: v.get("overload_trip")?.as_usize()?,
            worker_timeout_ms: v.get("worker_timeout_ms")?.as_u64()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Knobs for the distributed round protocol (`repro coord` / `repro
/// worker`, see `dist::`): the shared run shape every member derives its
/// work from, plus the lease/retransmission timing.
#[derive(Clone, Debug, PartialEq)]
pub struct DistConfig {
    /// Members the coordinator waits for before the first round starts.
    pub clients: usize,
    /// Training rounds to run.
    pub rounds: usize,
    /// Batch seqs assigned per round (round r owns seqs
    /// `[r*batches_per_round, (r+1)*batches_per_round)`).
    pub batches_per_round: usize,
    /// Examples per batch.
    pub batch_size: usize,
    /// Label-space size of the synthetic workload.
    pub num_classes: usize,
    /// Feature dimension of the synthetic workload.
    pub feat_dim: usize,
    /// Adagrad learning rate.
    pub lr: f32,
    /// The shared run seed: batches, assignments and the synthetic data
    /// are all pure functions of it.
    pub seed: u64,
    /// Lease duration: a client whose last frame is older than this is
    /// marked dead and its unapplied seqs are reassigned.
    pub lease_ms: u64,
    /// Client retransmission interval for unacknowledged updates (also
    /// paces its resync probe while waiting on a lost `begin`).
    pub resend_ms: u64,
}

impl Default for DistConfig {
    fn default() -> Self {
        Self {
            clients: 2,
            rounds: 8,
            batches_per_round: 8,
            batch_size: 64,
            num_classes: 256,
            feat_dim: 32,
            lr: 0.05,
            seed: 1,
            lease_ms: 1000,
            resend_ms: 200,
        }
    }
}

impl DistConfig {
    /// Heartbeat cadence: renew the lease several times per lease window
    /// so one dropped heartbeat never kills a healthy client.
    pub fn heartbeat_ms(&self) -> u64 {
        (self.lease_ms / 4).max(1)
    }

    /// Reject knob values that would wedge the round protocol.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.clients >= 1, "need at least 1 client");
        anyhow::ensure!(self.rounds >= 1, "need at least 1 round");
        anyhow::ensure!(self.batches_per_round >= 1, "need at least 1 batch per round");
        anyhow::ensure!(self.batch_size >= 1, "batch size must be at least 1");
        anyhow::ensure!(self.num_classes >= 2, "need at least 2 classes");
        anyhow::ensure!(self.feat_dim >= 1, "feature dimension must be at least 1");
        anyhow::ensure!(
            self.lr.is_finite() && self.lr > 0.0,
            "learning rate must be positive and finite"
        );
        anyhow::ensure!(self.resend_ms >= 1, "resend interval must be at least 1 ms");
        anyhow::ensure!(
            self.lease_ms > self.resend_ms,
            "lease {} ms must exceed the resend interval {} ms \
             (a client must get at least one retransmission per lease)",
            self.lease_ms,
            self.resend_ms
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clients", Json::Num(self.clients as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("batches_per_round", Json::Num(self.batches_per_round as f64)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("num_classes", Json::Num(self.num_classes as f64)),
            ("feat_dim", Json::Num(self.feat_dim as f64)),
            ("lr", Json::Num(self.lr as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("lease_ms", Json::Num(self.lease_ms as f64)),
            ("resend_ms", Json::Num(self.resend_ms as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let cfg = Self {
            clients: v.get("clients")?.as_usize()?,
            rounds: v.get("rounds")?.as_usize()?,
            batches_per_round: v.get("batches_per_round")?.as_usize()?,
            batch_size: v.get("batch_size")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            feat_dim: v.get("feat_dim")?.as_usize()?,
            lr: v.get("lr")?.as_f64()? as f32,
            seed: v.get("seed")?.as_u64()?,
            lease_ms: v.get("lease_ms")?.as_u64()?,
            resend_ms: v.get("resend_ms")?.as_u64()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Dataset presets simulating the paper's benchmarks at laptop scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetPreset {
    /// Wikipedia-500K stand-in: larger N, deeper label hierarchy.
    WikiSim,
    /// Amazon-670K stand-in: fewer points per label.
    AmazonSim,
    /// EURLex-4K stand-in: small C where full softmax is tractable.
    EurlexSim,
    /// Tiny smoke-test preset for unit/integration tests.
    Tiny,
}

impl FromStr for DatasetPreset {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "wiki-sim" | "wiki" => DatasetPreset::WikiSim,
            "amazon-sim" | "amazon" => DatasetPreset::AmazonSim,
            "eurlex-sim" | "eurlex" => DatasetPreset::EurlexSim,
            "tiny" => DatasetPreset::Tiny,
            other => anyhow::bail!("unknown dataset preset {other:?}"),
        })
    }
}

impl fmt::Display for DatasetPreset {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            DatasetPreset::WikiSim => "wiki-sim",
            DatasetPreset::AmazonSim => "amazon-sim",
            DatasetPreset::EurlexSim => "eurlex-sim",
            DatasetPreset::Tiny => "tiny",
        })
    }
}

/// Synthetic generator parameters (see `data::synthetic`).
#[derive(Clone, Copy, Debug)]
pub struct SyntheticConfig {
    pub n_train: usize,
    pub n_test: usize,
    pub n_valid: usize,
    pub num_classes: usize,
    pub feat_dim: usize,
    /// Depth of the generative label hierarchy.
    pub hierarchy_depth: usize,
    /// Per-level centroid scale decay (cluster tightness).
    pub level_decay: f32,
    /// Observation noise around the label centroid.
    pub noise: f32,
    /// Zipf exponent for label frequencies.
    pub zipf_exponent: f64,
    pub seed: u64,
}

impl SyntheticConfig {
    pub fn preset(p: DatasetPreset) -> Self {
        match p {
            DatasetPreset::WikiSim => Self {
                n_train: 200_000,
                n_test: 4096,
                n_valid: 4096,
                num_classes: 16_384,
                feat_dim: 64,
                hierarchy_depth: 8,
                level_decay: 0.7,
                noise: 0.45,
                zipf_exponent: 1.05,
                seed: 2020,
            },
            DatasetPreset::AmazonSim => Self {
                n_train: 60_000,
                n_test: 4096,
                n_valid: 2048,
                num_classes: 12_288,
                feat_dim: 64,
                hierarchy_depth: 7,
                level_decay: 0.72,
                noise: 0.5,
                zipf_exponent: 0.95,
                seed: 670,
            },
            DatasetPreset::EurlexSim => Self {
                n_train: 13_952, // ~paper's N=13,960, rounded to batch grid
                n_test: 2048,
                n_valid: 1536,
                num_classes: 4096,
                feat_dim: 64,
                hierarchy_depth: 6,
                level_decay: 0.7,
                noise: 0.5,
                zipf_exponent: 1.0,
                seed: 4000,
            },
            DatasetPreset::Tiny => Self {
                n_train: 4096,
                n_test: 512,
                n_valid: 512,
                num_classes: 256,
                feat_dim: 64,
                hierarchy_depth: 4,
                level_decay: 0.7,
                noise: 0.4,
                zipf_exponent: 1.0,
                seed: 7,
            },
        }
    }
}

/// Tuned hyperparameters per (dataset, method) — our Table 1.
pub fn tuned_hyper(p: DatasetPreset, m: Method) -> Hyper {
    use DatasetPreset::*;
    use Method::*;
    let (lr, lambda, num_negatives) = match (p, m) {
        (WikiSim, Adversarial) => (0.05, 1e-3, 1),
        (WikiSim, Uniform) => (0.05, 1e-4, 1),
        (WikiSim, Frequency) => (0.05, 1e-4, 1),
        (WikiSim, Nce) => (0.05, 1e-4, 1),
        (WikiSim, AugmentReduce) => (0.01, 1e-5, 1),
        (WikiSim, OneVsEach) => (0.02, 1e-5, 1),
        (WikiSim, Softmax) => (0.3, 3e-4, 1),

        (AmazonSim, Adversarial) => (0.05, 1e-3, 1),
        (AmazonSim, Uniform) => (0.05, 1e-4, 1),
        (AmazonSim, Frequency) => (0.05, 1e-4, 1),
        (AmazonSim, Nce) => (0.05, 1e-4, 1),
        (AmazonSim, AugmentReduce) => (0.01, 1e-5, 1),
        (AmazonSim, OneVsEach) => (0.03, 1e-5, 1),
        (AmazonSim, Softmax) => (0.3, 3e-4, 1),

        (EurlexSim, Softmax) => (0.3, 3e-4, 1),
        (EurlexSim, Uniform) => (0.03, 3e-4, 1),
        (EurlexSim, _) => (0.03, 1e-3, 1),

        (Tiny, Softmax) => (0.3, 3e-4, 1),
        (Tiny, _) => (0.05, 1e-3, 1),
    };
    Hyper { lr, lambda, num_negatives }
}

/// A full training-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetPreset,
    pub method: Method,
    pub hyper: Hyper,
    pub tree: TreeConfig,
    /// Training batch size; must match the AOT artifact B.
    pub batch_size: usize,
    pub max_steps: usize,
    /// Hard wallclock budget for training (seconds, excl. eval).
    pub max_seconds: f64,
    /// Evaluate every `eval_every` steps (0 = log-spaced schedule).
    pub eval_every: usize,
    /// Number of eval points (subsampled from the test split).
    pub eval_points: usize,
    pub seed: u64,
    /// Pipelined batch generation (worker threads) on/off.
    pub pipelined: bool,
    /// Host-side worker-pool width for the sharded hot path (pipeline
    /// workers, gather/scatter shards, eval sweeps). 0 = auto-detect from
    /// hardware, 1 = fully serial. Learning curves are bit-identical at
    /// every setting; only wallclock changes.
    pub parallelism: usize,
    /// Step-overlap protocol: serial, double-buffered (gather/literal-
    /// build of step t+1 behind the execute of step t), or the three-deep
    /// pipeline with a dedicated execute thread. Learning curves are
    /// bit-identical at every setting; only wallclock changes.
    pub overlap: OverlapMode,
}

impl RunConfig {
    pub fn new(dataset: DatasetPreset, method: Method) -> Self {
        Self {
            dataset,
            method,
            hyper: tuned_hyper(dataset, method),
            tree: TreeConfig::default(),
            batch_size: 256,
            max_steps: 20_000,
            max_seconds: 120.0,
            eval_every: 0,
            eval_points: 2048,
            seed: 1,
            pipelined: true,
            parallelism: 0,
            overlap: OverlapMode::env_default(),
        }
    }

    /// Serialize to JSON (reproducible experiment configs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.to_string())),
            ("method", Json::Str(self.method.to_string())),
            ("lr", Json::Num(self.hyper.lr as f64)),
            ("lambda", Json::Num(self.hyper.lambda as f64)),
            ("num_negatives", Json::Num(self.hyper.num_negatives as f64)),
            ("aux_dim", Json::Num(self.tree.aux_dim as f64)),
            ("lambda_n", Json::Num(self.tree.lambda_n)),
            ("newton_iters", Json::Num(self.tree.newton_iters as f64)),
            ("max_alternations", Json::Num(self.tree.max_alternations as f64)),
            ("fit_subsample", Json::Num(self.tree.fit_subsample as f64)),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("max_steps", Json::Num(self.max_steps as f64)),
            ("max_seconds", Json::Num(self.max_seconds)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("eval_points", Json::Num(self.eval_points as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("pipelined", Json::Bool(self.pipelined)),
            ("parallelism", Json::Num(self.parallelism as f64)),
            ("overlap", Json::Str(self.overlap.to_string())),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let dataset: DatasetPreset = v.get("dataset")?.as_str()?.parse()?;
        let method: Method = v.get("method")?.as_str()?.parse()?;
        let mut cfg = RunConfig::new(dataset, method);
        cfg.hyper.lr = v.get("lr")?.as_f32()?;
        cfg.hyper.lambda = v.get("lambda")?.as_f32()?;
        cfg.hyper.num_negatives = v.get("num_negatives")?.as_usize()?;
        cfg.tree.aux_dim = v.get("aux_dim")?.as_usize()?;
        cfg.tree.lambda_n = v.get("lambda_n")?.as_f64()?;
        cfg.tree.newton_iters = v.get("newton_iters")?.as_usize()?;
        cfg.tree.max_alternations = v.get("max_alternations")?.as_usize()?;
        cfg.tree.fit_subsample = v.get("fit_subsample")?.as_usize()?;
        cfg.batch_size = v.get("batch_size")?.as_usize()?;
        cfg.max_steps = v.get("max_steps")?.as_usize()?;
        cfg.max_seconds = v.get("max_seconds")?.as_f64()?;
        cfg.eval_every = v.get("eval_every")?.as_usize()?;
        cfg.eval_points = v.get("eval_points")?.as_usize()?;
        cfg.seed = v.get("seed")?.as_u64()?;
        cfg.pipelined = v.get("pipelined")?.as_bool()?;
        // optional for configs saved before the parallelism knob existed
        if let Some(p) = v.opt("parallelism") {
            cfg.parallelism = p.as_usize()?;
        }
        // optional for configs saved before the overlap knob existed
        if let Some(o) = v.opt("overlap") {
            cfg.overlap = o.as_str()?.parse()?;
        }
        cfg.tree.validate()?;
        Ok(cfg)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        Ok(std::fs::write(path, self.to_json().to_string())?)
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL_SAMPLING.iter().chain([Method::Softmax].iter()) {
            let parsed: Method = m.name().parse().unwrap();
            assert_eq!(parsed, *m);
        }
        assert!("bogus".parse::<Method>().is_err());
    }

    #[test]
    fn method_aliases() {
        assert_eq!("adv".parse::<Method>().unwrap(), Method::Adversarial);
        assert_eq!("ar".parse::<Method>().unwrap(), Method::AugmentReduce);
        assert_eq!("ove".parse::<Method>().unwrap(), Method::OneVsEach);
    }

    #[test]
    fn tree_flags() {
        assert!(Method::Adversarial.needs_tree());
        assert!(Method::Nce.needs_tree());
        assert!(!Method::Uniform.needs_tree());
        assert!(Method::Adversarial.corrects_bias());
        assert!(!Method::Nce.corrects_bias());
    }

    #[test]
    fn run_config_json_roundtrip() {
        let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
        cfg.hyper.lr = 0.123;
        cfg.max_seconds = 7.5;
        cfg.pipelined = false;
        cfg.parallelism = 4;
        cfg.overlap = OverlapMode::On;
        let back = RunConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.method, cfg.method);
        assert_eq!(back.batch_size, cfg.batch_size);
        assert_eq!(back.hyper.lr, cfg.hyper.lr);
        assert_eq!(back.max_seconds, cfg.max_seconds);
        assert!(!back.pipelined);
        assert_eq!(back.parallelism, 4);
        assert_eq!(back.overlap, OverlapMode::On);
    }

    #[test]
    fn overlap_mode_parses_and_defaults_when_absent_from_json() {
        assert_eq!("auto".parse::<OverlapMode>().unwrap(), OverlapMode::Auto);
        assert_eq!("on".parse::<OverlapMode>().unwrap(), OverlapMode::On);
        assert_eq!("off".parse::<OverlapMode>().unwrap(), OverlapMode::Off);
        assert_eq!("pipeline".parse::<OverlapMode>().unwrap(), OverlapMode::Pipeline);
        assert_eq!("3".parse::<OverlapMode>().unwrap(), OverlapMode::Pipeline, "depth alias");
        assert_eq!("ON".parse::<OverlapMode>().unwrap(), OverlapMode::On, "case-insensitive");
        assert!("sideways".parse::<OverlapMode>().is_err());
        // the pipeline mode survives a config JSON roundtrip
        let mut pcfg = RunConfig::new(DatasetPreset::Tiny, Method::Uniform);
        pcfg.overlap = OverlapMode::Pipeline;
        let back =
            RunConfig::from_json(&Json::parse(&pcfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.overlap, OverlapMode::Pipeline);
        // configs saved before the knob existed must still load
        let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Uniform);
        cfg.overlap = OverlapMode::Off;
        let mut v = cfg.to_json();
        if let Json::Obj(m) = &mut v {
            m.remove("overlap");
        }
        let back = RunConfig::from_json(&v).unwrap();
        // absent key falls back to the constructor default (env or Auto)
        assert_eq!(back.overlap, OverlapMode::env_default());
    }

    #[test]
    fn parallelism_defaults_when_absent_from_json() {
        // configs saved before the knob existed must still load
        let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Uniform);
        cfg.parallelism = 7;
        let mut v = cfg.to_json();
        if let Json::Obj(m) = &mut v {
            m.remove("parallelism");
        }
        let back = RunConfig::from_json(&v).unwrap();
        assert_eq!(back.parallelism, 0);
    }

    #[test]
    fn oversized_aux_dim_rejected_at_load() {
        let mut cfg = RunConfig::new(DatasetPreset::Tiny, Method::Adversarial);
        cfg.tree.aux_dim = MAX_AUX_DIM + 1;
        assert!(RunConfig::from_json(&cfg.to_json()).is_err());
        cfg.tree.aux_dim = MAX_AUX_DIM;
        assert!(RunConfig::from_json(&cfg.to_json()).is_ok());
        cfg.tree.aux_dim = 0;
        assert!(RunConfig::from_json(&cfg.to_json()).is_err());
        assert!(TreeConfig::default().validate().is_ok());
    }

    #[test]
    fn serve_config_validates_and_roundtrips() {
        let cfg = ServeConfig::default();
        assert!(cfg.validate().is_ok());
        assert!(!cfg.exact);
        let back = ServeConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, cfg);
        assert!(ServeConfig { beam: 0, ..cfg }.validate().is_err());
        assert!(ServeConfig { k: 0, ..cfg }.validate().is_err());
    }

    #[test]
    fn quant_mode_parses_and_defaults_when_absent_from_json() {
        assert_eq!("off".parse::<QuantMode>().unwrap(), QuantMode::Off);
        assert_eq!("f16".parse::<QuantMode>().unwrap(), QuantMode::F16);
        assert_eq!("i8".parse::<QuantMode>().unwrap(), QuantMode::I8);
        assert_eq!("F16".parse::<QuantMode>().unwrap(), QuantMode::F16, "case-insensitive");
        assert!("fp8".parse::<QuantMode>().is_err());
        for q in [QuantMode::Off, QuantMode::F16, QuantMode::I8] {
            assert_eq!(q.name().parse::<QuantMode>().unwrap(), q);
        }
        // quantize round-trips through JSON
        let cfg = ServeConfig { quantize: QuantMode::I8, ..ServeConfig::default() };
        let back =
            ServeConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.quantize, QuantMode::I8);
        // configs saved before the knob existed must still load
        let mut v = cfg.to_json();
        if let Json::Obj(m) = &mut v {
            m.remove("quantize");
        }
        let back = ServeConfig::from_json(&v).unwrap();
        // absent key falls back to the constructor default (env or Off)
        assert_eq!(back.quantize, QuantMode::env_default());
    }

    #[test]
    fn daemon_config_validates_and_roundtrips() {
        let cfg = DaemonConfig::default();
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.coalesce_ms(), cfg.deadline_ms / 4);
        assert_eq!(cfg.shed_highwater(), cfg.queue_capacity / 2);
        let back =
            DaemonConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert!(DaemonConfig { queue_capacity: 0, ..cfg.clone() }.validate().is_err());
        assert!(DaemonConfig { deadline_ms: 0, ..cfg.clone() }.validate().is_err());
        assert!(DaemonConfig { max_batch: 0, ..cfg.clone() }.validate().is_err());
        assert!(DaemonConfig { overload_trip: 0, ..cfg.clone() }.validate().is_err());
        // worker timeout may not undercut the deadline
        assert!(DaemonConfig { worker_timeout_ms: 10, ..cfg.clone() }.validate().is_err());
        // ladder must narrow strictly and never hit zero
        assert!(DaemonConfig { degrade_beams: vec![16, 16], ..cfg.clone() }
            .validate()
            .is_err());
        assert!(DaemonConfig { degrade_beams: vec![4, 16], ..cfg.clone() }
            .validate()
            .is_err());
        assert!(DaemonConfig { degrade_beams: vec![16, 0], ..cfg.clone() }
            .validate()
            .is_err());
        assert!(DaemonConfig { degrade_beams: vec![], ..cfg }.validate().is_ok());
        // tiny deadlines still coalesce for at least a millisecond
        let tight = DaemonConfig { deadline_ms: 2, worker_timeout_ms: 2000, ..Default::default() };
        assert_eq!(tight.coalesce_ms(), 1);
    }

    #[test]
    fn presets_have_sane_shapes() {
        for p in [
            DatasetPreset::WikiSim,
            DatasetPreset::AmazonSim,
            DatasetPreset::EurlexSim,
            DatasetPreset::Tiny,
        ] {
            let c = SyntheticConfig::preset(p);
            assert!(c.n_train >= 1024, "need at least a few batches of data");
            assert!(c.num_classes >= 128);
            assert_eq!(c.feat_dim, 64, "feat dim must match AOT artifacts");
        }
    }

    #[test]
    fn eurlex_fits_softmax_artifact() {
        let c = SyntheticConfig::preset(DatasetPreset::EurlexSim);
        assert_eq!(c.num_classes, 4096, "must match softmax_grad artifact C");
    }

    #[test]
    fn dist_config_json_roundtrip() {
        let cfg = DistConfig {
            clients: 3,
            rounds: 5,
            batches_per_round: 6,
            batch_size: 32,
            num_classes: 128,
            feat_dim: 16,
            lr: 0.125, // exactly representable: f32 -> f64 -> f32 is lossless
            seed: 99,
            lease_ms: 900,
            resend_ms: 150,
        };
        let json = Json::parse(&cfg.to_json().to_string()).unwrap();
        let back = DistConfig::from_json(&json).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn dist_config_validation_rejects_wedging_knobs() {
        let ok = DistConfig::default();
        assert!(ok.validate().is_ok());
        assert!(DistConfig { clients: 0, ..ok.clone() }.validate().is_err());
        assert!(DistConfig { rounds: 0, ..ok.clone() }.validate().is_err());
        assert!(DistConfig { batches_per_round: 0, ..ok.clone() }.validate().is_err());
        assert!(DistConfig { num_classes: 1, ..ok.clone() }.validate().is_err());
        assert!(DistConfig { lr: 0.0, ..ok.clone() }.validate().is_err());
        assert!(DistConfig { lr: f32::NAN, ..ok.clone() }.validate().is_err());
        // a lease shorter than the resend interval could never see a retry
        assert!(DistConfig { lease_ms: 100, resend_ms: 200, ..ok }.validate().is_err());
        // heartbeats always land several times per lease
        let cfg = DistConfig::default();
        assert!(cfg.heartbeat_ms() * 2 < cfg.lease_ms);
    }
}
