//! Noise distributions p_n for negative sampling.
//!
//! Three families from the paper's Sec. 5 comparison:
//! * [`UniformSampler`] — baseline (i): p_n(y') = 1/C.
//! * [`FrequencySampler`] — baseline (ii): p_n(y') ∝ empirical label
//!   frequency (word2vec-style), O(1) draws via an alias table.
//! * [`AdversarialSampler`] — the proposed conditional model
//!   p_n(y'|x): PCA projection + the fitted probabilistic decision tree,
//!   O(k log C) draws (Sec. 3). Also serves as the NCE base distribution.
//!
//! All samplers expose exact `log_prob`, which the training losses (Eq. 6,
//! NCE) and the Eq. 5 bias correction consume.

use crate::config::{TreeConfig, MAX_AUX_DIM};
use crate::data::Dataset;
use crate::linalg::Pca;
use crate::tree::{fit::fit_tree_with, FitStats, Tree, TreeKernel};
use crate::utils::json::Json;
use crate::utils::{AliasTable, Pool, Rng};
use std::path::Path;

/// A conditional noise distribution over labels.
///
/// `x` is the *raw* feature vector; conditional samplers project it
/// internally. Unconditional samplers ignore it.
pub trait NoiseSampler: Send + Sync {
    /// Draw y' ~ p_n(·|x); returns (label, log p_n(label|x)).
    fn sample(&self, x: &[f32], rng: &mut Rng) -> (u32, f32);

    /// log p_n(y|x).
    fn log_prob(&self, x: &[f32], y: u32) -> f32;

    /// Fill `out[c] = log p_n(c|x)` for all labels. Default loops over
    /// `log_prob`; conditional samplers override with an O(kC) sweep.
    fn log_prob_all(&self, x: &[f32], out: &mut [f32]) {
        for (c, o) in out.iter_mut().enumerate() {
            *o = self.log_prob(x, c as u32);
        }
    }

    /// Is p_n conditional on x?
    fn is_conditional(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// uniform
// ---------------------------------------------------------------------------

/// p_n(y') = 1/C.
#[derive(Clone, Debug)]
pub struct UniformSampler {
    num_classes: usize,
    log_p: f32,
}

impl UniformSampler {
    pub fn new(num_classes: usize) -> Self {
        assert!(num_classes > 0);
        Self { num_classes, log_p: -(num_classes as f32).ln() }
    }
}

impl NoiseSampler for UniformSampler {
    fn sample(&self, _x: &[f32], rng: &mut Rng) -> (u32, f32) {
        (rng.below(self.num_classes) as u32, self.log_p)
    }

    fn log_prob(&self, _x: &[f32], _y: u32) -> f32 {
        self.log_p
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

// ---------------------------------------------------------------------------
// empirical frequency
// ---------------------------------------------------------------------------

/// p_n(y') ∝ count(y') with optional additive smoothing so every label has
/// nonzero probability (needed for finite log-probs in Eq. 6).
#[derive(Clone, Debug)]
pub struct FrequencySampler {
    table: AliasTable,
}

impl FrequencySampler {
    /// Build from a dataset's empirical label counts. `smoothing` must be
    /// finite and non-negative — validated here with a clear error, since
    /// a NaN/∞/negative value would otherwise surface downstream as NaN
    /// alias weights or an opaque alias-table rejection far from the
    /// misconfigured call site. (`smoothing = 0` is valid: unseen labels
    /// then get log-probability −∞, which Eq. 6 callers must smooth away
    /// themselves.)
    pub fn from_dataset(data: &Dataset, smoothing: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            smoothing.is_finite() && smoothing >= 0.0,
            "frequency smoothing must be finite and >= 0, got {smoothing}"
        );
        let counts = data.label_counts();
        let weights: Vec<f64> = counts.iter().map(|&c| c as f64 + smoothing).collect();
        Ok(Self { table: AliasTable::new(&weights)? })
    }
}

impl NoiseSampler for FrequencySampler {
    fn sample(&self, _x: &[f32], rng: &mut Rng) -> (u32, f32) {
        let y = self.table.sample(rng);
        (y as u32, self.table.log_prob(y))
    }

    fn log_prob(&self, _x: &[f32], y: u32) -> f32 {
        self.table.log_prob(y as usize)
    }

    fn name(&self) -> &'static str {
        "frequency"
    }
}

// ---------------------------------------------------------------------------
// adversarial (PCA + tree)
// ---------------------------------------------------------------------------

/// The paper's auxiliary model: PCA to k dims, then the probabilistic
/// decision tree of Sec. 3.
#[derive(Clone, Debug)]
pub struct AdversarialSampler {
    pub pca: Pca,
    pub tree: Tree,
    /// Lane-major batch kernel derived from `tree` — rebuilt whenever the
    /// tree is (re)fitted or loaded, bit-identical to the scalar walkers.
    pub kernel: TreeKernel,
}

impl AdversarialSampler {
    /// Fit PCA + tree on the training set. Returns fit diagnostics.
    pub fn fit(data: &Dataset, cfg: &TreeConfig, seed: u64) -> (Self, FitStats) {
        Self::fit_with(data, cfg, seed, &Pool::serial())
    }

    /// [`AdversarialSampler::fit`] with every aux-model construction stage
    /// sharded over a worker pool: PCA covariance accumulation, the
    /// O(N·K·k) projection pass, and the level-synchronous tree fit. Each
    /// stage is bit-deterministic, so the fitted model is identical at any
    /// worker count.
    pub fn fit_with(data: &Dataset, cfg: &TreeConfig, seed: u64, pool: &Pool) -> (Self, FitStats) {
        // backstop for configs built in code; JSON-loaded configs are
        // validated in `RunConfig::from_json`
        assert!(
            cfg.aux_dim >= 1 && cfg.aux_dim <= MAX_AUX_DIM,
            "aux_dim {} out of range [1, {MAX_AUX_DIM}] — see TreeConfig::validate",
            cfg.aux_dim
        );
        let k = cfg.aux_dim.min(data.feat_dim);
        let pca = Pca::fit_with(&data.features, data.len(), data.feat_dim, k, seed, pool);
        let x_proj = pca.project_all_with(&data.features, data.len(), pool);
        let mut rng = Rng::new(seed ^ 0x7ee);
        let (tree, stats) = fit_tree_with(
            &x_proj,
            &data.labels,
            data.len(),
            k,
            data.num_classes,
            cfg,
            &mut rng,
            pool,
        );
        let kernel = TreeKernel::build(&tree);
        (Self { pca, tree, kernel }, stats)
    }

    /// Projected feature dimension k.
    pub fn aux_dim(&self) -> usize {
        self.tree.aux_dim
    }

    /// Project raw features into the tree's input space.
    pub fn project(&self, x: &[f32], out: &mut [f32]) {
        self.pca.project(x, out);
    }

    /// Serialize to JSON (PCA + tree in one checkpoint).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pca", self.pca.to_json()),
            ("tree", self.tree.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let pca = Pca::from_json(v.get("pca")?)?;
        let tree = Tree::from_json(v.get("tree")?)?;
        let s = Self { kernel: TreeKernel::build(&tree), pca, tree };
        // same bound as TreeConfig::validate — the hot-path methods below
        // project into MAX_AUX_DIM-float stack buffers
        anyhow::ensure!(
            s.tree.aux_dim >= 1 && s.tree.aux_dim <= MAX_AUX_DIM,
            "checkpoint aux_dim {} out of range [1, {}]",
            s.tree.aux_dim,
            MAX_AUX_DIM
        );
        // the PCA must feed exactly the tree's input space: a mismatch
        // would silently truncate/zero-fill projections in release builds
        // (Pca::project only debug_asserts its output length)
        anyhow::ensure!(
            s.pca.output_dim == s.tree.aux_dim,
            "checkpoint PCA output_dim {} != tree aux_dim {}",
            s.pca.output_dim,
            s.tree.aux_dim
        );
        Ok(s)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        Ok(std::fs::write(path, self.to_json().to_string())?)
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }

    /// Project raw features into a caller-provided stack buffer, returning
    /// the filled k-prefix. One shared bound check for all three hot-path
    /// methods: `aux_dim <= MAX_AUX_DIM` is enforced at fit and checkpoint
    /// load, so this assert only guards hand-built `Tree`s — but it must
    /// hold in release builds too, not just under `debug_assert`.
    #[inline]
    fn project_stack<'a>(&self, x: &[f32], buf: &'a mut [f32; MAX_AUX_DIM]) -> &'a [f32] {
        let k = self.aux_dim();
        assert!(k <= MAX_AUX_DIM, "aux_dim {k} exceeds MAX_AUX_DIM {MAX_AUX_DIM}");
        self.pca.project(x, &mut buf[..k]);
        &buf[..k]
    }

    /// Fill `out[j*C..(j+1)*C]` with log p_n(·|x_j) for a block of `m` raw
    /// feature rows (`xs` is `[m, K]` row-major), routed through the
    /// kernel's batched activation sweep so node weights are loaded once
    /// per example tile instead of once per example. Per row bit-identical
    /// to [`NoiseSampler::log_prob_all`]; used by the eval sweeps
    /// ([`crate::eval::LpnCache`], the reference evaluator). One-shot
    /// convenience — sweeps that call per 8-row block should hold an
    /// [`LpnBlockScratch`] and use
    /// [`AdversarialSampler::log_prob_all_block_with`].
    pub fn log_prob_all_block(&self, xs: &[f32], m: usize, out: &mut [f32]) {
        self.log_prob_all_block_with(xs, m, out, &mut LpnBlockScratch::default())
    }

    /// [`AdversarialSampler::log_prob_all_block`] with caller-owned scratch:
    /// the projection and activation buffers (the latter is `m · (C−1)`
    /// floats) are grown once and fully overwritten each call, so a sweep
    /// looping over blocks pays no per-block allocation or memset.
    pub fn log_prob_all_block_with(
        &self,
        xs: &[f32],
        m: usize,
        out: &mut [f32],
        scratch: &mut LpnBlockScratch,
    ) {
        let k = self.aux_dim();
        let c = self.tree.num_classes;
        let nn = self.kernel.num_nodes();
        debug_assert_eq!(xs.len() % m.max(1), 0);
        debug_assert_eq!(out.len(), m * c);
        let kf = if m == 0 { 0 } else { xs.len() / m };
        if scratch.proj.len() < m * k {
            scratch.proj.resize(m * k, 0.0);
        }
        if scratch.acts.len() < m * nn {
            scratch.acts.resize(m * nn, 0.0);
        }
        let proj = &mut scratch.proj[..m * k];
        let acts = &mut scratch.acts[..m * nn];
        for (j, row) in xs.chunks_exact(kf.max(1)).enumerate().take(m) {
            self.pca.project(row, &mut proj[j * k..(j + 1) * k]);
        }
        self.kernel.node_activations_batch(proj, m, acts);
        for (j, out_row) in out.chunks_exact_mut(c).enumerate() {
            self.tree.log_prob_all_from_activations_with(
                &acts[j * nn..(j + 1) * nn],
                out_row,
                &mut scratch.lp,
            );
        }
    }
}

/// Reusable projection/activation/prefix scratch for
/// [`AdversarialSampler::log_prob_all_block_with`].
#[derive(Default)]
pub struct LpnBlockScratch {
    proj: Vec<f32>,
    acts: Vec<f32>,
    lp: Vec<f32>,
}

impl NoiseSampler for AdversarialSampler {
    fn sample(&self, x: &[f32], rng: &mut Rng) -> (u32, f32) {
        let mut proj = [0f32; MAX_AUX_DIM];
        let proj = self.project_stack(x, &mut proj);
        self.tree.sample(proj, rng)
    }

    fn log_prob(&self, x: &[f32], y: u32) -> f32 {
        let mut proj = [0f32; MAX_AUX_DIM];
        let proj = self.project_stack(x, &mut proj);
        self.tree.log_prob(proj, y)
    }

    fn log_prob_all(&self, x: &[f32], out: &mut [f32]) {
        let mut proj = [0f32; MAX_AUX_DIM];
        let proj = self.project_stack(x, &mut proj);
        // scalar-walker path: at m = 1 the tiled kernel amortizes nothing
        // and is documented bit-identical, so the oracle sweep is simplest.
        // Block callers use `log_prob_all_block_with`.
        self.tree.log_prob_all(proj, out);
    }

    fn is_conditional(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "adversarial"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetPreset, SyntheticConfig};
    use crate::data::Splits;

    fn tiny_splits() -> Splits {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 4096;
        Splits::synthetic(&cfg)
    }

    #[test]
    fn uniform_sampler_covers_labels() {
        let s = UniformSampler::new(16);
        let mut rng = Rng::new(1);
        let mut seen = vec![false; 16];
        for _ in 0..2000 {
            let (y, lp) = s.sample(&[], &mut rng);
            assert!((lp + (16f32).ln()).abs() < 1e-6);
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn frequency_sampler_matches_counts() {
        let d = tiny_splits().train;
        let s = FrequencySampler::from_dataset(&d, 0.0).unwrap();
        let counts = d.label_counts();
        let n = d.len() as f64;
        let mut rng = Rng::new(2);
        // empirical check on the most frequent label
        let top = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        let draws = 200_000;
        let mut hits = 0usize;
        for _ in 0..draws {
            if s.sample(&[], &mut rng).0 as usize == top {
                hits += 1;
            }
        }
        let expect = counts[top] as f64 / n;
        let got = hits as f64 / draws as f64;
        assert!((got - expect).abs() < 0.01, "got {got}, expect {expect}");
        assert!((s.log_prob(&[], top as u32) - (expect as f32).ln()).abs() < 0.01);
    }

    #[test]
    fn frequency_smoothing_gives_finite_logprob_to_unseen() {
        let d = tiny_splits().train;
        let counts = d.label_counts();
        if let Some(unseen) = counts.iter().position(|&c| c == 0) {
            let s0 = FrequencySampler::from_dataset(&d, 0.0).unwrap();
            let s1 = FrequencySampler::from_dataset(&d, 1.0).unwrap();
            assert_eq!(s0.log_prob(&[], unseen as u32), f32::NEG_INFINITY);
            assert!(s1.log_prob(&[], unseen as u32).is_finite());
        }
    }

    #[test]
    fn frequency_sampler_rejects_degenerate_smoothing() {
        let d = tiny_splits().train;
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0, -1e-9] {
            let err = FrequencySampler::from_dataset(&d, bad)
                .err()
                .unwrap_or_else(|| panic!("smoothing {bad} must be rejected"));
            assert!(
                err.to_string().contains("smoothing"),
                "error must name the knob: {err}"
            );
        }
        assert!(FrequencySampler::from_dataset(&d, 0.0).is_ok());
        assert!(FrequencySampler::from_dataset(&d, 2.5).is_ok());
    }

    #[test]
    fn adversarial_sampler_fits_and_normalizes() {
        let splits = tiny_splits();
        let cfg = TreeConfig { aux_dim: 8, ..Default::default() };
        let (s, stats) = AdversarialSampler::fit(&splits.train, &cfg, 5);
        assert!(stats.nodes_fitted > 0);
        assert!(s.is_conditional());
        let x = splits.test.x(0);
        let mut lps = vec![0f32; splits.train.num_classes];
        s.log_prob_all(x, &mut lps);
        let total: f64 = lps.iter().map(|&l| (l as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-4, "total {total}");
        // sample/log_prob consistency
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let (y, lp) = s.sample(x, &mut rng);
            assert!((lp - s.log_prob(x, y)).abs() < 1e-5);
        }
    }

    #[test]
    fn adversarial_beats_frequency_loglik() {
        // The conditional model must explain held-out labels better than
        // the best unconditional model — the premise of the whole paper.
        let splits = tiny_splits();
        let cfg = TreeConfig { aux_dim: 8, ..Default::default() };
        let (adv, _) = AdversarialSampler::fit(&splits.train, &cfg, 5);
        let freq = FrequencySampler::from_dataset(&splits.train, 1.0).unwrap();
        let d = &splits.test;
        let (mut la, mut lf) = (0f64, 0f64);
        for i in 0..d.len() {
            la += adv.log_prob(d.x(i), d.y(i)) as f64;
            lf += freq.log_prob(d.x(i), d.y(i)) as f64;
        }
        la /= d.len() as f64;
        lf /= d.len() as f64;
        assert!(la > lf + 0.2, "adv {la:.3} vs freq {lf:.3}");
    }

    #[test]
    fn adversarial_save_load_roundtrip() {
        let splits = tiny_splits();
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let (s, _) = AdversarialSampler::fit(&splits.train, &cfg, 5);
        let dir = std::env::temp_dir().join("adv_softmax_test_sampler.json");
        s.save(&dir).unwrap();
        let back = AdversarialSampler::load(&dir).unwrap();
        let x = splits.test.x(3);
        assert_eq!(s.log_prob(x, 7), back.log_prob(x, 7));
        std::fs::remove_file(dir).ok();
    }
}
