//! Training coordinator: per-method update rules over the AOT HLO step
//! artifacts, with pipelined negative-sample generation.
//!
//! The step protocol for sampling-based methods is gather → execute →
//! scatter: rust gathers the 2B touched parameter rows, the HLO artifact
//! (Pallas gradient core) computes the fused loss + row gradients, rust
//! scatters them back through sparse Adagrad. Cost per step is O(B·K) on
//! the host plus the kernel, independent of C — the property that makes
//! negative sampling scale (Sec. 2.1).
//!
//! Negative generation (the O(k log C) tree descents) depends only on the
//! features, so in pipelined mode it runs on a worker thread a few batches
//! ahead, fully overlapped with PJRT execution and the optimizer scatter.

pub mod batcher;
pub mod curve;

pub use batcher::{BatchGen, BatchMode, RawBatch, SamplerKind};
pub use curve::{CurvePoint, LearningCurve};

use crate::config::{Method, RunConfig};
use crate::data::{Dataset, Splits};
use crate::eval::{EvalResult, Evaluator, LpnCache};
use crate::model::ParamStore;
use crate::runtime::{lit_f32, lit_i32, read_f32, Executable, Registry};
use crate::sampler::{AdversarialSampler, FrequencySampler, UniformSampler};
use crate::utils::{Rng, StopWatch};
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

/// How many batches the pipelined generator may run ahead.
const PIPELINE_DEPTH: usize = 4;

/// Where batches come from.
enum BatchSource {
    Inline(BatchGen),
    Pipelined {
        rx: Receiver<RawBatch>,
        stop: Arc<AtomicBool>,
        handle: Option<JoinHandle<()>>,
    },
}

impl BatchSource {
    fn next(&mut self) -> RawBatch {
        match self {
            BatchSource::Inline(gen) => gen.next_batch(),
            BatchSource::Pipelined { rx, .. } => {
                rx.recv().expect("batch generator thread died")
            }
        }
    }
}

impl Drop for BatchSource {
    fn drop(&mut self) {
        if let BatchSource::Pipelined { rx, stop, handle } = self {
            stop.store(true, Ordering::Relaxed);
            // unblock a sender stuck on a full channel, then join
            while rx.try_recv().is_ok() {}
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// A prepared training run: data, sampler, parameters, compiled step.
pub struct TrainRun {
    pub cfg: RunConfig,
    data: Arc<Dataset>,
    eval_set: Dataset,
    pub params: ParamStore,
    step_exec: Arc<Executable>,
    evaluator: Evaluator,
    /// Fitted auxiliary model (Some for methods that need the tree).
    pub aux: Option<Arc<AdversarialSampler>>,
    pub aux_fit_seconds: f64,
    mode: BatchMode,
    source: BatchSource,
    step: usize,
    /// Eq. 5 correction cache for the fixed eval subset (built lazily on
    /// the first corrected evaluation; exact because the tree is frozen).
    lpn_cache: Option<LpnCache>,
    // scratch
    wp: Vec<f32>,
    bp: Vec<f32>,
    wn: Vec<f32>,
    bn: Vec<f32>,
}

impl TrainRun {
    /// Build everything needed to train `cfg.method` on `splits`.
    pub fn prepare(registry: &Registry, splits: &Splits, cfg: &RunConfig) -> Result<Self> {
        let shapes = &registry.manifest.shapes;
        anyhow::ensure!(
            cfg.batch_size == shapes.train_b,
            "batch_size {} must match AOT train_b {}",
            cfg.batch_size,
            shapes.train_b
        );
        anyhow::ensure!(
            splits.train.feat_dim == shapes.feat_k,
            "feat_dim {} must match AOT feat_k {}",
            splits.train.feat_dim,
            shapes.feat_k
        );
        if cfg.method == Method::Softmax {
            anyhow::ensure!(
                splits.train.num_classes == shapes.softmax_c,
                "softmax method requires C == AOT softmax_c ({} vs {})",
                splits.train.num_classes,
                shapes.softmax_c
            );
        }

        let data = Arc::new(splits.train.clone());
        let c = data.num_classes;
        let mut rng = Rng::new(cfg.seed);

        // --- auxiliary model (Sec. 3) ---
        let (aux, aux_fit_seconds) = if cfg.method.needs_tree() {
            let t0 = std::time::Instant::now();
            let (adv, stats) = AdversarialSampler::fit(&data, &cfg.tree, cfg.seed);
            let dt = t0.elapsed().as_secs_f64();
            log::info(&format!(
                "aux tree fitted: {} nodes, {:.1}s, train loglik {:.3}",
                stats.nodes_fitted, dt, stats.train_mean_loglik
            ));
            (Some(Arc::new(adv)), dt)
        } else {
            (None, 0.0)
        };

        // --- sampler + batch mode ---
        let mode = BatchMode::of(cfg.method);
        let sampler = match cfg.method {
            Method::Adversarial | Method::Nce => {
                let adv = aux.clone().unwrap();
                let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
                SamplerKind::Adversarial { sampler: adv, x_proj }
            }
            Method::Frequency => {
                SamplerKind::Frequency(FrequencySampler::from_dataset(&data, 1.0)?)
            }
            _ => SamplerKind::Uniform(UniformSampler::new(c)),
        };
        let scale = match cfg.method {
            Method::AugmentReduce => {
                (c as f32 - 1.0) / cfg.hyper.num_negatives.max(1) as f32
            }
            _ => 1.0,
        };
        let gen = BatchGen::new(
            data.clone(),
            sampler,
            mode,
            cfg.batch_size,
            scale,
            rng.split(1),
        );
        // Pipelining overlaps batch generation with PJRT execution; on a
        // single hardware thread there is nothing to overlap with and the
        // channel only adds overhead, so fall back to inline generation.
        let multi_core = std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false);
        let source = if cfg.pipelined && multi_core && mode != BatchMode::Softmax {
            spawn_pipeline(gen)
        } else {
            BatchSource::Inline(gen)
        };

        // --- compiled step ---
        let exec_name = match cfg.method {
            Method::Adversarial | Method::Uniform | Method::Frequency => "ns_grad_",
            Method::Nce => "nce_grad_",
            Method::AugmentReduce | Method::OneVsEach => "ove_grad_",
            Method::Softmax => "softmax_grad_",
        };
        let step_exec = registry.get_by_prefix(exec_name)?;

        let eval_set = splits.test.subsample(cfg.eval_points, &mut rng.split(2));
        let b = cfg.batch_size;
        let k = data.feat_dim;
        Ok(Self {
            cfg: cfg.clone(),
            params: ParamStore::zeros(c, k, cfg.hyper.lr),
            data,
            eval_set,
            step_exec,
            evaluator: Evaluator::new(registry)?,
            aux,
            aux_fit_seconds,
            mode,
            source,
            step: 0,
            lpn_cache: None,
            wp: vec![0f32; b * k],
            bp: vec![0f32; b],
            wn: vec![0f32; b * k],
            bn: vec![0f32; b],
        })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// Run one training step; returns the mean per-example loss.
    pub fn step_once(&mut self) -> Result<f64> {
        let batch = self.source.next();
        let loss = self.apply_batch(&batch)?;
        self.step += 1;
        Ok(loss)
    }

    /// Execute + scatter one assembled batch (public for benches).
    pub fn apply_batch(&mut self, batch: &RawBatch) -> Result<f64> {
        let b = self.cfg.batch_size;
        let k = self.data.feat_dim;
        let lam = [self.cfg.hyper.lambda];
        let x_lit = lit_f32(&batch.x, &[b, k])?;
        let lam_lit = lit_f32(&lam, &[1])?;

        let mean_loss = match self.mode {
            BatchMode::NsLike | BatchMode::Pairwise => {
                self.params.gather(&batch.pos, &mut self.wp, &mut self.bp);
                self.params.gather(&batch.neg, &mut self.wn, &mut self.bn);
                let wp = lit_f32(&self.wp, &[b, k])?;
                let bp = lit_f32(&self.bp, &[b])?;
                let wn = lit_f32(&self.wn, &[b, k])?;
                let bn = lit_f32(&self.bn, &[b])?;
                let outs = if self.mode == BatchMode::NsLike {
                    let lpn_p = lit_f32(&batch.lpn_p, &[b])?;
                    let lpn_n = lit_f32(&batch.lpn_n, &[b])?;
                    self.step_exec
                        .run(&[x_lit, wp, bp, wn, bn, lpn_p, lpn_n, lam_lit])
                        .context("ns/nce step")?
                } else {
                    let scale = lit_f32(&batch.lpn_n, &[b])?;
                    self.step_exec
                        .run(&[x_lit, wp, bp, wn, bn, scale, lam_lit])
                        .context("ove step")?
                };
                let loss = read_f32(&outs[0])?;
                // read the row gradients into the (now free) gather
                // buffers instead of allocating — perf pass iteration 3
                crate::runtime::literal::read_f32_into(&outs[1], &mut self.wp)?;
                crate::runtime::literal::read_f32_into(&outs[2], &mut self.bp)?;
                crate::runtime::literal::read_f32_into(&outs[3], &mut self.wn)?;
                crate::runtime::literal::read_f32_into(&outs[4], &mut self.bn)?;
                self.params.apply_sparse(&batch.pos, &self.wp, &self.bp);
                self.params.apply_sparse(&batch.neg, &self.wn, &self.bn);
                loss.iter().map(|&l| l as f64).sum::<f64>() / b as f64
            }
            BatchMode::Softmax => {
                let c = self.params.num_classes;
                let w = lit_f32(&self.params.w, &[c, k])?;
                let bb = lit_f32(&self.params.b, &[c])?;
                let y: Vec<i32> = batch.pos.iter().map(|&v| v as i32).collect();
                let y_lit = lit_i32(&y, &[b])?;
                let outs = self
                    .step_exec
                    .run(&[x_lit, w, bb, y_lit, lam_lit])
                    .context("softmax step")?;
                let loss = read_f32(&outs[0])?;
                let gw = read_f32(&outs[1])?;
                let gb = read_f32(&outs[2])?;
                self.params.apply_dense(&gw, &gb);
                loss.iter().map(|&l| l as f64).sum::<f64>() / b as f64
            }
        };
        Ok(mean_loss)
    }

    /// Evaluate current parameters on the held-out eval subset, applying
    /// the Eq. 5 bias correction iff the method calls for it.
    pub fn evaluate_now(&mut self) -> Result<EvalResult> {
        self.evaluate_with(self.cfg.method.corrects_bias())
    }

    /// Evaluate with the Eq. 5 correction explicitly on/off (ablation A1).
    /// Requesting correction without a fitted tree evaluates uncorrected.
    pub fn evaluate_with(&mut self, bias_correction: bool) -> Result<EvalResult> {
        let cache = if bias_correction {
            match (&mut self.lpn_cache, &self.aux) {
                (slot @ None, Some(adv)) => {
                    *slot = Some(LpnCache::build(adv, &self.eval_set));
                    slot.as_ref()
                }
                (slot, _) => slot.as_ref(),
            }
        } else {
            None
        };
        self.evaluator
            .evaluate_cached(&self.params, &self.eval_set, cache)
    }

    /// Full training loop with the learning-curve protocol of Figure 1:
    /// train wallclock excludes evaluation, aux fit time preloads the
    /// clock, eval checkpoints are log-spaced (or every `eval_every`).
    pub fn train(&mut self) -> Result<LearningCurve> {
        let mut curve = LearningCurve::new(self.cfg.dataset, self.cfg.method, self.aux_fit_seconds);
        let mut watch = StopWatch::new();
        watch.preload(std::time::Duration::from_secs_f64(self.aux_fit_seconds));
        let mut next_eval = curve::next_eval_step(0, self.cfg.eval_every);
        let mut loss_sum = 0f64;
        let mut loss_n = 0usize;

        watch.resume();
        loop {
            let loss = self.step_once()?;
            loss_sum += loss;
            loss_n += 1;

            let done = self.step >= self.cfg.max_steps
                || watch.elapsed_secs() >= self.cfg.max_seconds + self.aux_fit_seconds;
            if self.step >= next_eval || done {
                watch.pause();
                let r = self.evaluate_now()?;
                curve.points.push(CurvePoint {
                    step: self.step,
                    wall_s: watch.elapsed_secs(),
                    train_loss: loss_sum / loss_n.max(1) as f64,
                    log_likelihood: r.log_likelihood,
                    accuracy: r.accuracy,
                });
                loss_sum = 0.0;
                loss_n = 0;
                next_eval = curve::next_eval_step(self.step, self.cfg.eval_every);
                watch.resume();
            }
            if done {
                break;
            }
        }
        Ok(curve)
    }
}

fn spawn_pipeline(mut gen: BatchGen) -> BatchSource {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let (tx, rx) = sync_channel::<RawBatch>(PIPELINE_DEPTH);
    let handle = std::thread::Builder::new()
        .name("batch-gen".into())
        .spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                let b = gen.next_batch();
                if tx.send(b).is_err() {
                    break;
                }
            }
        })
        .expect("spawn batch generator");
    BatchSource::Pipelined { rx, stop, handle: Some(handle) }
}

/// Minimal logging shim (keeps the library free of logger dependencies;
/// the CLI prints, tests stay quiet unless `REPRO_VERBOSE` is set).
mod log {
    pub fn info(msg: &str) {
        if std::env::var_os("REPRO_VERBOSE").is_some() {
            eprintln!("[repro] {msg}");
        }
    }
}
