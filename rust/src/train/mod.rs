//! Training coordinator: per-method update rules over the AOT HLO step
//! artifacts, with a host-parallel, deterministic step pipeline.
//!
//! # Step protocol
//!
//! The step protocol for sampling-based methods is gather → execute →
//! scatter: rust gathers the 2B touched parameter rows, the HLO artifact
//! (Pallas gradient core) computes the fused loss + row gradients, rust
//! scatters them back through sparse Adagrad. Cost per step is O(B·K) on
//! the host plus the kernel, independent of C — the property that makes
//! negative sampling scale (Sec. 2.1).
//!
//! # Performance architecture: pipeline, sharding, determinism
//!
//! Every host-side stage of a step is parallel, and every stage is
//! **bit-deterministic** — the same seed produces the same learning curve
//! at every `parallelism` setting:
//!
//! * **Batch pipeline** — negative generation (the O(k log C) tree
//!   descents) depends only on the features, never on the evolving
//!   parameters, so M workers assemble batches ahead of the coordinator.
//!   The batch stream is a pure function of (seed, batch sequence number):
//!   worker m produces batches `t ≡ m (mod M)` from per-batch RNG streams
//!   (see [`batcher`]), and the coordinator consumes the per-worker
//!   channels round-robin, so the stream is bit-identical to the inline
//!   path for every M. `RawBatch` buffers cycle back to their worker
//!   through a return channel — steady-state assembly is allocation-free.
//!   Within each worker, descents run through the SIMD-width
//!   [`crate::tree::TreeKernel`] (8 lanes per inner loop, canonical
//!   reduction order), bit-identical to the scalar walkers.
//! * **Sharded gather/scatter** — [`ParamStore::gather_par`] and
//!   [`ParamStore::apply_sparse_par`] shard rows by `label % num_shards`,
//!   so all updates to one row happen on one worker in batch order:
//!   duplicate-label Adagrad semantics stay exactly sequential-per-row and
//!   the result is bit-identical to the serial scatter. The softmax
//!   baseline's dense scatter shards contiguous row spans the same way
//!   ([`ParamStore::apply_dense_par`]).
//! * **Parallel eval sweep** — the Eq. 5 correction cache
//!   ([`LpnCache::build_with`]) shards its O(N·C·k) per-example sweep over
//!   the pool (bit-identical: one writer per row). The pure-rust reference
//!   evaluator has a pool variant too
//!   ([`crate::eval::evaluate_reference_with`], used by tests/benches; its
//!   f64 reduction order varies with worker count, so it stays out of the
//!   bit-deterministic training path).
//! * **Parallel aux-model fit** — the one-off cost the paper counts in
//!   its training-time claim is sharded too: PCA mean/covariance
//!   accumulate per fixed row-slab and reduce in slab order
//!   ([`crate::linalg::Pca::fit_with`]), and the tree fits level by level
//!   with the whole frontier of one depth running concurrently under
//!   per-node RNG streams ([`crate::tree::fit::fit_tree_with`]) — both
//!   bit-identical at every worker count.
//! * **Shutdown** — pipeline teardown closes both channel directions
//!   before joining, so a worker blocked on a full batch channel (or
//!   polling the buffer-return channel) observes disconnection and exits;
//!   there is no drain-then-join race and no stop flag.
//!
//! PJRT execution itself stays on the coordinator thread (the runtime
//! handles are not `Send`); the pipeline overlaps batch generation with
//! it, and the pool parallelizes the host stages around it.

pub mod batcher;
pub mod curve;

pub use batcher::{BatchGen, BatchMode, RawBatch, SamplerKind};
pub use curve::{CurvePoint, LearningCurve};

use crate::config::{Method, RunConfig};
use crate::data::{Dataset, Splits};
use crate::eval::{EvalResult, Evaluator, LpnCache};
use crate::model::ParamStore;
use crate::runtime::{lit_f32, lit_i32, read_f32, Executable, Registry};
use crate::sampler::{AdversarialSampler, FrequencySampler, UniformSampler};
use crate::utils::{Pool, Rng, StopWatch};
use anyhow::{Context, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Buffers in flight per pipeline worker (its private recycle pool).
const PIPELINE_DEPTH_PER_WORKER: usize = 2;
/// Cap on pipeline workers: batch assembly saturates well before the
/// coordinator-side stages, and idle workers only cost memory.
const PIPELINE_MAX_WORKERS: usize = 8;

/// Where batches come from: the inline generator or the worker pipeline.
/// Callers must return each batch via [`BatchSource::recycle`] so buffers
/// keep cycling instead of being reallocated.
pub struct BatchSource {
    inner: SourceInner,
}

enum SourceInner {
    Inline {
        gen: BatchGen,
        spare: Vec<RawBatch>,
    },
    Pipelined(Pipeline),
}

/// M workers, each with a bounded batch channel and a buffer-return
/// channel. Worker m owns batches `t ≡ m (mod M)`; the coordinator reads
/// the channels round-robin, which restores the global order.
struct Pipeline {
    batch_rx: Vec<Receiver<RawBatch>>,
    buf_tx: Vec<SyncSender<RawBatch>>,
    handles: Vec<JoinHandle<()>>,
    /// Worker whose batch is next in sequence order.
    next_worker: usize,
    /// Worker that produced the oldest outstanding batch (recycle target).
    recycle_worker: usize,
}

impl BatchSource {
    /// Single-thread source (batch assembled on the calling thread).
    pub fn inline(gen: BatchGen) -> Self {
        BatchSource { inner: SourceInner::Inline { gen, spare: Vec::new() } }
    }

    /// Spawn `workers` pipeline workers over `gen`'s batch stream.
    pub fn pipelined(gen: &BatchGen, workers: usize) -> Self {
        let m = workers.max(1);
        let mut batch_rx = Vec::with_capacity(m);
        let mut buf_tx = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for w in 0..m {
            let (btx, brx) = sync_channel::<RawBatch>(PIPELINE_DEPTH_PER_WORKER);
            let (rtx, rrx) = sync_channel::<RawBatch>(PIPELINE_DEPTH_PER_WORKER);
            let mut wgen = gen.worker(w as u64, m as u64);
            let handle = std::thread::Builder::new()
                .name(format!("batch-gen-{w}"))
                .spawn(move || {
                    use std::sync::mpsc::TryRecvError;
                    let (b, k) = (wgen.batch_size(), wgen.feat_dim());
                    loop {
                        // Prefer a recycled buffer; fall back to a fresh
                        // allocation so a caller that drops batches instead
                        // of recycling degrades to per-batch allocation
                        // (bounded by the batch channel's backpressure)
                        // rather than deadlocking the pipeline.
                        let mut buf = match rrx.try_recv() {
                            Ok(buf) => buf,
                            Err(TryRecvError::Empty) => RawBatch::alloc(b, k),
                            Err(TryRecvError::Disconnected) => break,
                        };
                        wgen.fill_next(&mut buf);
                        // errors once the coordinator closes its end
                        if btx.send(buf).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn batch generator");
            batch_rx.push(brx);
            buf_tx.push(rtx);
            handles.push(handle);
        }
        BatchSource {
            inner: SourceInner::Pipelined(Pipeline {
                batch_rx,
                buf_tx,
                handles,
                next_worker: 0,
                recycle_worker: 0,
            }),
        }
    }

    /// Next batch of the deterministic stream.
    pub fn next(&mut self) -> RawBatch {
        match &mut self.inner {
            SourceInner::Inline { gen, spare } => {
                let mut buf = spare
                    .pop()
                    .unwrap_or_else(|| RawBatch::alloc(gen.batch_size(), gen.feat_dim()));
                gen.fill_next(&mut buf);
                buf
            }
            SourceInner::Pipelined(p) => {
                let buf = p.batch_rx[p.next_worker]
                    .recv()
                    .expect("batch generator thread died");
                p.next_worker = (p.next_worker + 1) % p.batch_rx.len();
                buf
            }
        }
    }

    /// Return a consumed batch's buffers for reuse. Recycling in the order
    /// batches were taken (the training loop's natural behavior) routes
    /// each buffer back to the worker that produced it; skipped or
    /// out-of-order recycling is safe — workers allocate fresh buffers
    /// when their return queue is empty, and `try_send` drops the buffer
    /// when it is full.
    pub fn recycle(&mut self, batch: RawBatch) {
        match &mut self.inner {
            SourceInner::Inline { spare, .. } => spare.push(batch),
            SourceInner::Pipelined(p) => {
                let _ = p.buf_tx[p.recycle_worker].try_send(batch);
                p.recycle_worker = (p.recycle_worker + 1) % p.buf_tx.len();
            }
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Close both directions first: a worker blocked sending a finished
        // batch, or waiting for a recycled buffer, sees the disconnect and
        // exits. Only then join. (The previous design drained the batch
        // channel once and could re-fill before the worker checked its
        // stop flag — a deadlock on join.)
        self.batch_rx.clear();
        self.buf_tx.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A prepared training run: data, sampler, parameters, compiled step.
pub struct TrainRun {
    pub cfg: RunConfig,
    data: Arc<Dataset>,
    eval_set: Dataset,
    pub params: ParamStore,
    step_exec: Arc<Executable>,
    evaluator: Evaluator,
    /// Fitted auxiliary model (Some for methods that need the tree).
    pub aux: Option<Arc<AdversarialSampler>>,
    pub aux_fit_seconds: f64,
    /// Worker pool for the sharded host stages (gather/scatter/eval).
    pool: Pool,
    mode: BatchMode,
    source: BatchSource,
    step: usize,
    /// Eq. 5 correction cache for the fixed eval subset (built lazily on
    /// the first corrected evaluation; exact because the tree is frozen).
    lpn_cache: Option<LpnCache>,
    // scratch
    wp: Vec<f32>,
    bp: Vec<f32>,
    wn: Vec<f32>,
    bn: Vec<f32>,
}

impl TrainRun {
    /// Build everything needed to train `cfg.method` on `splits`.
    pub fn prepare(registry: &Registry, splits: &Splits, cfg: &RunConfig) -> Result<Self> {
        let shapes = &registry.manifest.shapes;
        anyhow::ensure!(
            cfg.batch_size == shapes.train_b,
            "batch_size {} must match AOT train_b {}",
            cfg.batch_size,
            shapes.train_b
        );
        anyhow::ensure!(
            splits.train.feat_dim == shapes.feat_k,
            "feat_dim {} must match AOT feat_k {}",
            splits.train.feat_dim,
            shapes.feat_k
        );
        if cfg.method == Method::Softmax {
            anyhow::ensure!(
                splits.train.num_classes == shapes.softmax_c,
                "softmax method requires C == AOT softmax_c ({} vs {})",
                splits.train.num_classes,
                shapes.softmax_c
            );
        }

        let data = Arc::new(splits.train.clone());
        let c = data.num_classes;
        let mut rng = Rng::new(cfg.seed);
        let pool = Pool::from_parallelism(cfg.parallelism);

        // --- auxiliary model (Sec. 3) ---
        let (aux, aux_fit_seconds) = if cfg.method.needs_tree() {
            let t0 = std::time::Instant::now();
            let (adv, stats) = AdversarialSampler::fit_with(&data, &cfg.tree, cfg.seed, &pool);
            let dt = t0.elapsed().as_secs_f64();
            let slowest_level = stats.level_seconds.iter().cloned().fold(0.0, f64::max);
            log::info(&format!(
                "aux tree fitted: {} nodes, {:.1}s ({} levels over {} workers, \
                 slowest level {:.2}s), train loglik {:.3}",
                stats.nodes_fitted,
                dt,
                stats.level_seconds.len(),
                pool.num_workers(),
                slowest_level,
                stats.train_mean_loglik
            ));
            (Some(Arc::new(adv)), dt)
        } else {
            (None, 0.0)
        };

        // --- sampler + batch mode ---
        let mode = BatchMode::of(cfg.method);
        let sampler = match cfg.method {
            Method::Adversarial | Method::Nce => {
                let adv = aux.clone().unwrap();
                let x_proj =
                    Arc::new(adv.pca.project_all_with(&data.features, data.len(), &pool));
                SamplerKind::Adversarial { sampler: adv, x_proj }
            }
            Method::Frequency => {
                SamplerKind::Frequency(FrequencySampler::from_dataset(&data, 1.0)?)
            }
            _ => SamplerKind::Uniform(UniformSampler::new(c)),
        };
        let scale = match cfg.method {
            Method::AugmentReduce => {
                (c as f32 - 1.0) / cfg.hyper.num_negatives.max(1) as f32
            }
            _ => 1.0,
        };
        let gen = BatchGen::new(
            data.clone(),
            sampler,
            mode,
            cfg.batch_size,
            scale,
            rng.split(1),
        );
        // Pipelining overlaps batch generation with PJRT execution; on a
        // single hardware thread there is nothing to overlap with and the
        // channels only add overhead, so fall back to inline generation.
        let multi_core = std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false);
        let source = if cfg.pipelined && multi_core && mode != BatchMode::Softmax {
            let workers = pool.num_workers().min(PIPELINE_MAX_WORKERS);
            BatchSource::pipelined(&gen, workers)
        } else {
            BatchSource::inline(gen)
        };

        // --- compiled step ---
        let exec_name = match cfg.method {
            Method::Adversarial | Method::Uniform | Method::Frequency => "ns_grad_",
            Method::Nce => "nce_grad_",
            Method::AugmentReduce | Method::OneVsEach => "ove_grad_",
            Method::Softmax => "softmax_grad_",
        };
        let step_exec = registry.get_by_prefix(exec_name)?;

        let eval_set = splits.test.subsample(cfg.eval_points, &mut rng.split(2));
        let b = cfg.batch_size;
        let k = data.feat_dim;
        Ok(Self {
            cfg: cfg.clone(),
            params: ParamStore::zeros(c, k, cfg.hyper.lr),
            data,
            eval_set,
            step_exec,
            evaluator: Evaluator::new(registry)?,
            aux,
            aux_fit_seconds,
            pool,
            mode,
            source,
            step: 0,
            lpn_cache: None,
            wp: vec![0f32; b * k],
            bp: vec![0f32; b],
            wn: vec![0f32; b * k],
            bn: vec![0f32; b],
        })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// Run one training step; returns the mean per-example loss.
    pub fn step_once(&mut self) -> Result<f64> {
        let batch = self.source.next();
        let result = self.apply_batch(&batch);
        self.source.recycle(batch);
        let loss = result?;
        self.step += 1;
        Ok(loss)
    }

    /// Execute + scatter one assembled batch (public for benches).
    pub fn apply_batch(&mut self, batch: &RawBatch) -> Result<f64> {
        let b = self.cfg.batch_size;
        let k = self.data.feat_dim;
        let lam = [self.cfg.hyper.lambda];
        let x_lit = lit_f32(&batch.x, &[b, k])?;
        let lam_lit = lit_f32(&lam, &[1])?;

        let mean_loss = match self.mode {
            BatchMode::NsLike | BatchMode::Pairwise => {
                self.params
                    .gather_par(&self.pool, &batch.pos, &mut self.wp, &mut self.bp);
                self.params
                    .gather_par(&self.pool, &batch.neg, &mut self.wn, &mut self.bn);
                let wp = lit_f32(&self.wp, &[b, k])?;
                let bp = lit_f32(&self.bp, &[b])?;
                let wn = lit_f32(&self.wn, &[b, k])?;
                let bn = lit_f32(&self.bn, &[b])?;
                let outs = if self.mode == BatchMode::NsLike {
                    let lpn_p = lit_f32(&batch.lpn_p, &[b])?;
                    let lpn_n = lit_f32(&batch.lpn_n, &[b])?;
                    self.step_exec
                        .run(&[x_lit, wp, bp, wn, bn, lpn_p, lpn_n, lam_lit])
                        .context("ns/nce step")?
                } else {
                    let scale = lit_f32(&batch.lpn_n, &[b])?;
                    self.step_exec
                        .run(&[x_lit, wp, bp, wn, bn, scale, lam_lit])
                        .context("ove step")?
                };
                let loss = read_f32(&outs[0])?;
                // read the row gradients into the (now free) gather
                // buffers instead of allocating — perf pass iteration 3
                crate::runtime::literal::read_f32_into(&outs[1], &mut self.wp)?;
                crate::runtime::literal::read_f32_into(&outs[2], &mut self.bp)?;
                crate::runtime::literal::read_f32_into(&outs[3], &mut self.wn)?;
                crate::runtime::literal::read_f32_into(&outs[4], &mut self.bn)?;
                self.params
                    .apply_sparse_par(&self.pool, &batch.pos, &self.wp, &self.bp);
                self.params
                    .apply_sparse_par(&self.pool, &batch.neg, &self.wn, &self.bn);
                loss.iter().map(|&l| l as f64).sum::<f64>() / b as f64
            }
            BatchMode::Softmax => {
                let c = self.params.num_classes;
                let w = lit_f32(&self.params.w, &[c, k])?;
                let bb = lit_f32(&self.params.b, &[c])?;
                let y: Vec<i32> = batch.pos.iter().map(|&v| v as i32).collect();
                let y_lit = lit_i32(&y, &[b])?;
                let outs = self
                    .step_exec
                    .run(&[x_lit, w, bb, y_lit, lam_lit])
                    .context("softmax step")?;
                let loss = read_f32(&outs[0])?;
                let gw = read_f32(&outs[1])?;
                let gb = read_f32(&outs[2])?;
                self.params.apply_dense_par(&self.pool, &gw, &gb);
                loss.iter().map(|&l| l as f64).sum::<f64>() / b as f64
            }
        };
        Ok(mean_loss)
    }

    /// Evaluate current parameters on the held-out eval subset, applying
    /// the Eq. 5 bias correction iff the method calls for it.
    pub fn evaluate_now(&mut self) -> Result<EvalResult> {
        self.evaluate_with(self.cfg.method.corrects_bias())
    }

    /// Evaluate with the Eq. 5 correction explicitly on/off (ablation A1).
    /// Requesting correction without a fitted tree evaluates uncorrected.
    pub fn evaluate_with(&mut self, bias_correction: bool) -> Result<EvalResult> {
        let cache = if bias_correction {
            match (&mut self.lpn_cache, &self.aux) {
                (slot @ None, Some(adv)) => {
                    *slot = Some(LpnCache::build_with(adv, &self.eval_set, &self.pool));
                    slot.as_ref()
                }
                (slot, _) => slot.as_ref(),
            }
        } else {
            None
        };
        self.evaluator
            .evaluate_cached_with(&self.params, &self.eval_set, cache, &self.pool)
    }

    /// Full training loop with the learning-curve protocol of Figure 1:
    /// train wallclock excludes evaluation, aux fit time preloads the
    /// clock, eval checkpoints are log-spaced (or every `eval_every`).
    pub fn train(&mut self) -> Result<LearningCurve> {
        let mut curve = LearningCurve::new(self.cfg.dataset, self.cfg.method, self.aux_fit_seconds);
        let mut watch = StopWatch::new();
        watch.preload(std::time::Duration::from_secs_f64(self.aux_fit_seconds));
        let mut next_eval = curve::next_eval_step(0, self.cfg.eval_every);
        let mut loss_sum = 0f64;
        let mut loss_n = 0usize;

        watch.resume();
        loop {
            let loss = self.step_once()?;
            loss_sum += loss;
            loss_n += 1;

            let done = self.step >= self.cfg.max_steps
                || watch.elapsed_secs() >= self.cfg.max_seconds + self.aux_fit_seconds;
            if self.step >= next_eval || done {
                watch.pause();
                let r = self.evaluate_now()?;
                curve.points.push(CurvePoint {
                    step: self.step,
                    wall_s: watch.elapsed_secs(),
                    train_loss: loss_sum / loss_n.max(1) as f64,
                    log_likelihood: r.log_likelihood,
                    accuracy: r.accuracy,
                });
                loss_sum = 0.0;
                loss_n = 0;
                next_eval = curve::next_eval_step(self.step, self.cfg.eval_every);
                watch.resume();
            }
            if done {
                break;
            }
        }
        Ok(curve)
    }
}

/// Minimal logging shim (keeps the library free of logger dependencies;
/// the CLI prints, tests stay quiet unless `REPRO_VERBOSE` is set).
mod log {
    pub fn info(msg: &str) {
        if std::env::var_os("REPRO_VERBOSE").is_some() {
            eprintln!("[repro] {msg}");
        }
    }
}
