//! Training coordinator: per-method update rules over the AOT HLO step
//! artifacts, with a host-parallel, deterministic step pipeline and a
//! double-buffered step engine.
//!
//! # Step protocol: a stage graph over two slots
//!
//! A sampling-method step is a graph of five stages: **gather** the 2B
//! touched parameter rows, **pack** them (plus the batch's features and
//! `lpn` corrections) into literals, **execute** the HLO artifact (Pallas
//! gradient core) on the PJRT runtime, **read back** the row gradients,
//! and **scatter** them through sparse Adagrad. Cost per step is O(B·K)
//! on the host plus the kernel, independent of C — the property that
//! makes negative sampling scale (Sec. 2.1).
//!
//! [`StepEngine`] runs that graph over **two in-flight step slots**
//! ([`StepSlot`]: own gather/readback scratch + reusable literal
//! buffers). With overlap enabled, while step *t* executes on the
//! coordinator thread (PJRT handles are not `Send`), step *t+1*'s host
//! work — parameter gather, `lpn` literal packing, and the x-literal
//! build — runs concurrently on the background workers
//! ([`Pool::submit_sharded`]):
//!
//! ```text
//!   coordinator:  …execute(t)─────────┐ readback(t) scatter(t) patch(t+1)
//!   pool workers: gather(t+1) lits(t+1)┘        (join before scatter)
//! ```
//!
//! **Conflict-aware row leasing** keeps this bit-exact: before the stage
//! launches, the rows step *t* will update are leased
//! ([`ParamStore::lease_rows`]); the eager gather skips leased rows and
//! [`ParamStore::patch_leased`] re-gathers exactly those slots after
//! *t*'s scatter lands. Every gathered buffer therefore holds precisely
//! what the serial gather-after-scatter would have read — the learning
//! curve is bit-identical to the serial protocol at every `parallelism`
//! setting and with overlap on or off (`RunConfig::overlap`, default
//! auto). The dense softmax baseline always runs the serial protocol:
//! its "gather" is the whole parameter matrix, so every row conflicts.
//!
//! Step-input literals recycle through a per-slot
//! [`crate::runtime::LitScratch`]: after execute(t), t's input literals
//! retire into the slot's scratch and step t+2 refills them in place —
//! steady-state literal creation allocates nothing.
//!
//! # Performance architecture: pipeline, sharding, determinism
//!
//! Every host-side stage of a step is parallel, and every stage is
//! **bit-deterministic** — the same seed produces the same learning curve
//! at every `parallelism` setting:
//!
//! * **Batch pipeline** — negative generation (the O(k log C) tree
//!   descents) depends only on the features, never on the evolving
//!   parameters, so M workers assemble batches ahead of the coordinator.
//!   The batch stream is a pure function of (seed, batch sequence number):
//!   worker m produces batches `t ≡ m (mod M)` from per-batch RNG streams
//!   (see [`batcher`]), and the coordinator consumes the per-worker
//!   channels round-robin, so the stream is bit-identical to the inline
//!   path for every M. `RawBatch` buffers cycle back to their worker
//!   through a return channel — steady-state assembly is allocation-free.
//!   Within each worker, descents run through the SIMD-width
//!   [`crate::tree::TreeKernel`] (8 lanes per inner loop, canonical
//!   reduction order), bit-identical to the scalar walkers.
//! * **Sharded gather/scatter** — [`ParamStore::gather_par`] and
//!   [`ParamStore::apply_sparse_par`] shard rows by `label % num_shards`,
//!   so all updates to one row happen on one worker in batch order:
//!   duplicate-label Adagrad semantics stay exactly sequential-per-row and
//!   the result is bit-identical to the serial scatter. The softmax
//!   baseline's dense scatter shards contiguous row spans the same way
//!   ([`ParamStore::apply_dense_par`]).
//! * **Parallel eval sweep** — the Eq. 5 correction cache
//!   ([`LpnCache::build_with`]) shards its O(N·C·k) per-example sweep over
//!   the pool (bit-identical: one writer per row). The pure-rust reference
//!   evaluator has a pool variant too
//!   ([`crate::eval::evaluate_reference_with`], used by tests/benches; its
//!   f64 reduction order varies with worker count, so it stays out of the
//!   bit-deterministic training path).
//! * **Parallel aux-model fit** — the one-off cost the paper counts in
//!   its training-time claim is sharded too: PCA mean/covariance
//!   accumulate per fixed row-slab and reduce in slab order
//!   ([`crate::linalg::Pca::fit_with`]), and the tree fits level by level
//!   with the whole frontier of one depth running concurrently under
//!   per-node RNG streams ([`crate::tree::fit::fit_tree_with`]) — both
//!   bit-identical at every worker count.
//! * **Shutdown** — pipeline teardown closes both channel directions
//!   before joining, so a worker blocked on a full batch channel (or
//!   polling the buffer-return channel) observes disconnection and exits;
//!   there is no drain-then-join race and no stop flag.
//!
//! PJRT execution itself stays on the coordinator thread (the runtime
//! handles are not `Send`); the batch pipeline overlaps batch generation
//! with it, the double-buffered engine overlaps the *next step's*
//! gather/literal stages with it, and the pool parallelizes the remaining
//! host stages around it.

pub mod batcher;
pub mod curve;

pub use batcher::{BatchGen, BatchMode, RawBatch, SamplerKind};
pub use curve::{CurvePoint, LearningCurve};

use crate::config::{Method, OverlapMode, RunConfig};
use crate::data::{Dataset, Splits};
use crate::eval::{EvalResult, Evaluator, LpnCache};
use crate::model::ParamStore;
use crate::runtime::{read_f32, read_f32_into, Executable, LitScratch, Registry};
use crate::sampler::{AdversarialSampler, FrequencySampler, UniformSampler};
use crate::utils::{Pool, Rng, SharedMut, StopWatch};
use anyhow::{Context, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Buffers in flight per pipeline worker (its private recycle pool).
const PIPELINE_DEPTH_PER_WORKER: usize = 2;
/// Cap on pipeline workers: batch assembly saturates well before the
/// coordinator-side stages, and idle workers only cost memory.
const PIPELINE_MAX_WORKERS: usize = 8;

/// Where batches come from: the inline generator or the worker pipeline.
/// Callers must return each batch via [`BatchSource::recycle`] so buffers
/// keep cycling instead of being reallocated.
pub struct BatchSource {
    inner: SourceInner,
}

enum SourceInner {
    Inline {
        gen: BatchGen,
        spare: Vec<RawBatch>,
    },
    Pipelined(Pipeline),
}

/// M workers, each with a bounded batch channel and a buffer-return
/// channel. Worker m owns batches `t ≡ m (mod M)`; the coordinator reads
/// the channels round-robin, which restores the global order.
struct Pipeline {
    batch_rx: Vec<Receiver<RawBatch>>,
    buf_tx: Vec<SyncSender<RawBatch>>,
    handles: Vec<JoinHandle<()>>,
    /// Worker whose batch is next in sequence order.
    next_worker: usize,
    /// Worker that produced the oldest outstanding batch (recycle target).
    recycle_worker: usize,
}

impl BatchSource {
    /// Single-thread source (batch assembled on the calling thread).
    pub fn inline(gen: BatchGen) -> Self {
        BatchSource { inner: SourceInner::Inline { gen, spare: Vec::new() } }
    }

    /// Spawn `workers` pipeline workers over `gen`'s batch stream.
    pub fn pipelined(gen: &BatchGen, workers: usize) -> Self {
        let m = workers.max(1);
        let mut batch_rx = Vec::with_capacity(m);
        let mut buf_tx = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for w in 0..m {
            let (btx, brx) = sync_channel::<RawBatch>(PIPELINE_DEPTH_PER_WORKER);
            let (rtx, rrx) = sync_channel::<RawBatch>(PIPELINE_DEPTH_PER_WORKER);
            let mut wgen = gen.worker(w as u64, m as u64);
            let handle = crate::utils::spawn_named(&format!("batch-gen-{w}"), move || {
                use std::sync::mpsc::TryRecvError;
                let (b, k) = (wgen.batch_size(), wgen.feat_dim());
                loop {
                    // Prefer a recycled buffer; fall back to a fresh
                    // allocation so a caller that drops batches instead
                    // of recycling degrades to per-batch allocation
                    // (bounded by the batch channel's backpressure)
                    // rather than deadlocking the pipeline.
                    let mut buf = match rrx.try_recv() {
                        Ok(buf) => buf,
                        Err(TryRecvError::Empty) => RawBatch::alloc(b, k),
                        Err(TryRecvError::Disconnected) => break,
                    };
                    wgen.fill_next(&mut buf);
                    // errors once the coordinator closes its end
                    if btx.send(buf).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn batch generator");
            batch_rx.push(brx);
            buf_tx.push(rtx);
            handles.push(handle);
        }
        BatchSource {
            inner: SourceInner::Pipelined(Pipeline {
                batch_rx,
                buf_tx,
                handles,
                next_worker: 0,
                recycle_worker: 0,
            }),
        }
    }

    /// Next batch of the deterministic stream.
    pub fn next(&mut self) -> RawBatch {
        match &mut self.inner {
            SourceInner::Inline { gen, spare } => {
                let mut buf = spare
                    .pop()
                    .unwrap_or_else(|| RawBatch::alloc(gen.batch_size(), gen.feat_dim()));
                gen.fill_next(&mut buf);
                buf
            }
            SourceInner::Pipelined(p) => {
                let buf = p.batch_rx[p.next_worker]
                    .recv()
                    .expect("batch generator thread died");
                p.next_worker = (p.next_worker + 1) % p.batch_rx.len();
                buf
            }
        }
    }

    /// Return a consumed batch's buffers for reuse. Recycling in the order
    /// batches were taken (the training loop's natural behavior) routes
    /// each buffer back to the worker that produced it; skipped or
    /// out-of-order recycling is safe — workers allocate fresh buffers
    /// when their return queue is empty, and `try_send` drops the buffer
    /// when it is full.
    pub fn recycle(&mut self, batch: RawBatch) {
        match &mut self.inner {
            SourceInner::Inline { spare, .. } => spare.push(batch),
            SourceInner::Pipelined(p) => {
                let _ = p.buf_tx[p.recycle_worker].try_send(batch);
                p.recycle_worker = (p.recycle_worker + 1) % p.buf_tx.len();
            }
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Close both directions first: a worker blocked sending a finished
        // batch, or waiting for a recycled buffer, sees the disconnect and
        // exits. Only then join. (The previous design drained the batch
        // channel once and could re-fill before the worker checked its
        // stop flag — a deadlock on join.)
        self.batch_rx.clear();
        self.buf_tx.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The device half of a step: anything that can execute a prepared input
/// set and return the output tuple (loss + gradients) in manifest order.
/// [`Executable`] is the production implementation; tests and benches
/// drive the engine with deterministic host mocks (the vendored `xla`
/// stub cannot execute HLO).
pub trait StepExecutor {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>>;
}

impl StepExecutor for Executable {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run(inputs)
    }
}

/// Executable input positions shared by the NS-like and pairwise layouts:
/// `[x, wp, bp, wn, bn, …tail…]` where the tail is `[lpn_p, lpn_n, lam]`
/// (NS/NCE) or `[scale, lam]` (OVE/A&R). Softmax uses
/// `[x, w, b, y, lam]`, assembled inline in the serial path.
const IN_X: usize = 0;
const IN_WP: usize = 1;
const IN_BP: usize = 2;
const IN_WN: usize = 3;
const IN_BN: usize = 4;

/// Executable input count for a batch mode.
fn num_inputs(mode: BatchMode) -> usize {
    match mode {
        BatchMode::NsLike => 8,   // x, wp, bp, wn, bn, lpn_p, lpn_n, lam
        BatchMode::Pairwise => 7, // x, wp, bp, wn, bn, scale, lam
        BatchMode::Softmax => 5,  // x, w, b, y, lam
    }
}

/// One of the two in-flight step slots of the double-buffered engine: the
/// step being executed and the step being prepared each own a full set of
/// gather/readback scratch and literal buffers, so the stages of
/// consecutive steps never contend (module docs).
struct StepSlot {
    /// The slot's assembled batch (present from fetch until the step's
    /// scatter has landed and the buffers return to the pipeline).
    batch: Option<RawBatch>,
    /// Executable inputs by position, sealed in two stages: batch-derived
    /// literals during the background stage, parameter-row literals after
    /// the patch.
    lits: Vec<Option<xla::Literal>>,
    /// Error raised by the background literal build (single-writer cell;
    /// surfaced on the coordinator at the join point).
    lit_err: Option<anyhow::Error>,
    /// Recycler for retired step-input literals (allocation-free refills).
    scratch: LitScratch,
    /// Gather buffers for the positive/negative rows; after execute they
    /// double as the gradient readback buffers.
    wp: Vec<f32>,
    bp: Vec<f32>,
    wn: Vec<f32>,
    bn: Vec<f32>,
    /// Gather + literals reflect the current parameters and the slot can
    /// be executed as-is.
    prepared: bool,
}

impl StepSlot {
    /// `with_gather` sizes the row scratch: false for slots that never
    /// gather (softmax — the dense path reads the whole matrix — and the
    /// second slot of a serial-protocol engine, which is never prepared).
    fn new(batch_size: usize, feat_dim: usize, n_inputs: usize, with_gather: bool) -> Self {
        let (wlen, blen) = if with_gather {
            (batch_size * feat_dim, batch_size)
        } else {
            (0, 0)
        };
        Self {
            batch: None,
            lits: (0..n_inputs).map(|_| None).collect(),
            lit_err: None,
            scratch: LitScratch::new(),
            wp: vec![0f32; wlen],
            bp: vec![0f32; blen],
            wn: vec![0f32; wlen],
            bn: vec![0f32; blen],
            prepared: false,
        }
    }

    /// Retire any sealed literals back into the slot's scratch.
    fn recycle_lits(&mut self) {
        for s in self.lits.iter_mut() {
            if let Some(lit) = s.take() {
                self.scratch.recycle(lit);
            }
        }
    }
}

/// Move a sealed slot's literals out for the execute call.
fn take_inputs(lits: &mut [Option<xla::Literal>]) -> Vec<xla::Literal> {
    lits.iter_mut()
        .map(|s| s.take().expect("slot literals sealed before execute"))
        .collect()
}

/// Build the batch-derived inputs (x, lpn/scale, lam) for a slot. The
/// parameter-row literals are built separately, after the gathered rows
/// are final ([`build_param_lits`]). Runs either inline (serial protocol)
/// or on stage shard 0 of the background stage.
fn build_batch_lits(
    scratch: &mut LitScratch,
    lits: &mut [Option<xla::Literal>],
    batch: &RawBatch,
    mode: BatchMode,
    b: usize,
    k: usize,
    lam: f32,
) -> Result<()> {
    lits[IN_X] = Some(scratch.lit_f32(&batch.x, &[b, k])?);
    match mode {
        BatchMode::NsLike => {
            lits[5] = Some(scratch.lit_f32(&batch.lpn_p, &[b])?);
            lits[6] = Some(scratch.lit_f32(&batch.lpn_n, &[b])?);
            lits[7] = Some(scratch.lit_f32(&[lam], &[1])?);
        }
        BatchMode::Pairwise => {
            lits[5] = Some(scratch.lit_f32(&batch.lpn_n, &[b])?);
            lits[6] = Some(scratch.lit_f32(&[lam], &[1])?);
        }
        BatchMode::Softmax => unreachable!("softmax inputs are assembled inline"),
    }
    Ok(())
}

/// Seal a slot's parameter-row literals from its (final) gather buffers.
fn build_param_lits(slot: &mut StepSlot, b: usize, k: usize) -> Result<()> {
    slot.lits[IN_WP] = Some(slot.scratch.lit_f32(&slot.wp, &[b, k])?);
    slot.lits[IN_BP] = Some(slot.scratch.lit_f32(&slot.bp, &[b])?);
    slot.lits[IN_WN] = Some(slot.scratch.lit_f32(&slot.wn, &[b, k])?);
    slot.lits[IN_BN] = Some(slot.scratch.lit_f32(&slot.bn, &[b])?);
    Ok(())
}

/// The double-buffered step engine (module docs): owns the two step slots
/// and runs the stage graph either strictly serially or with step t+1's
/// host stages overlapped behind step t's execute. Parameters, pool and
/// batch source stay with the caller so tests and benches can drive the
/// engine with mock executors.
pub struct StepEngine {
    mode: BatchMode,
    batch_size: usize,
    feat_dim: usize,
    lambda: f32,
    overlap: bool,
    slots: [StepSlot; 2],
    /// Slot holding the fully prepared next step, if any.
    pending: Option<usize>,
    // softmax scratch: labels as i32 + dense gradient readback (reused
    // across steps instead of per-step allocations)
    y_i32: Vec<i32>,
    gw_dense: Vec<f32>,
    gb_dense: Vec<f32>,
    /// Batch slots re-gathered by the post-scatter patch (engine lifetime).
    pub rows_patched: u64,
    /// Steps that ran the overlapped protocol.
    pub steps_overlapped: u64,
}

impl StepEngine {
    pub fn new(
        mode: BatchMode,
        batch_size: usize,
        feat_dim: usize,
        lambda: f32,
        overlap: bool,
    ) -> Self {
        let n = num_inputs(mode);
        let gather0 = mode != BatchMode::Softmax;
        let gather1 = gather0 && overlap; // slot 1 exists only for overlap
        Self {
            mode,
            batch_size,
            feat_dim,
            lambda,
            overlap,
            slots: [
                StepSlot::new(batch_size, feat_dim, n, gather0),
                StepSlot::new(batch_size, feat_dim, n, gather1),
            ],
            pending: None,
            y_i32: Vec::new(),
            gw_dense: Vec::new(),
            gb_dense: Vec::new(),
            rows_patched: 0,
            steps_overlapped: 0,
        }
    }

    /// Does this engine run the overlapped protocol? (Softmax always runs
    /// serially: its dense update conflicts with every row.)
    pub fn overlap_enabled(&self) -> bool {
        self.overlap && self.mode != BatchMode::Softmax
    }

    /// Drop any prefetched step state. Call after mutating the parameters
    /// outside the engine (e.g. [`StepEngine::apply_batch`] does this
    /// internally): the prefetched gather would otherwise be stale against
    /// the serial protocol. The prefetched batch itself is kept — it is
    /// the next batch of the deterministic stream — and is re-gathered on
    /// the next step.
    pub fn invalidate_prefetch(&mut self) {
        for slot in self.slots.iter_mut() {
            slot.prepared = false;
            slot.recycle_lits();
        }
    }

    /// Run one full step of the configured protocol; returns the mean
    /// per-example loss. Bit-identical results with overlap on or off.
    pub fn step(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        source: &mut BatchSource,
    ) -> Result<f64> {
        if !self.overlap_enabled() {
            let batch = source.next();
            let result = self.run_serial(exec, params, pool, &batch);
            source.recycle(batch);
            return result;
        }
        self.step_overlapped(exec, params, pool, source)
    }

    /// Serial protocol on a caller-supplied batch. Invalidates any
    /// prefetched slot first (the scatter below would make it stale).
    pub fn apply_batch(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        batch: &RawBatch,
    ) -> Result<f64> {
        self.invalidate_prefetch();
        self.run_serial(exec, params, pool, batch)
    }

    /// gather → pack → execute → readback → scatter, all on the calling
    /// thread (pool-sharded within each stage). The reference protocol
    /// the overlapped path must match bit for bit.
    fn run_serial(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        batch: &RawBatch,
    ) -> Result<f64> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        match self.mode {
            BatchMode::NsLike | BatchMode::Pairwise => {
                let mode = self.mode;
                let slot = &mut self.slots[0];
                params.gather_par(pool, &batch.pos, &mut slot.wp, &mut slot.bp);
                params.gather_par(pool, &batch.neg, &mut slot.wn, &mut slot.bn);
                build_batch_lits(&mut slot.scratch, &mut slot.lits, batch, mode, b, k, lam)?;
                build_param_lits(slot, b, k)?;
                let inputs = take_inputs(&mut slot.lits);
                let result = exec.run_step(&inputs).context(match mode {
                    BatchMode::NsLike => "ns/nce step",
                    _ => "ove step",
                });
                for lit in inputs {
                    slot.scratch.recycle(lit);
                }
                let outs = result?;
                let loss = read_f32(&outs[0])?;
                // read the row gradients into the (now free) gather
                // buffers instead of allocating — perf pass iteration 3
                read_f32_into(&outs[1], &mut slot.wp)?;
                read_f32_into(&outs[2], &mut slot.bp)?;
                read_f32_into(&outs[3], &mut slot.wn)?;
                read_f32_into(&outs[4], &mut slot.bn)?;
                params.apply_sparse_par(pool, &batch.pos, &slot.wp, &slot.bp);
                params.apply_sparse_par(pool, &batch.neg, &slot.wn, &slot.bn);
                Ok(crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64)
            }
            BatchMode::Softmax => {
                let c = params.num_classes;
                // reusable i32 label + dense-gradient scratch (these were
                // per-step allocations before the engine refactor)
                self.y_i32.clear();
                self.y_i32.extend(batch.pos.iter().map(|&v| v as i32));
                self.gw_dense.resize(c * k, 0.0);
                self.gb_dense.resize(c, 0.0);
                let slot = &mut self.slots[0];
                slot.lits[0] = Some(slot.scratch.lit_f32(&batch.x, &[b, k])?);
                slot.lits[1] = Some(slot.scratch.lit_f32(&params.w, &[c, k])?);
                slot.lits[2] = Some(slot.scratch.lit_f32(&params.b, &[c])?);
                slot.lits[3] = Some(slot.scratch.lit_i32(&self.y_i32, &[b])?);
                slot.lits[4] = Some(slot.scratch.lit_f32(&[lam], &[1])?);
                let inputs = take_inputs(&mut slot.lits);
                let result = exec.run_step(&inputs).context("softmax step");
                for lit in inputs {
                    slot.scratch.recycle(lit);
                }
                let outs = result?;
                let loss = read_f32(&outs[0])?;
                read_f32_into(&outs[1], &mut self.gw_dense)?;
                read_f32_into(&outs[2], &mut self.gb_dense)?;
                params.apply_dense_par(pool, &self.gw_dense, &self.gb_dense);
                Ok(crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64)
            }
        }
    }

    /// Bring `idx`'s slot to "prepared" through the serial stages (cold
    /// start and post-invalidation re-preparation).
    fn prepare_slot(&mut self, idx: usize, params: &ParamStore, pool: &Pool) -> Result<()> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        let mode = self.mode;
        let slot = &mut self.slots[idx];
        slot.recycle_lits();
        let batch = slot.batch.as_ref().expect("prepare_slot needs a fetched batch");
        params.gather_par(pool, &batch.pos, &mut slot.wp, &mut slot.bp);
        params.gather_par(pool, &batch.neg, &mut slot.wn, &mut slot.bn);
        build_batch_lits(&mut slot.scratch, &mut slot.lits, batch, mode, b, k, lam)?;
        build_param_lits(slot, b, k)?;
        slot.prepared = true;
        Ok(())
    }

    /// The overlapped protocol (module docs): execute step t while step
    /// t+1's gather + batch-literal stages run on the background workers,
    /// then scatter t and patch t+1's leased rows.
    fn step_overlapped(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        source: &mut BatchSource,
    ) -> Result<f64> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        let mode = self.mode;

        // Current step's slot: the prepared pending slot, or a cold start
        // (first step, or the step after an aborted one — residue from an
        // abort is dropped; the pipeline tolerates unreturned buffers).
        let cur_idx = match self.pending.take() {
            Some(i) => i,
            None => {
                for slot in self.slots.iter_mut() {
                    slot.batch = None;
                    slot.recycle_lits();
                    slot.prepared = false;
                }
                self.slots[0].batch = Some(source.next());
                0
            }
        };
        if !self.slots[cur_idx].prepared {
            // cold start or an external invalidation: serial preparation
            self.prepare_slot(cur_idx, params, pool)?;
        }
        let nxt_idx = 1 - cur_idx;
        {
            let nxt = &mut self.slots[nxt_idx];
            debug_assert!(nxt.batch.is_none() && !nxt.prepared);
            nxt.batch = Some(source.next());
            nxt.lit_err = None;
        }

        let (cur, nxt) = {
            let (a, z) = self.slots.split_at_mut(1);
            if cur_idx == 0 {
                (&mut a[0], &mut z[0])
            } else {
                (&mut z[0], &mut a[0])
            }
        };

        // Lease step t's update set, then launch t+1's host stages on the
        // background workers while t executes here. Nothing writes the
        // parameters until the stage is joined, so the eager gather is
        // race-free; leased (conflicting) rows are skipped and patched
        // after the scatter below.
        let cur_batch = cur.batch.as_ref().expect("prepared slot holds its batch");
        let lease = params.lease_rows(&[&cur_batch.pos, &cur_batch.neg]);
        let exec_result;
        {
            let nxt_batch: &RawBatch = nxt.batch.as_ref().unwrap();
            let wp_view = SharedMut::new(&mut nxt.wp);
            let bp_view = SharedMut::new(&mut nxt.bp);
            let wn_view = SharedMut::new(&mut nxt.wn);
            let bn_view = SharedMut::new(&mut nxt.bn);
            let lits_view = SharedMut::new(nxt.lits.as_mut_slice());
            let scratch_view = SharedMut::new(std::slice::from_mut(&mut nxt.scratch));
            let err_view = SharedMut::new(std::slice::from_mut(&mut nxt.lit_err));
            let params_ref: &ParamStore = params;
            let shards = pool.stage_shards();
            let stage = pool.submit_sharded(move |shard| {
                if shard == 0 {
                    // SAFETY: stage shard 0 is the only writer of the
                    // literal array, the scratch and the error cell.
                    let (scratch, lits, err) = unsafe {
                        (
                            &mut scratch_view.slice_mut(0, 1)[0],
                            lits_view.slice_mut(0, lits_view.len()),
                            &mut err_view.slice_mut(0, 1)[0],
                        )
                    };
                    if let Err(e) = build_batch_lits(scratch, lits, nxt_batch, mode, b, k, lam)
                    {
                        *err = Some(e);
                    }
                }
                params_ref
                    .gather_leased_shard(&nxt_batch.pos, lease, shards, shard, &wp_view, &bp_view);
                params_ref
                    .gather_leased_shard(&nxt_batch.neg, lease, shards, shard, &wn_view, &bn_view);
            });

            // Device half of step t: the coordinator blocks here — this is
            // the latency the background stage hides.
            let inputs = take_inputs(&mut cur.lits);
            exec_result = exec.run_step(&inputs);
            stage.join();
            // retire t's inputs for reuse by step t+2 in this slot
            for lit in inputs {
                cur.scratch.recycle(lit);
            }
        }
        cur.prepared = false;
        // Transient-failure contract: on an execute failure, batch t is
        // lost without a scatter — exactly as in the serial protocol,
        // which recycles the failed batch — and the prefetched batch t+1
        // is handed back as an *unprepared* pending slot, so a retrying
        // caller resumes on the serial batch stream with the serial
        // parameters (tests/overlap_parity.rs pins this). The other error
        // exits are deterministic configuration faults, not transient,
        // and don't promise cross-protocol parity: a background
        // literal-build failure also drops step t (its successful execute
        // is discarded unscattered) but still salvages t+1, and a
        // readback/seal shape mismatch below returns before t's scatter
        // and falls back to the cold-start reset on the next call.
        if let Some(e) = nxt.lit_err.take() {
            nxt.recycle_lits();
            self.pending = Some(nxt_idx);
            source.recycle(cur.batch.take().expect("current slot holds its batch"));
            return Err(e.context("background literal build"));
        }
        let outs = match exec_result {
            Ok(outs) => outs,
            Err(e) => {
                nxt.recycle_lits();
                self.pending = Some(nxt_idx);
                source.recycle(cur.batch.take().expect("current slot holds its batch"));
                return Err(e.context(match mode {
                    BatchMode::NsLike => "ns/nce step",
                    _ => "ove step",
                }));
            }
        };

        // Readback + scatter of step t (reusing t's gather buffers).
        let loss = read_f32(&outs[0])?;
        read_f32_into(&outs[1], &mut cur.wp)?;
        read_f32_into(&outs[2], &mut cur.bp)?;
        read_f32_into(&outs[3], &mut cur.wn)?;
        read_f32_into(&outs[4], &mut cur.bn)?;
        params.apply_sparse_par(pool, &cur_batch.pos, &cur.wp, &cur.bp);
        params.apply_sparse_par(pool, &cur_batch.neg, &cur.wn, &cur.bn);
        let mean_loss = crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64;

        // Patch t+1's leased rows now that t's scatter has landed, then
        // seal its parameter literals: the slot is fully prepared.
        {
            let nxt_batch = nxt.batch.as_ref().unwrap();
            self.rows_patched +=
                params.patch_leased(&nxt_batch.pos, lease, &mut nxt.wp, &mut nxt.bp) as u64;
            self.rows_patched +=
                params.patch_leased(&nxt_batch.neg, lease, &mut nxt.wn, &mut nxt.bn) as u64;
        }
        build_param_lits(nxt, b, k)?;
        nxt.prepared = true;
        self.steps_overlapped += 1;

        // Retire step t's batch buffers to the pipeline and hand over.
        source.recycle(cur.batch.take().expect("current slot holds its batch"));
        self.pending = Some(nxt_idx);
        Ok(mean_loss)
    }
}

/// A prepared training run: data, sampler, parameters, compiled step.
pub struct TrainRun {
    pub cfg: RunConfig,
    data: Arc<Dataset>,
    eval_set: Dataset,
    pub params: ParamStore,
    step_exec: Arc<Executable>,
    evaluator: Evaluator,
    /// Fitted auxiliary model (Some for methods that need the tree).
    pub aux: Option<Arc<AdversarialSampler>>,
    pub aux_fit_seconds: f64,
    /// Worker pool for the sharded host stages (gather/scatter/eval).
    pool: Pool,
    source: BatchSource,
    /// The double-buffered (or serial) stage graph over the step slots.
    engine: StepEngine,
    step: usize,
    /// Eq. 5 correction cache for the fixed eval subset (built lazily on
    /// the first corrected evaluation; exact because the tree is frozen).
    lpn_cache: Option<LpnCache>,
}

impl TrainRun {
    /// Build everything needed to train `cfg.method` on `splits`.
    pub fn prepare(registry: &Registry, splits: &Splits, cfg: &RunConfig) -> Result<Self> {
        let shapes = &registry.manifest.shapes;
        anyhow::ensure!(
            cfg.batch_size == shapes.train_b,
            "batch_size {} must match AOT train_b {}",
            cfg.batch_size,
            shapes.train_b
        );
        anyhow::ensure!(
            splits.train.feat_dim == shapes.feat_k,
            "feat_dim {} must match AOT feat_k {}",
            splits.train.feat_dim,
            shapes.feat_k
        );
        if cfg.method == Method::Softmax {
            anyhow::ensure!(
                splits.train.num_classes == shapes.softmax_c,
                "softmax method requires C == AOT softmax_c ({} vs {})",
                splits.train.num_classes,
                shapes.softmax_c
            );
        }

        let data = Arc::new(splits.train.clone());
        let c = data.num_classes;
        let mut rng = Rng::new(cfg.seed);
        let pool = Pool::from_parallelism(cfg.parallelism);

        // --- auxiliary model (Sec. 3) ---
        let (aux, aux_fit_seconds) = if cfg.method.needs_tree() {
            let t0 = StopWatch::started();
            let (adv, stats) = AdversarialSampler::fit_with(&data, &cfg.tree, cfg.seed, &pool);
            let dt = t0.elapsed_secs();
            let slowest_level = stats.level_seconds.iter().cloned().fold(0.0, f64::max);
            log::info(&format!(
                "aux tree fitted: {} nodes, {:.1}s ({} levels over {} workers, \
                 slowest level {:.2}s), train loglik {:.3}",
                stats.nodes_fitted,
                dt,
                stats.level_seconds.len(),
                pool.num_workers(),
                slowest_level,
                stats.train_mean_loglik
            ));
            (Some(Arc::new(adv)), dt)
        } else {
            (None, 0.0)
        };

        // --- sampler + batch mode ---
        let mode = BatchMode::of(cfg.method);
        let sampler = match cfg.method {
            Method::Adversarial | Method::Nce => {
                let adv = aux.clone().unwrap();
                let x_proj =
                    Arc::new(adv.pca.project_all_with(&data.features, data.len(), &pool));
                SamplerKind::Adversarial { sampler: adv, x_proj }
            }
            Method::Frequency => {
                SamplerKind::Frequency(FrequencySampler::from_dataset(&data, 1.0)?)
            }
            _ => SamplerKind::Uniform(UniformSampler::new(c)),
        };
        let scale = match cfg.method {
            Method::AugmentReduce => {
                (c as f32 - 1.0) / cfg.hyper.num_negatives.max(1) as f32
            }
            _ => 1.0,
        };
        let gen = BatchGen::new(
            data.clone(),
            sampler,
            mode,
            cfg.batch_size,
            scale,
            rng.split(1),
        );
        // Pipelining overlaps batch generation with PJRT execution; on a
        // single hardware thread there is nothing to overlap with and the
        // channels only add overhead, so fall back to inline generation.
        let multi_core = std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false);
        let source = if cfg.pipelined && multi_core && mode != BatchMode::Softmax {
            let workers = pool.num_workers().min(PIPELINE_MAX_WORKERS);
            BatchSource::pipelined(&gen, workers)
        } else {
            BatchSource::inline(gen)
        };

        // --- compiled step ---
        let exec_name = match cfg.method {
            Method::Adversarial | Method::Uniform | Method::Frequency => "ns_grad_",
            Method::Nce => "nce_grad_",
            Method::AugmentReduce | Method::OneVsEach => "ove_grad_",
            Method::Softmax => "softmax_grad_",
        };
        let step_exec = registry.get_by_prefix(exec_name)?;

        let eval_set = splits.test.subsample(cfg.eval_points, &mut rng.split(2));
        let b = cfg.batch_size;
        let k = data.feat_dim;
        // Overlap needs at least one background worker to hide the stage
        // behind the execute; on a serial pool (or single hardware thread)
        // the protocol degrades to inline calls, so auto turns it off.
        let overlap = match cfg.overlap {
            OverlapMode::On => true,
            OverlapMode::Off => false,
            OverlapMode::Auto => multi_core && pool.num_workers() > 1,
        };
        let engine = StepEngine::new(mode, b, k, cfg.hyper.lambda, overlap);
        Ok(Self {
            cfg: cfg.clone(),
            params: ParamStore::zeros(c, k, cfg.hyper.lr),
            data,
            eval_set,
            step_exec,
            evaluator: Evaluator::new(registry)?,
            aux,
            aux_fit_seconds,
            pool,
            source,
            engine,
            step: 0,
            lpn_cache: None,
        })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// Run one training step; returns the mean per-example loss. With
    /// overlap enabled this also advances the prefetched next step (see
    /// [`StepEngine`]); results are bit-identical either way.
    pub fn step_once(&mut self) -> Result<f64> {
        let loss = self.engine.step(
            self.step_exec.as_ref(),
            &mut self.params,
            &self.pool,
            &mut self.source,
        )?;
        self.step += 1;
        Ok(loss)
    }

    /// Execute + scatter one assembled batch through the strictly serial
    /// protocol (public for benches). Any prefetched overlapped step is
    /// invalidated first and transparently re-gathered on the next
    /// [`TrainRun::step_once`] — the caller's batch is applied with exact
    /// serial semantics, and the engine's own batch stream resumes where
    /// it left off (note the stream runs one batch ahead under overlap,
    /// so interleaving external batches reorders *between* the two
    /// streams, never within either).
    pub fn apply_batch(&mut self, batch: &RawBatch) -> Result<f64> {
        self.engine.apply_batch(
            self.step_exec.as_ref(),
            &mut self.params,
            &self.pool,
            batch,
        )
    }

    /// Engine introspection (overlap + patch counters; tests/benches).
    pub fn engine(&self) -> &StepEngine {
        &self.engine
    }

    /// Drop prefetched step state after mutating [`TrainRun::params`]
    /// directly (the engine re-gathers on the next step). Without this, an
    /// external parameter edit between overlapped steps would train the
    /// next step on pre-edit rows.
    pub fn invalidate_prefetch(&mut self) {
        self.engine.invalidate_prefetch();
    }

    /// Immutable serving snapshot of the current parameters plus the
    /// frozen auxiliary model — classifier rows only, no Adagrad state —
    /// for the serve/predict pipeline (`repro train --save-model`).
    pub fn serving_model(&self) -> crate::serve::ServingModel {
        crate::serve::ServingModel::from_parts(
            &self.params,
            self.aux.as_deref(),
            self.cfg.method.corrects_bias(),
        )
    }

    /// Evaluate current parameters on the held-out eval subset, applying
    /// the Eq. 5 bias correction iff the method calls for it.
    pub fn evaluate_now(&mut self) -> Result<EvalResult> {
        self.evaluate_with(self.cfg.method.corrects_bias())
    }

    /// Evaluate with the Eq. 5 correction explicitly on/off (ablation A1).
    /// Requesting correction without a fitted tree evaluates uncorrected.
    pub fn evaluate_with(&mut self, bias_correction: bool) -> Result<EvalResult> {
        let cache = if bias_correction {
            match (&mut self.lpn_cache, &self.aux) {
                (slot @ None, Some(adv)) => {
                    *slot = Some(LpnCache::build_with(adv, &self.eval_set, &self.pool));
                    slot.as_ref()
                }
                (slot, _) => slot.as_ref(),
            }
        } else {
            None
        };
        self.evaluator
            .evaluate_cached_with(&self.params, &self.eval_set, cache, &self.pool)
    }

    /// Full training loop with the learning-curve protocol of Figure 1:
    /// train wallclock excludes evaluation, aux fit time preloads the
    /// clock, eval checkpoints are log-spaced (or every `eval_every`).
    pub fn train(&mut self) -> Result<LearningCurve> {
        let mut curve = LearningCurve::new(self.cfg.dataset, self.cfg.method, self.aux_fit_seconds);
        let mut watch = StopWatch::new();
        watch.preload(std::time::Duration::from_secs_f64(self.aux_fit_seconds));
        let mut next_eval = curve::next_eval_step(0, self.cfg.eval_every);
        let mut loss_sum = 0f64;
        let mut loss_n = 0usize;

        watch.resume();
        loop {
            let loss = self.step_once()?;
            loss_sum += loss;
            loss_n += 1;

            let done = self.step >= self.cfg.max_steps
                || watch.elapsed_secs() >= self.cfg.max_seconds + self.aux_fit_seconds;
            if self.step >= next_eval || done {
                watch.pause();
                let r = self.evaluate_now()?;
                curve.points.push(CurvePoint {
                    step: self.step,
                    wall_s: watch.elapsed_secs(),
                    train_loss: loss_sum / loss_n.max(1) as f64,
                    log_likelihood: r.log_likelihood,
                    accuracy: r.accuracy,
                });
                loss_sum = 0.0;
                loss_n = 0;
                next_eval = curve::next_eval_step(self.step, self.cfg.eval_every);
                watch.resume();
            }
            if done {
                break;
            }
        }
        Ok(curve)
    }
}

/// Minimal logging shim (keeps the library free of logger dependencies;
/// the CLI prints, tests stay quiet unless `REPRO_VERBOSE` is set).
mod log {
    pub fn info(msg: &str) {
        if std::env::var_os("REPRO_VERBOSE").is_some() {
            eprintln!("[repro] {msg}");
        }
    }
}
