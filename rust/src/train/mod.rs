//! Training coordinator: per-method update rules over the AOT HLO step
//! artifacts, with a host-parallel, deterministic step pipeline and a
//! ring-buffered step engine running at pipeline depth 1, 2 or 3.
//!
//! # Step protocol: a stage graph over a ring of slots
//!
//! A sampling-method step is a graph of five stages: **gather** the 2B
//! touched parameter rows, **pack** them (plus the batch's features and
//! `lpn` corrections) into literals, **execute** the HLO artifact (Pallas
//! gradient core) on the PJRT runtime, **read back** the row gradients,
//! and **scatter** them through sparse Adagrad. Cost per step is O(B·K)
//! on the host plus the kernel, independent of C — the property that
//! makes negative sampling scale (Sec. 2.1).
//!
//! [`StepEngine`] runs that graph over a ring of in-flight step slots
//! ([`StepSlot`]: own gather/readback scratch + reusable literal
//! buffers) at a configurable depth (`RunConfig::overlap`):
//!
//! * **Depth 1** — strictly serial gather → pack → execute → readback →
//!   scatter on the calling thread (the reference protocol).
//! * **Depth 2** — double-buffered: while step *t* executes on the
//!   coordinator thread, step *t+1*'s host work — parameter gather,
//!   `lpn` literal packing, and the x-literal build — runs concurrently
//!   on the background workers ([`Pool::submit_sharded`]):
//!
//!   ```text
//!     coordinator:  …execute(t)─────────┐ readback(t) scatter(t) patch(t+1)
//!     pool workers: gather(t+1) lits(t+1)┘        (join before scatter)
//!   ```
//!
//! * **Depth 3** — a three-slot ring with a **dedicated execute thread**
//!   (spawned through the sanctioned [`crate::utils::spawn_named`] path):
//!   executes run back-to-back on their own thread, the coordinator
//!   drains readback → conflict-scatter for step *t*, and the pool runs
//!   the *remainder* of *t*'s scatter concurrently with step *t+2*'s
//!   eager gather and batch-literal build — in steady state the device
//!   never waits on the host:
//!
//!   ```text
//!     exec thread:  …execute(t)──────────────┐ execute(t+1)──────────────…
//!     coordinator:  wait · readback(t) patch(t+1) conflict-scatter(t) seal(t+1)
//!     pool workers: [ remainder-scatter(t) ∥ gather(t+2) ∥ lits(t+2) ]
//!   ```
//!
//!   Step *t*'s input literals are **donated** to the execute
//!   ([`StepExecutor::run_step_donated`]): the runtime hands their
//!   storage back (or, on real PJRT, aliases it into the outputs) and the
//!   slot's scratch refills it in place for step *t+3*, so steady-state
//!   execute performs zero literal allocations (pinned by a
//!   scratch-counter test over [`StepEngine::lit_allocs`]).
//!
//! **Conflict-aware row leasing** keeps every depth bit-exact: before a
//! step executes, the rows it will update are leased under a fresh
//! monotonic id ([`ParamStore::lease_rows`]); eager gathers skip every
//! row stamped at or above the oldest live lease, and the skipped slots
//! are re-read once the covering scatters land ([`ParamStore::patch_leased`]
//! at depth 2, the two-phase [`ParamStore::patch_leased_range`] /
//! [`ParamStore::patch_slots`] pair at depth 3). At depth 3 two leases
//! are live at once, so a scatter is split *by row*: updates to rows the
//! next step reads (re-stamped by its lease) apply serially before its
//! literals seal ([`ParamStore::apply_sparse_stamped`]), and the
//! remainder applies on the pool concurrently with the next execute
//! ([`crate::model::ParamStageViews::scatter_shard`]). Each row still
//! sees its updates in exact serial order, so losses and parameters are
//! bit-identical across depth {1,2,3} × any worker count
//! (`tests/overlap_parity.rs`). The dense softmax baseline always runs
//! the serial protocol: its "gather" is the whole parameter matrix, so
//! every row conflicts.
//!
//! Step-input literals recycle through a per-slot
//! [`crate::runtime::LitScratch`]: after execute(t), t's input literals
//! retire (or are donated back) into the slot's scratch and a later step
//! refills them in place — steady-state literal creation allocates
//! nothing at every depth.
//!
//! # Performance architecture: pipeline, sharding, determinism
//!
//! Every host-side stage of a step is parallel, and every stage is
//! **bit-deterministic** — the same seed produces the same learning curve
//! at every `parallelism` setting:
//!
//! * **Batch pipeline** — negative generation (the O(k log C) tree
//!   descents) depends only on the features, never on the evolving
//!   parameters, so M workers assemble batches ahead of the coordinator.
//!   The batch stream is a pure function of (seed, batch sequence number):
//!   worker m produces batches `t ≡ m (mod M)` from per-batch RNG streams
//!   (see [`batcher`]), and the coordinator consumes the per-worker
//!   channels round-robin, so the stream is bit-identical to the inline
//!   path for every M. `RawBatch` buffers cycle back to their worker
//!   through a return channel — steady-state assembly is allocation-free.
//!   Within each worker, descents run through the SIMD-width
//!   [`crate::tree::TreeKernel`] (8 lanes per inner loop, canonical
//!   reduction order), bit-identical to the scalar walkers.
//! * **Sharded gather/scatter** — [`ParamStore::gather_par`] and
//!   [`ParamStore::apply_sparse_par`] shard rows by `label % num_shards`,
//!   so all updates to one row happen on one worker in batch order:
//!   duplicate-label Adagrad semantics stay exactly sequential-per-row and
//!   the result is bit-identical to the serial scatter. The softmax
//!   baseline's dense scatter shards contiguous row spans the same way
//!   ([`ParamStore::apply_dense_par`]).
//! * **Parallel eval sweep** — the Eq. 5 correction cache
//!   ([`LpnCache::build_with`]) shards its O(N·C·k) per-example sweep over
//!   the pool (bit-identical: one writer per row). The pure-rust reference
//!   evaluator has a pool variant too
//!   ([`crate::eval::evaluate_reference_with`], used by tests/benches; its
//!   f64 reduction order varies with worker count, so it stays out of the
//!   bit-deterministic training path).
//! * **Parallel aux-model fit** — the one-off cost the paper counts in
//!   its training-time claim is sharded too: PCA mean/covariance
//!   accumulate per fixed row-slab and reduce in slab order
//!   ([`crate::linalg::Pca::fit_with`]), and the tree fits level by level
//!   with the whole frontier of one depth running concurrently under
//!   per-node RNG streams ([`crate::tree::fit::fit_tree_with`]) — both
//!   bit-identical at every worker count.
//! * **Shutdown** — pipeline teardown closes both channel directions
//!   before joining, so a worker blocked on a full batch channel (or
//!   polling the buffer-return channel) observes disconnection and exits;
//!   there is no drain-then-join race and no stop flag.
//!
//! At depth ≤ 2, PJRT execution stays on the coordinator thread; depth 3
//! moves it to the dedicated execute thread — executors are `Sync` (the
//! [`StepExecutor`] supertrait), the vendored runtime's handles are plain
//! `Send + Sync` data, and the real PJRT client is thread-safe. At every
//! depth the batch pipeline overlaps batch generation with the execute,
//! and the pool parallelizes the remaining host stages around it.
//!
//! Per-stage wall time accumulates into [`StageTimes`] through the
//! sanctioned [`StopWatch`] clock: gather / pack / execute / readback /
//! scatter buckets plus an execute-occupancy ratio, surfaced by
//! `repro train --timing` and the hot-path bench's `step_pipeline`
//! section. Buckets are attributed by what the coordinator waits on, so
//! background work concurrent with an execute lands in the bucket whose
//! join exposed it (at depth 3 the remainder-scatter ∥ gather stage banks
//! under `scatter`).

pub mod batcher;
pub mod curve;

pub use batcher::{BatchGen, BatchMode, RawBatch, SamplerKind};
pub use curve::{CurvePoint, LearningCurve};

use crate::config::{Method, OverlapMode, RunConfig};
use crate::data::{Dataset, Splits};
use crate::eval::{EvalResult, Evaluator, LpnCache};
use crate::model::ParamStore;
use crate::runtime::{read_f32, read_f32_into, Executable, LitScratch, Registry};
use crate::sampler::{AdversarialSampler, FrequencySampler, UniformSampler};
use crate::utils::{Pool, Rng, SharedMut, StopWatch};
use anyhow::{Context, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Buffers in flight per pipeline worker (its private recycle pool).
const PIPELINE_DEPTH_PER_WORKER: usize = 2;
/// Cap on pipeline workers: batch assembly saturates well before the
/// coordinator-side stages, and idle workers only cost memory.
const PIPELINE_MAX_WORKERS: usize = 8;

/// Where batches come from: the inline generator or the worker pipeline.
/// Callers must return each batch via [`BatchSource::recycle`] so buffers
/// keep cycling instead of being reallocated.
pub struct BatchSource {
    inner: SourceInner,
}

enum SourceInner {
    Inline {
        gen: BatchGen,
        spare: Vec<RawBatch>,
    },
    Pipelined(Pipeline),
}

/// M workers, each with a bounded batch channel and a buffer-return
/// channel. Worker m owns batches `t ≡ m (mod M)`; the coordinator reads
/// the channels round-robin, which restores the global order.
struct Pipeline {
    batch_rx: Vec<Receiver<RawBatch>>,
    buf_tx: Vec<SyncSender<RawBatch>>,
    handles: Vec<JoinHandle<()>>,
    /// Worker whose batch is next in sequence order.
    next_worker: usize,
    /// Worker that produced the oldest outstanding batch (recycle target).
    recycle_worker: usize,
}

impl BatchSource {
    /// Single-thread source (batch assembled on the calling thread).
    pub fn inline(gen: BatchGen) -> Self {
        BatchSource { inner: SourceInner::Inline { gen, spare: Vec::new() } }
    }

    /// Spawn `workers` pipeline workers over `gen`'s batch stream.
    pub fn pipelined(gen: &BatchGen, workers: usize) -> Self {
        let m = workers.max(1);
        let mut batch_rx = Vec::with_capacity(m);
        let mut buf_tx = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for w in 0..m {
            let (btx, brx) = sync_channel::<RawBatch>(PIPELINE_DEPTH_PER_WORKER);
            let (rtx, rrx) = sync_channel::<RawBatch>(PIPELINE_DEPTH_PER_WORKER);
            let mut wgen = gen.worker(w as u64, m as u64);
            let handle = crate::utils::spawn_named(&format!("batch-gen-{w}"), move || {
                use std::sync::mpsc::TryRecvError;
                let (b, k) = (wgen.batch_size(), wgen.feat_dim());
                loop {
                    // Prefer a recycled buffer; fall back to a fresh
                    // allocation so a caller that drops batches instead
                    // of recycling degrades to per-batch allocation
                    // (bounded by the batch channel's backpressure)
                    // rather than deadlocking the pipeline.
                    let mut buf = match rrx.try_recv() {
                        Ok(buf) => buf,
                        Err(TryRecvError::Empty) => RawBatch::alloc(b, k),
                        Err(TryRecvError::Disconnected) => break,
                    };
                    wgen.fill_next(&mut buf);
                    // errors once the coordinator closes its end
                    if btx.send(buf).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn batch generator");
            batch_rx.push(brx);
            buf_tx.push(rtx);
            handles.push(handle);
        }
        BatchSource {
            inner: SourceInner::Pipelined(Pipeline {
                batch_rx,
                buf_tx,
                handles,
                next_worker: 0,
                recycle_worker: 0,
            }),
        }
    }

    /// Next batch of the deterministic stream.
    pub fn next(&mut self) -> RawBatch {
        match &mut self.inner {
            SourceInner::Inline { gen, spare } => {
                let mut buf = spare
                    .pop()
                    .unwrap_or_else(|| RawBatch::alloc(gen.batch_size(), gen.feat_dim()));
                gen.fill_next(&mut buf);
                buf
            }
            SourceInner::Pipelined(p) => {
                let buf = p.batch_rx[p.next_worker]
                    .recv()
                    .expect("batch generator thread died");
                p.next_worker = (p.next_worker + 1) % p.batch_rx.len();
                buf
            }
        }
    }

    /// Return a consumed batch's buffers for reuse. Recycling in the order
    /// batches were taken (the training loop's natural behavior) routes
    /// each buffer back to the worker that produced it; skipped or
    /// out-of-order recycling is safe — workers allocate fresh buffers
    /// when their return queue is empty, and `try_send` drops the buffer
    /// when it is full.
    pub fn recycle(&mut self, batch: RawBatch) {
        match &mut self.inner {
            SourceInner::Inline { spare, .. } => spare.push(batch),
            SourceInner::Pipelined(p) => {
                let _ = p.buf_tx[p.recycle_worker].try_send(batch);
                p.recycle_worker = (p.recycle_worker + 1) % p.buf_tx.len();
            }
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Close both directions first: a worker blocked sending a finished
        // batch, or waiting for a recycled buffer, sees the disconnect and
        // exits. Only then join. (The previous design drained the batch
        // channel once and could re-fill before the worker checked its
        // stop flag — a deadlock on join.)
        self.batch_rx.clear();
        self.buf_tx.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The device half of a step: anything that can execute a prepared input
/// set and return the output tuple (loss + gradients) in manifest order.
/// [`Executable`] is the production implementation; tests and benches
/// drive the engine with deterministic host mocks (the vendored `xla`
/// stub cannot execute HLO).
///
/// Executors must be `Sync`: at pipeline depth 3 the engine calls them
/// from its dedicated execute thread while the coordinator still holds
/// the same reference.
pub trait StepExecutor: Sync {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>>;

    /// Donation-aware execute: takes the inputs by value so the runtime
    /// can alias their storage into the outputs, and returns
    /// `(outputs, donated)` where `donated` are input literals handed
    /// back for host-side refill ([`LitScratch::donate`]). The default
    /// recycles every input after a borrowed [`StepExecutor::run_step`],
    /// so host mocks get donation for free. On an error the inputs are
    /// consumed — the failure path refills from fresh allocations.
    fn run_step_donated(
        &self,
        inputs: Vec<xla::Literal>,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
        let outs = self.run_step(&inputs)?;
        Ok((outs, inputs))
    }
}

impl StepExecutor for Executable {
    fn run_step(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run(inputs)
    }

    fn run_step_donated(
        &self,
        inputs: Vec<xla::Literal>,
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
        self.run_donated(inputs)
    }
}

/// One execute queued to the dedicated thread: a lifetime-erased pointer
/// to the caller's executor plus the sealed input literals.
struct ExecReq {
    exec: ExecPtr,
    inputs: Vec<xla::Literal>,
}

/// `(outputs, donated-back inputs)` or the execute error.
type ExecResp = Result<(Vec<xla::Literal>, Vec<xla::Literal>)>;

/// Lifetime-erased executor pointer shipped to the execute thread.
struct ExecPtr(*const (dyn StepExecutor + 'static));

// SAFETY: the pointee is `Sync` (a `StepExecutor` supertrait), so calling
// it from the execute thread while the coordinator holds shared
// references is sound. The erased lifetime is upheld by the engine:
// every queued request is resolved — received or drained by
// [`ExecTicket`]'s drop — before `step()` returns, and the caller's
// executor borrow outlives that call.
unsafe impl Send for ExecPtr {}

/// The dedicated execute thread (pipeline depth 3): executes run
/// back-to-back here while the coordinator drains the previous step and
/// the pool prepares the next one. Spawned through the sanctioned
/// [`crate::utils::spawn_named`] path; at most one request is in flight
/// at a time (the ring has a single sealed slot).
struct ExecThread {
    /// `None` only during drop (taking it disconnects the thread's recv).
    req_tx: Option<SyncSender<ExecReq>>,
    resp_rx: Receiver<ExecResp>,
    handle: Option<JoinHandle<()>>,
}

impl ExecThread {
    fn spawn() -> Result<Self> {
        let (req_tx, req_rx) = sync_channel::<ExecReq>(1);
        let (resp_tx, resp_rx) = sync_channel::<ExecResp>(1);
        let handle = crate::utils::spawn_named("step-exec", move || {
            while let Ok(req) = req_rx.recv() {
                // SAFETY: the coordinator keeps the executor borrow alive
                // until this request's response is consumed (the
                // `ExecTicket` contract), so the erased pointer is valid
                // for the whole call.
                let exec = unsafe { &*req.exec.0 };
                let resp = exec.run_step_donated(req.inputs);
                if resp_tx.send(resp).is_err() {
                    break; // engine dropped; exit
                }
            }
        })
        .context("spawn execute thread")?;
        Ok(Self { req_tx: Some(req_tx), resp_rx, handle: Some(handle) })
    }

    /// Queue one execute. The returned ticket must be resolved (received
    /// or dropped) before `exec`'s borrow ends — the engine resolves it
    /// before `step()` returns on every path, including unwinds.
    fn submit<'t>(&'t self, exec: &dyn StepExecutor, inputs: Vec<xla::Literal>) -> ExecTicket<'t> {
        let trait_obj: &dyn StepExecutor = exec;
        // SAFETY (lifetime erasure): see `ExecPtr` — the ticket is
        // resolved before the borrow ends.
        let ptr = ExecPtr(unsafe {
            std::mem::transmute::<&dyn StepExecutor, &'static dyn StepExecutor>(trait_obj)
        });
        self.req_tx
            .as_ref()
            .expect("execute thread channel open")
            .send(ExecReq { exec: ptr, inputs })
            .expect("execute thread died");
        ExecTicket { rx: &self.resp_rx, received: false }
    }
}

impl Drop for ExecThread {
    fn drop(&mut self) {
        // Disconnect the request channel so the thread's recv errors out,
        // then join. No request can be in flight here (ticket contract).
        self.req_tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Receipt for an in-flight execute. Dropping an unresolved ticket waits
/// out the response and discards it (the failure/unwind paths), so the
/// executor borrow and the donated literals are never touched after the
/// coordinator abandons the step — the same drop-waits discipline as the
/// pool's `StageHandle`.
struct ExecTicket<'t> {
    rx: &'t Receiver<ExecResp>,
    received: bool,
}

impl ExecTicket<'_> {
    /// Block until the queued execute's response arrives.
    fn recv(mut self) -> ExecResp {
        self.received = true;
        self.rx.recv().expect("execute thread died")
    }
}

impl Drop for ExecTicket<'_> {
    fn drop(&mut self) {
        if !self.received {
            let _ = self.rx.recv();
        }
    }
}

/// Executable input positions shared by the NS-like and pairwise layouts:
/// `[x, wp, bp, wn, bn, …tail…]` where the tail is `[lpn_p, lpn_n, lam]`
/// (NS/NCE) or `[scale, lam]` (OVE/A&R). Softmax uses
/// `[x, w, b, y, lam]`, assembled inline in the serial path.
const IN_X: usize = 0;
const IN_WP: usize = 1;
const IN_BP: usize = 2;
const IN_WN: usize = 3;
const IN_BN: usize = 4;

/// Executable input count for a batch mode.
fn num_inputs(mode: BatchMode) -> usize {
    match mode {
        BatchMode::NsLike => 8,   // x, wp, bp, wn, bn, lpn_p, lpn_n, lam
        BatchMode::Pairwise => 7, // x, wp, bp, wn, bn, scale, lam
        BatchMode::Softmax => 5,  // x, w, b, y, lam
    }
}

/// A slot's executable-input literal set plus its recycling scratch: the
/// single home of the seal / take / retire plumbing shared by every
/// protocol depth (serial recycling, depth-2 retirement after the
/// coordinator-side execute, depth-3 donation through the execute
/// thread).
struct SlotLits {
    /// Inputs by position, sealed in two stages: batch-derived literals
    /// first, parameter-row literals after the gather is final.
    slots: Vec<Option<xla::Literal>>,
    /// Recycler for retired step-input literals (allocation-free refills).
    scratch: LitScratch,
}

impl SlotLits {
    fn new(n_inputs: usize) -> Self {
        Self { slots: (0..n_inputs).map(|_| None).collect(), scratch: LitScratch::new() }
    }

    /// Seal input `pos` from an f32 host slice (a scratch refill — no
    /// allocation once the scratch is warm).
    fn set_f32(&mut self, pos: usize, data: &[f32], dims: &[usize]) -> Result<()> {
        self.slots[pos] = Some(self.scratch.lit_f32(data, dims)?);
        Ok(())
    }

    /// Seal input `pos` from an i32 host slice.
    fn set_i32(&mut self, pos: usize, data: &[i32], dims: &[usize]) -> Result<()> {
        self.slots[pos] = Some(self.scratch.lit_i32(data, dims)?);
        Ok(())
    }

    /// Move the sealed literals out for the execute call.
    fn take_sealed(&mut self) -> Vec<xla::Literal> {
        self.slots
            .iter_mut()
            .map(|s| s.take().expect("slot literals sealed before execute"))
            .collect()
    }

    /// Retire one executed input literal for reuse.
    fn recycle(&mut self, lit: xla::Literal) {
        self.scratch.recycle(lit);
    }

    /// Bulk-retire a donated input set ([`StepExecutor::run_step_donated`]).
    fn donate(&mut self, lits: Vec<xla::Literal>) {
        self.scratch.donate(lits);
    }

    /// Retire any still-sealed literals (invalidation / failure paths).
    fn recycle_all(&mut self) {
        for s in self.slots.iter_mut() {
            if let Some(lit) = s.take() {
                self.scratch.recycle(lit);
            }
        }
    }

    /// Fresh literal allocations this slot has performed so far.
    fn created_count(&self) -> u64 {
        self.scratch.created_count()
    }
}

/// One in-flight step slot of the ring: the step being executed, the step
/// being drained, and the step being prepared each own a full set of
/// gather/readback scratch and literal buffers, so the stages of
/// consecutive steps never contend (module docs).
struct StepSlot {
    /// The slot's assembled batch (present from fetch until the step's
    /// scatter has landed and the buffers return to the pipeline).
    batch: Option<RawBatch>,
    /// Executable-input literals plus their recycling scratch.
    lits: SlotLits,
    /// Error raised by the background literal build (single-writer cell;
    /// surfaced on the coordinator at the join point).
    lit_err: Option<anyhow::Error>,
    /// Gather buffers for the positive/negative rows; after execute they
    /// double as the gradient readback buffers (and, at depth 3, hold the
    /// gradients until the remainder scatter lands one call later).
    wp: Vec<f32>,
    bp: Vec<f32>,
    wn: Vec<f32>,
    bn: Vec<f32>,
    /// Gather + literals reflect the current parameters and the slot can
    /// be executed as-is.
    prepared: bool,
}

impl StepSlot {
    /// `with_gather` sizes the row scratch: false for slots that never
    /// gather (softmax — the dense path reads the whole matrix — and the
    /// ring slots a shallower protocol never prepares).
    fn new(batch_size: usize, feat_dim: usize, n_inputs: usize, with_gather: bool) -> Self {
        let (wlen, blen) = if with_gather {
            (batch_size * feat_dim, batch_size)
        } else {
            (0, 0)
        };
        Self {
            batch: None,
            lits: SlotLits::new(n_inputs),
            lit_err: None,
            wp: vec![0f32; wlen],
            bp: vec![0f32; blen],
            wn: vec![0f32; wlen],
            bn: vec![0f32; blen],
            prepared: false,
        }
    }

    /// Retire any sealed literals back into the slot's scratch.
    fn recycle_lits(&mut self) {
        self.lits.recycle_all();
    }

    /// Seal the parameter-row literals from the (final) gather buffers.
    fn seal_param_lits(&mut self, b: usize, k: usize) -> Result<()> {
        self.lits.set_f32(IN_WP, &self.wp, &[b, k])?;
        self.lits.set_f32(IN_BP, &self.bp, &[b])?;
        self.lits.set_f32(IN_WN, &self.wn, &[b, k])?;
        self.lits.set_f32(IN_BN, &self.bn, &[b])?;
        Ok(())
    }
}

/// Disjoint mutable references to two ring slots.
fn slot_pair_mut(slots: &mut [StepSlot; 3], a: usize, b: usize) -> (&mut StepSlot, &mut StepSlot) {
    assert_ne!(a, b, "slot pair must be disjoint");
    if a < b {
        let (lo, hi) = slots.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = slots.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// Build the batch-derived inputs (x, lpn/scale, lam) for a slot. The
/// parameter-row literals are sealed separately, after the gathered rows
/// are final ([`StepSlot::seal_param_lits`]). Runs either inline (serial
/// protocol) or on stage shard 0 of the background stage.
fn build_batch_lits(
    lits: &mut SlotLits,
    batch: &RawBatch,
    mode: BatchMode,
    b: usize,
    k: usize,
    lam: f32,
) -> Result<()> {
    lits.set_f32(IN_X, &batch.x, &[b, k])?;
    match mode {
        BatchMode::NsLike => {
            lits.set_f32(5, &batch.lpn_p, &[b])?;
            lits.set_f32(6, &batch.lpn_n, &[b])?;
            lits.set_f32(7, &[lam], &[1])?;
        }
        BatchMode::Pairwise => {
            lits.set_f32(5, &batch.lpn_n, &[b])?;
            lits.set_f32(6, &[lam], &[1])?;
        }
        BatchMode::Softmax => unreachable!("softmax inputs are assembled inline"),
    }
    Ok(())
}

/// Cumulative coordinator wall time per pipeline stage, measured with the
/// sanctioned [`StopWatch`] clock (`repro train --timing`, hot-path
/// bench). Attribution is by what the coordinator waits on: host work
/// running concurrently with an execute lands in the bucket whose join
/// exposed it — at depth 2 background-gather overshoot banks under
/// `gather`, at depth 3 the remainder-scatter ∥ gather stage banks under
/// `scatter` and the wait for the execute thread under `execute`.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub gather_s: f64,
    pub pack_s: f64,
    pub execute_s: f64,
    pub readback_s: f64,
    pub scatter_s: f64,
    /// Steps that completed successfully under this engine.
    pub steps: u64,
}

impl StageTimes {
    /// Total timed coordinator wall clock across the five buckets.
    pub fn total_s(&self) -> f64 {
        self.gather_s + self.pack_s + self.execute_s + self.readback_s + self.scatter_s
    }

    /// Fraction of the timed wall clock spent inside (or waiting on) the
    /// execute stage — the pipeline's device-occupancy proxy: higher
    /// means the host stages hide better behind the device.
    pub fn execute_occupancy(&self) -> f64 {
        let t = self.total_s();
        if t > 0.0 {
            self.execute_s / t
        } else {
            0.0
        }
    }

    /// One-line stage report (`repro train --timing`).
    pub fn report(&self) -> String {
        format!(
            "stages over {} steps: gather {:.3}s | pack {:.3}s | execute {:.3}s | \
             readback {:.3}s | scatter {:.3}s | execute occupancy {:.1}%",
            self.steps,
            self.gather_s,
            self.pack_s,
            self.execute_s,
            self.readback_s,
            self.scatter_s,
            self.execute_occupancy() * 100.0
        )
    }
}

/// Successive-mark stage timer: each `bank` call adds the wall time since
/// the previous mark to one [`StageTimes`] bucket.
struct StageMarks {
    sw: StopWatch,
    last: f64,
}

impl StageMarks {
    fn start() -> Self {
        Self { sw: StopWatch::started(), last: 0.0 }
    }

    fn bank(&mut self, acc: &mut f64) {
        let now = self.sw.elapsed_secs();
        *acc += now - self.last;
        self.last = now;
    }
}

/// Where the three-slot ring stands between pipelined calls (depth 3).
struct RingState {
    /// Slot sealed and ready for the next execute.
    exec_idx: usize,
    /// Lease id live on the sealed slot's rows.
    exec_lease: u64,
    /// Slot whose remainder scatter is still pending: `(slot, lease)`.
    /// Its gather buffers hold the step's gradients; the rows of its
    /// batch still stamped with the lease apply on the next call's
    /// background stage.
    drain: Option<(usize, u64)>,
}

/// The ring-buffered step engine (module docs): owns the step slots and
/// runs the stage graph serially (depth 1), double-buffered (depth 2) or
/// through the three-deep execute pipeline (depth 3). Parameters, pool
/// and batch source stay with the caller so tests and benches can drive
/// the engine with mock executors.
pub struct StepEngine {
    mode: BatchMode,
    batch_size: usize,
    feat_dim: usize,
    lambda: f32,
    /// Pipeline depth: 1 serial, 2 double-buffered, 3 ring + dedicated
    /// execute thread (clamped to [1, 3] at construction).
    depth: usize,
    slots: [StepSlot; 3],
    /// Slot holding the fetched next step, if any (depth 2: fully
    /// prepared; depth 3 failure paths: batch only, unprepared).
    pending: Option<usize>,
    /// Depth-3 ring state across calls (`None` = cold start next call).
    ring: Option<RingState>,
    /// Dedicated execute thread (depth 3; spawned on first use).
    exec_thread: Option<ExecThread>,
    // deferred-slot scratch for the two-phase patch (depth 3; reused
    // across steps instead of per-step allocations)
    deferred_pos: Vec<u32>,
    deferred_neg: Vec<u32>,
    // softmax scratch: labels as i32 + dense gradient readback (reused
    // across steps instead of per-step allocations)
    y_i32: Vec<i32>,
    gw_dense: Vec<f32>,
    gb_dense: Vec<f32>,
    /// Batch slots re-gathered by the post-scatter patch (engine lifetime).
    pub rows_patched: u64,
    /// Steps that ran the depth-2 overlapped protocol.
    pub steps_overlapped: u64,
    /// Steps that ran the depth-3 pipelined protocol.
    pub steps_pipelined: u64,
    /// Per-stage coordinator wall time (all depths).
    times: StageTimes,
}

impl StepEngine {
    pub fn new(
        mode: BatchMode,
        batch_size: usize,
        feat_dim: usize,
        lambda: f32,
        depth: usize,
    ) -> Self {
        let depth = depth.clamp(1, 3);
        let n = num_inputs(mode);
        let gather0 = mode != BatchMode::Softmax;
        // ring slots beyond the protocol's reach are never prepared and
        // skip the row scratch
        let gather1 = gather0 && depth >= 2;
        let gather2 = gather0 && depth >= 3;
        Self {
            mode,
            batch_size,
            feat_dim,
            lambda,
            depth,
            slots: [
                StepSlot::new(batch_size, feat_dim, n, gather0),
                StepSlot::new(batch_size, feat_dim, n, gather1),
                StepSlot::new(batch_size, feat_dim, n, gather2),
            ],
            pending: None,
            ring: None,
            exec_thread: None,
            deferred_pos: Vec::new(),
            deferred_neg: Vec::new(),
            y_i32: Vec::new(),
            gw_dense: Vec::new(),
            gb_dense: Vec::new(),
            rows_patched: 0,
            steps_overlapped: 0,
            steps_pipelined: 0,
            times: StageTimes::default(),
        }
    }

    /// Configured pipeline depth (1, 2 or 3).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Does this engine run an overlapped protocol (depth ≥ 2)? Softmax
    /// always runs serially: its dense update conflicts with every row.
    pub fn overlap_enabled(&self) -> bool {
        self.depth >= 2 && self.mode != BatchMode::Softmax
    }

    /// Does this engine run the three-deep execute pipeline?
    pub fn pipeline_enabled(&self) -> bool {
        self.depth >= 3 && self.mode != BatchMode::Softmax
    }

    /// Per-stage coordinator wall-time breakdown.
    pub fn times(&self) -> &StageTimes {
        &self.times
    }

    /// Fresh literal allocations across all slots. Steady-state stepping
    /// refills retired/donated literals in place, so after a warmup of
    /// `depth` steps this counter must stop advancing (pinned by
    /// `tests/overlap_parity.rs`).
    pub fn lit_allocs(&self) -> u64 {
        self.slots.iter().map(|s| s.lits.created_count()).sum()
    }

    /// Drop any prefetched step state. Call after mutating the parameters
    /// outside the engine (e.g. [`StepEngine::apply_batch`] does this
    /// internally): the prefetched gather would otherwise be stale against
    /// the serial protocol. At depth 3 this first lands the previous
    /// step's pending remainder scatter serially, so the parameters are
    /// fully serial-consistent when the caller reads or edits them; the
    /// drained slot's batch buffers are dropped (there is no source here
    /// to recycle into). The prefetched batch itself is kept — it is the
    /// next batch of the deterministic stream — and is re-gathered on the
    /// next step.
    pub fn invalidate_prefetch(&mut self, params: &mut ParamStore) {
        if let Some(ring) = self.ring.take() {
            if let Some((didx, dlease)) = ring.drain {
                let d = &self.slots[didx];
                if let Some(batch) = d.batch.as_ref() {
                    params.apply_sparse_stamped(&batch.pos, &d.wp, &d.bp, dlease);
                    params.apply_sparse_stamped(&batch.neg, &d.wn, &d.bn, dlease);
                }
                self.slots[didx].batch = None;
            }
            // the sealed slot's batch is next in the stream: hand it back
            // as (unprepared) pending
            self.pending = Some(ring.exec_idx);
        }
        for slot in self.slots.iter_mut() {
            slot.prepared = false;
            slot.recycle_lits();
        }
    }

    /// Run one full step of the configured protocol; returns the mean
    /// per-example loss. Bit-identical results at every depth.
    pub fn step(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        source: &mut BatchSource,
    ) -> Result<f64> {
        if !self.overlap_enabled() {
            let batch = source.next();
            let result = self.run_serial(exec, params, pool, &batch);
            source.recycle(batch);
            return result;
        }
        if self.pipeline_enabled() {
            return self.step_pipelined(exec, params, pool, source);
        }
        self.step_overlapped(exec, params, pool, source)
    }

    /// Serial protocol on a caller-supplied batch. Invalidates any
    /// prefetched slot first (the scatter below would make it stale).
    pub fn apply_batch(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        batch: &RawBatch,
    ) -> Result<f64> {
        self.invalidate_prefetch(params);
        self.run_serial(exec, params, pool, batch)
    }

    /// gather → pack → execute → readback → scatter, all on the calling
    /// thread (pool-sharded within each stage). The reference protocol
    /// the overlapped path must match bit for bit.
    fn run_serial(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        batch: &RawBatch,
    ) -> Result<f64> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        let mut marks = StageMarks::start();
        let mean_loss = match self.mode {
            BatchMode::NsLike | BatchMode::Pairwise => {
                let mode = self.mode;
                let slot = &mut self.slots[0];
                params.gather_par(pool, &batch.pos, &mut slot.wp, &mut slot.bp);
                params.gather_par(pool, &batch.neg, &mut slot.wn, &mut slot.bn);
                marks.bank(&mut self.times.gather_s);
                build_batch_lits(&mut slot.lits, batch, mode, b, k, lam)?;
                slot.seal_param_lits(b, k)?;
                let inputs = slot.lits.take_sealed();
                marks.bank(&mut self.times.pack_s);
                let result = exec.run_step(&inputs).context(match mode {
                    BatchMode::NsLike => "ns/nce step",
                    _ => "ove step",
                });
                marks.bank(&mut self.times.execute_s);
                for lit in inputs {
                    slot.lits.recycle(lit);
                }
                let outs = result?;
                let loss = read_f32(&outs[0])?;
                // read the row gradients into the (now free) gather
                // buffers instead of allocating — perf pass iteration 3
                read_f32_into(&outs[1], &mut slot.wp)?;
                read_f32_into(&outs[2], &mut slot.bp)?;
                read_f32_into(&outs[3], &mut slot.wn)?;
                read_f32_into(&outs[4], &mut slot.bn)?;
                marks.bank(&mut self.times.readback_s);
                params.apply_sparse_par(pool, &batch.pos, &slot.wp, &slot.bp);
                params.apply_sparse_par(pool, &batch.neg, &slot.wn, &slot.bn);
                marks.bank(&mut self.times.scatter_s);
                crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64
            }
            BatchMode::Softmax => {
                let c = params.num_classes;
                // reusable i32 label + dense-gradient scratch (these were
                // per-step allocations before the engine refactor)
                self.y_i32.clear();
                self.y_i32.extend(batch.pos.iter().map(|&v| v as i32));
                self.gw_dense.resize(c * k, 0.0);
                self.gb_dense.resize(c, 0.0);
                let slot = &mut self.slots[0];
                slot.lits.set_f32(0, &batch.x, &[b, k])?;
                slot.lits.set_f32(1, &params.w, &[c, k])?;
                slot.lits.set_f32(2, &params.b, &[c])?;
                slot.lits.set_i32(3, &self.y_i32, &[b])?;
                slot.lits.set_f32(4, &[lam], &[1])?;
                let inputs = slot.lits.take_sealed();
                marks.bank(&mut self.times.pack_s);
                let result = exec.run_step(&inputs).context("softmax step");
                marks.bank(&mut self.times.execute_s);
                for lit in inputs {
                    slot.lits.recycle(lit);
                }
                let outs = result?;
                let loss = read_f32(&outs[0])?;
                read_f32_into(&outs[1], &mut self.gw_dense)?;
                read_f32_into(&outs[2], &mut self.gb_dense)?;
                marks.bank(&mut self.times.readback_s);
                params.apply_dense_par(pool, &self.gw_dense, &self.gb_dense);
                marks.bank(&mut self.times.scatter_s);
                crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64
            }
        };
        self.times.steps += 1;
        Ok(mean_loss)
    }

    /// Bring `idx`'s slot to "prepared" through the serial stages (cold
    /// start and post-invalidation re-preparation).
    fn prepare_slot(&mut self, idx: usize, params: &ParamStore, pool: &Pool) -> Result<()> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        let mode = self.mode;
        let slot = &mut self.slots[idx];
        slot.recycle_lits();
        let batch = slot.batch.as_ref().expect("prepare_slot needs a fetched batch");
        params.gather_par(pool, &batch.pos, &mut slot.wp, &mut slot.bp);
        params.gather_par(pool, &batch.neg, &mut slot.wn, &mut slot.bn);
        build_batch_lits(&mut slot.lits, batch, mode, b, k, lam)?;
        slot.seal_param_lits(b, k)?;
        slot.prepared = true;
        Ok(())
    }

    /// The overlapped protocol (module docs): execute step t while step
    /// t+1's gather + batch-literal stages run on the background workers,
    /// then scatter t and patch t+1's leased rows.
    fn step_overlapped(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        source: &mut BatchSource,
    ) -> Result<f64> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        let mode = self.mode;
        let mut marks = StageMarks::start();

        // Current step's slot: the prepared pending slot, or a cold start
        // (first step, or the step after an aborted one — residue from an
        // abort is dropped; the pipeline tolerates unreturned buffers).
        let cur_idx = match self.pending.take() {
            Some(i) => i,
            None => {
                for slot in self.slots.iter_mut() {
                    slot.batch = None;
                    slot.recycle_lits();
                    slot.prepared = false;
                }
                self.slots[0].batch = Some(source.next());
                0
            }
        };
        if !self.slots[cur_idx].prepared {
            // cold start or an external invalidation: serial preparation
            self.prepare_slot(cur_idx, params, pool)?;
        }
        let nxt_idx = 1 - cur_idx;
        {
            let nxt = &mut self.slots[nxt_idx];
            debug_assert!(nxt.batch.is_none() && !nxt.prepared);
            nxt.batch = Some(source.next());
            nxt.lit_err = None;
        }
        marks.bank(&mut self.times.gather_s);

        let (cur, nxt) = slot_pair_mut(&mut self.slots, cur_idx, nxt_idx);

        // Lease step t's update set, then launch t+1's host stages on the
        // background workers while t executes here. Nothing writes the
        // parameters until the stage is joined, so the eager gather is
        // race-free; leased (conflicting) rows are skipped and patched
        // after the scatter below.
        let cur_batch = cur.batch.as_ref().expect("prepared slot holds its batch");
        let lease = params.lease_rows(&[&cur_batch.pos, &cur_batch.neg]);
        let exec_result;
        {
            let nxt_batch: &RawBatch = nxt.batch.as_ref().unwrap();
            let wp_view = SharedMut::new(&mut nxt.wp);
            let bp_view = SharedMut::new(&mut nxt.bp);
            let wn_view = SharedMut::new(&mut nxt.wn);
            let bn_view = SharedMut::new(&mut nxt.bn);
            let lits_view = SharedMut::new(std::slice::from_mut(&mut nxt.lits));
            let err_view = SharedMut::new(std::slice::from_mut(&mut nxt.lit_err));
            let params_ref: &ParamStore = params;
            let shards = pool.stage_shards();
            let stage = pool.submit_sharded(move |shard| {
                if shard == 0 {
                    // SAFETY: stage shard 0 is the only writer of the
                    // literal set and the error cell.
                    let (lits, err) = unsafe {
                        (&mut lits_view.slice_mut(0, 1)[0], &mut err_view.slice_mut(0, 1)[0])
                    };
                    if let Err(e) = build_batch_lits(lits, nxt_batch, mode, b, k, lam) {
                        *err = Some(e);
                    }
                }
                params_ref
                    .gather_leased_shard(&nxt_batch.pos, lease, shards, shard, &wp_view, &bp_view);
                params_ref
                    .gather_leased_shard(&nxt_batch.neg, lease, shards, shard, &wn_view, &bn_view);
            });

            // Device half of step t: the coordinator blocks here — this is
            // the latency the background stage hides.
            let inputs = cur.lits.take_sealed();
            exec_result = exec.run_step(&inputs);
            marks.bank(&mut self.times.execute_s);
            stage.join();
            marks.bank(&mut self.times.gather_s);
            // retire t's inputs for reuse by step t+2 in this slot
            for lit in inputs {
                cur.lits.recycle(lit);
            }
        }
        cur.prepared = false;
        // Transient-failure contract: on an execute failure, batch t is
        // lost without a scatter — exactly as in the serial protocol,
        // which recycles the failed batch — and the prefetched batch t+1
        // is handed back as an *unprepared* pending slot, so a retrying
        // caller resumes on the serial batch stream with the serial
        // parameters (tests/overlap_parity.rs pins this). The other error
        // exits are deterministic configuration faults, not transient,
        // and don't promise cross-protocol parity: a background
        // literal-build failure also drops step t (its successful execute
        // is discarded unscattered) but still salvages t+1, and a
        // readback/seal shape mismatch below returns before t's scatter
        // and falls back to the cold-start reset on the next call.
        if let Some(e) = nxt.lit_err.take() {
            nxt.recycle_lits();
            self.pending = Some(nxt_idx);
            source.recycle(cur.batch.take().expect("current slot holds its batch"));
            return Err(e.context("background literal build"));
        }
        let outs = match exec_result {
            Ok(outs) => outs,
            Err(e) => {
                nxt.recycle_lits();
                self.pending = Some(nxt_idx);
                source.recycle(cur.batch.take().expect("current slot holds its batch"));
                return Err(e.context(match mode {
                    BatchMode::NsLike => "ns/nce step",
                    _ => "ove step",
                }));
            }
        };

        // Readback + scatter of step t (reusing t's gather buffers).
        let loss = read_f32(&outs[0])?;
        read_f32_into(&outs[1], &mut cur.wp)?;
        read_f32_into(&outs[2], &mut cur.bp)?;
        read_f32_into(&outs[3], &mut cur.wn)?;
        read_f32_into(&outs[4], &mut cur.bn)?;
        marks.bank(&mut self.times.readback_s);
        params.apply_sparse_par(pool, &cur_batch.pos, &cur.wp, &cur.bp);
        params.apply_sparse_par(pool, &cur_batch.neg, &cur.wn, &cur.bn);
        marks.bank(&mut self.times.scatter_s);
        let mean_loss = crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64;

        // Patch t+1's leased rows now that t's scatter has landed, then
        // seal its parameter literals: the slot is fully prepared.
        {
            let nxt_batch = nxt.batch.as_ref().unwrap();
            self.rows_patched +=
                params.patch_leased(&nxt_batch.pos, lease, &mut nxt.wp, &mut nxt.bp) as u64;
            self.rows_patched +=
                params.patch_leased(&nxt_batch.neg, lease, &mut nxt.wn, &mut nxt.bn) as u64;
        }
        marks.bank(&mut self.times.gather_s);
        nxt.seal_param_lits(b, k)?;
        nxt.prepared = true;
        marks.bank(&mut self.times.pack_s);
        self.steps_overlapped += 1;
        self.times.steps += 1;

        // Retire step t's batch buffers to the pipeline and hand over.
        source.recycle(cur.batch.take().expect("current slot holds its batch"));
        self.pending = Some(nxt_idx);
        Ok(mean_loss)
    }

    /// The three-deep pipelined protocol (module docs). Per call, with
    /// `t` the step whose loss this call returns:
    ///
    /// 1. queue execute(t) on the dedicated thread (sealed slot, donated
    ///    inputs);
    /// 2. fetch batch t+1 into the free slot;
    /// 3. run one background stage: remainder-scatter(t−1) ∥ eager
    ///    gather(t+1) ∥ batch-literal build(t+1), then join it;
    /// 4. recycle batch t−1 — its scatter is fully landed;
    /// 5. phase-A patch of t+1's rows (stamps in `[lease(t−1), lease(t))`
    ///    re-read; stamps ≥ lease(t) deferred);
    /// 6. receive execute(t): read back loss + gradients, donate the
    ///    inputs back to the slot's scratch;
    /// 7. lease t+1's rows, apply the conflict half of t's scatter (rows
    ///    re-stamped by the new lease) serially, phase-B patch the
    ///    deferred slots, seal t+1's parameter literals;
    /// 8. rotate the ring: t+1 becomes the sealed slot, t the drain slot.
    ///
    /// Every row still sees its updates in exact serial order (the split
    /// scatter applies each update exactly once, before any read of the
    /// row), so the protocol is bit-identical to the serial one.
    fn step_pipelined(
        &mut self,
        exec: &dyn StepExecutor,
        params: &mut ParamStore,
        pool: &Pool,
        source: &mut BatchSource,
    ) -> Result<f64> {
        let b = self.batch_size;
        let k = self.feat_dim;
        let lam = self.lambda;
        let mode = self.mode;
        let mut marks = StageMarks::start();

        // The slot about to execute: the ring's sealed slot, or a cold
        // start (first step, after a failure, or after an invalidation —
        // residue from an abort is dropped; the pipeline tolerates
        // unreturned buffers). A cold start runs the serial preparation
        // and takes the lease itself; in steady state the previous call
        // already did both.
        let (exec_idx, exec_lease, drain) = match self.ring.take() {
            Some(r) => (r.exec_idx, r.exec_lease, r.drain),
            None => {
                let idx = match self.pending.take() {
                    Some(i) => i,
                    None => {
                        for slot in self.slots.iter_mut() {
                            slot.batch = None;
                            slot.recycle_lits();
                            slot.prepared = false;
                        }
                        self.slots[0].batch = Some(source.next());
                        0
                    }
                };
                if !self.slots[idx].prepared {
                    self.prepare_slot(idx, params, pool)?;
                }
                let batch =
                    self.slots[idx].batch.as_ref().expect("prepared slot holds its batch");
                let lease = params.lease_rows(&[&batch.pos, &batch.neg]);
                (idx, lease, None)
            }
        };
        marks.bank(&mut self.times.gather_s);

        // 1. Queue execute(t): it runs on the dedicated thread from here
        // until the ticket is received in step 6.
        if self.exec_thread.is_none() {
            self.exec_thread = Some(ExecThread::spawn()?);
        }
        let ticket = {
            let inputs = {
                let eslot = &mut self.slots[exec_idx];
                debug_assert!(eslot.prepared);
                eslot.prepared = false;
                eslot.lits.take_sealed()
            };
            self.exec_thread
                .as_ref()
                .expect("execute thread spawned above")
                .submit(exec, inputs)
        };
        marks.bank(&mut self.times.pack_s);

        // 2. Fetch batch t+1 into the free slot (deterministic pick: the
        // lowest index that is neither executing nor draining).
        let gather_idx = (0..3)
            .find(|&i| i != exec_idx && Some(i) != drain.map(|(d, _)| d))
            .expect("three slots, at most two busy");
        {
            let g = &mut self.slots[gather_idx];
            debug_assert!(g.batch.is_none() && !g.prepared);
            g.batch = Some(source.next());
            g.lit_err = None;
        }
        marks.bank(&mut self.times.gather_s);

        // 3. One background stage: the remainder of step t−1's scatter
        // (rows still stamped with its lease), the eager gather of batch
        // t+1 (skipping rows stamped at or above the oldest live lease)
        // and the batch-literal build. Scatter and gather are disjoint by
        // stamp — a row is either still leased to t−1 (scattered, not
        // gathered) or free (gathered, not scattered) — so the stage is
        // race-free, and the execute thread touches only literals.
        let since = drain.map(|(_, l)| l).unwrap_or(exec_lease);
        {
            let (gslot, dslot) = match drain {
                Some((didx, _)) => {
                    let (g, d) = slot_pair_mut(&mut self.slots, gather_idx, didx);
                    (g, Some(&*d))
                }
                None => (&mut self.slots[gather_idx], None),
            };
            let g_batch: &RawBatch = gslot.batch.as_ref().unwrap();
            let wp_view = SharedMut::new(&mut gslot.wp);
            let bp_view = SharedMut::new(&mut gslot.bp);
            let wn_view = SharedMut::new(&mut gslot.wn);
            let bn_view = SharedMut::new(&mut gslot.bn);
            let lits_view = SharedMut::new(std::slice::from_mut(&mut gslot.lits));
            let err_view = SharedMut::new(std::slice::from_mut(&mut gslot.lit_err));
            let drain_ref = dslot.map(|d| {
                let batch = d.batch.as_ref().expect("drain slot holds its batch");
                (batch, &d.wp, &d.bp, &d.wn, &d.bn)
            });
            let dlease = drain.map(|(_, l)| l).unwrap_or(0);
            let views = params.stage_views();
            let shards = pool.stage_shards();
            let stage = pool.submit_sharded(move |shard| {
                if shard == 0 {
                    // SAFETY: stage shard 0 is the only writer of the
                    // literal set and the error cell.
                    let (lits, err) = unsafe {
                        (&mut lits_view.slice_mut(0, 1)[0], &mut err_view.slice_mut(0, 1)[0])
                    };
                    if let Err(e) = build_batch_lits(lits, g_batch, mode, b, k, lam) {
                        *err = Some(e);
                    }
                }
                if let Some((dbatch, gwp, gbp, gwn, gbn)) = drain_ref {
                    views.scatter_shard(&dbatch.pos, gwp, gbp, dlease, shards, shard);
                    views.scatter_shard(&dbatch.neg, gwn, gbn, dlease, shards, shard);
                }
                views.gather_shard(&g_batch.pos, since, shards, shard, &wp_view, &bp_view);
                views.gather_shard(&g_batch.neg, since, shards, shard, &wn_view, &bn_view);
            });
            stage.join();
        }
        marks.bank(&mut self.times.scatter_s);

        // 4. Batch t−1 is fully scattered: its buffers go home.
        if let Some((didx, _)) = drain {
            let batch = self.slots[didx].batch.take().expect("drain slot holds its batch");
            source.recycle(batch);
        }

        // Background literal-build failure: discard execute(t) — dropping
        // the ticket drains the response, so batch t is lost exactly as
        // under an execute failure below (its remainder-less scatter
        // never applies) — but salvage batch t+1 as unprepared pending.
        if let Some(e) = self.slots[gather_idx].lit_err.take() {
            drop(ticket);
            self.slots[gather_idx].recycle_lits();
            self.pending = Some(gather_idx);
            let eb = self.slots[exec_idx].batch.take().expect("exec slot holds its batch");
            source.recycle(eb);
            return Err(e.context("background literal build"));
        }

        // 5. Phase-A patch of batch t+1: rows whose covering scatter has
        // landed (stamped in [since, lease(t))) are re-read now; rows the
        // in-flight step t will update (stamped ≥ lease(t)) are deferred
        // to phase B.
        self.deferred_pos.clear();
        self.deferred_neg.clear();
        {
            let g = &mut self.slots[gather_idx];
            let batch = g.batch.as_ref().unwrap();
            self.rows_patched += params.patch_leased_range(
                &batch.pos,
                since,
                exec_lease,
                &mut g.wp,
                &mut g.bp,
                &mut self.deferred_pos,
            ) as u64;
            self.rows_patched += params.patch_leased_range(
                &batch.neg,
                since,
                exec_lease,
                &mut g.wn,
                &mut g.bn,
                &mut self.deferred_neg,
            ) as u64;
        }
        marks.bank(&mut self.times.gather_s);

        // 6. Receive execute(t).
        let (outs, donated) = match ticket.recv() {
            Ok(v) => v,
            Err(e) => {
                // Transient-failure contract (module docs): batch t is
                // lost — its conflict scatter never applies, while the
                // remainder scatter of t−1 landed in the stage above, so
                // the parameters hold the exact serial state through step
                // t−1. Batch t+1 is handed back as unprepared pending;
                // the next call cold-starts on the serial stream.
                self.slots[gather_idx].recycle_lits();
                self.pending = Some(gather_idx);
                let eb = self.slots[exec_idx].batch.take().expect("exec slot holds its batch");
                source.recycle(eb);
                return Err(e.context(match mode {
                    BatchMode::NsLike => "ns/nce step",
                    _ => "ove step",
                }));
            }
        };
        marks.bank(&mut self.times.execute_s);

        // Readback into the exec slot's gather buffers — they hold step
        // t's gradients from here until the remainder scatter lands on
        // the next call's stage. The donated inputs refill in place for
        // step t+3 (zero-allocation steady state).
        let loss;
        {
            let eslot = &mut self.slots[exec_idx];
            eslot.lits.donate(donated);
            loss = read_f32(&outs[0])?;
            read_f32_into(&outs[1], &mut eslot.wp)?;
            read_f32_into(&outs[2], &mut eslot.bp)?;
            read_f32_into(&outs[3], &mut eslot.wn)?;
            read_f32_into(&outs[4], &mut eslot.bn)?;
        }
        let mean_loss = crate::linalg::sum_f64(loss.iter().map(|&l| l as f64)) / b as f64;
        marks.bank(&mut self.times.readback_s);

        // 7. Lease t+1's rows — re-stamping every row the sealed step
        // reads — then apply the conflict half of t's scatter: exactly
        // the rows t+1 will read, serially, before its literals seal. The
        // rows of batch t left stamped with lease(t) are the remainder,
        // applied on the next call's stage.
        let next_lease = {
            let g = &self.slots[gather_idx];
            let batch = g.batch.as_ref().unwrap();
            params.lease_rows(&[&batch.pos, &batch.neg])
        };
        {
            let e = &self.slots[exec_idx];
            let batch = e.batch.as_ref().expect("exec slot holds its batch");
            params.apply_sparse_stamped(&batch.pos, &e.wp, &e.bp, next_lease);
            params.apply_sparse_stamped(&batch.neg, &e.wn, &e.bn, next_lease);
        }
        marks.bank(&mut self.times.scatter_s);

        // Phase-B patch: the deferred rows are final for this step now
        // that the conflict scatter has landed; re-read them and seal.
        {
            let g = &mut self.slots[gather_idx];
            let batch = g.batch.as_ref().unwrap();
            params.patch_slots(&batch.pos, &self.deferred_pos, &mut g.wp, &mut g.bp);
            params.patch_slots(&batch.neg, &self.deferred_neg, &mut g.wn, &mut g.bn);
        }
        self.rows_patched += (self.deferred_pos.len() + self.deferred_neg.len()) as u64;
        marks.bank(&mut self.times.gather_s);
        {
            let g = &mut self.slots[gather_idx];
            g.seal_param_lits(b, k)?;
            g.prepared = true;
        }
        marks.bank(&mut self.times.pack_s);

        // 8. Rotate the ring: t+1 executes next, t drains next call.
        self.steps_pipelined += 1;
        self.times.steps += 1;
        self.ring = Some(RingState {
            exec_idx: gather_idx,
            exec_lease: next_lease,
            drain: Some((exec_idx, exec_lease)),
        });
        Ok(mean_loss)
    }
}

/// A prepared training run: data, sampler, parameters, compiled step.
pub struct TrainRun {
    pub cfg: RunConfig,
    data: Arc<Dataset>,
    eval_set: Dataset,
    pub params: ParamStore,
    step_exec: Arc<Executable>,
    evaluator: Evaluator,
    /// Fitted auxiliary model (Some for methods that need the tree).
    pub aux: Option<Arc<AdversarialSampler>>,
    pub aux_fit_seconds: f64,
    /// Worker pool for the sharded host stages (gather/scatter/eval).
    pool: Pool,
    source: BatchSource,
    /// The double-buffered (or serial) stage graph over the step slots.
    engine: StepEngine,
    step: usize,
    /// Eq. 5 correction cache for the fixed eval subset (built lazily on
    /// the first corrected evaluation; exact because the tree is frozen).
    lpn_cache: Option<LpnCache>,
}

impl TrainRun {
    /// Build everything needed to train `cfg.method` on `splits`.
    pub fn prepare(registry: &Registry, splits: &Splits, cfg: &RunConfig) -> Result<Self> {
        let shapes = &registry.manifest.shapes;
        anyhow::ensure!(
            cfg.batch_size == shapes.train_b,
            "batch_size {} must match AOT train_b {}",
            cfg.batch_size,
            shapes.train_b
        );
        anyhow::ensure!(
            splits.train.feat_dim == shapes.feat_k,
            "feat_dim {} must match AOT feat_k {}",
            splits.train.feat_dim,
            shapes.feat_k
        );
        if cfg.method == Method::Softmax {
            anyhow::ensure!(
                splits.train.num_classes == shapes.softmax_c,
                "softmax method requires C == AOT softmax_c ({} vs {})",
                splits.train.num_classes,
                shapes.softmax_c
            );
        }

        let data = Arc::new(splits.train.clone());
        let c = data.num_classes;
        let mut rng = Rng::new(cfg.seed);
        let pool = Pool::from_parallelism(cfg.parallelism);

        // --- auxiliary model (Sec. 3) ---
        let (aux, aux_fit_seconds) = if cfg.method.needs_tree() {
            let t0 = StopWatch::started();
            let (adv, stats) = AdversarialSampler::fit_with(&data, &cfg.tree, cfg.seed, &pool);
            let dt = t0.elapsed_secs();
            let slowest_level = stats.level_seconds.iter().cloned().fold(0.0, f64::max);
            log::info(&format!(
                "aux tree fitted: {} nodes, {:.1}s ({} levels over {} workers, \
                 slowest level {:.2}s), train loglik {:.3}",
                stats.nodes_fitted,
                dt,
                stats.level_seconds.len(),
                pool.num_workers(),
                slowest_level,
                stats.train_mean_loglik
            ));
            (Some(Arc::new(adv)), dt)
        } else {
            (None, 0.0)
        };

        // --- sampler + batch mode ---
        let mode = BatchMode::of(cfg.method);
        let sampler = match cfg.method {
            Method::Adversarial | Method::Nce => {
                let adv = aux.clone().unwrap();
                let x_proj =
                    Arc::new(adv.pca.project_all_with(&data.features, data.len(), &pool));
                SamplerKind::Adversarial { sampler: adv, x_proj }
            }
            Method::Frequency => {
                SamplerKind::Frequency(FrequencySampler::from_dataset(&data, 1.0)?)
            }
            _ => SamplerKind::Uniform(UniformSampler::new(c)),
        };
        let scale = match cfg.method {
            Method::AugmentReduce => {
                (c as f32 - 1.0) / cfg.hyper.num_negatives.max(1) as f32
            }
            _ => 1.0,
        };
        let gen = BatchGen::new(
            data.clone(),
            sampler,
            mode,
            cfg.batch_size,
            scale,
            rng.split(1),
        );
        // Pipelining overlaps batch generation with PJRT execution; on a
        // single hardware thread there is nothing to overlap with and the
        // channels only add overhead, so fall back to inline generation.
        let multi_core = std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false);
        let source = if cfg.pipelined && multi_core && mode != BatchMode::Softmax {
            let workers = pool.num_workers().min(PIPELINE_MAX_WORKERS);
            BatchSource::pipelined(&gen, workers)
        } else {
            BatchSource::inline(gen)
        };

        // --- compiled step ---
        let exec_name = match cfg.method {
            Method::Adversarial | Method::Uniform | Method::Frequency => "ns_grad_",
            Method::Nce => "nce_grad_",
            Method::AugmentReduce | Method::OneVsEach => "ove_grad_",
            Method::Softmax => "softmax_grad_",
        };
        let step_exec = registry.get_by_prefix(exec_name)?;

        let eval_set = splits.test.subsample(cfg.eval_points, &mut rng.split(2));
        let b = cfg.batch_size;
        let k = data.feat_dim;
        // Overlap needs at least one background worker to hide the stage
        // behind the execute; on a serial pool (or single hardware thread)
        // the protocol degrades to inline calls, so auto drops to depth 1.
        // Depth 3 (the dedicated execute thread) is opt-in via
        // `--overlap pipeline` / `REPRO_OVERLAP=pipeline`.
        let depth = match cfg.overlap {
            OverlapMode::Pipeline => 3,
            OverlapMode::On => 2,
            OverlapMode::Off => 1,
            OverlapMode::Auto => {
                if multi_core && pool.num_workers() > 1 {
                    2
                } else {
                    1
                }
            }
        };
        let engine = StepEngine::new(mode, b, k, cfg.hyper.lambda, depth);
        Ok(Self {
            cfg: cfg.clone(),
            params: ParamStore::zeros(c, k, cfg.hyper.lr),
            data,
            eval_set,
            step_exec,
            evaluator: Evaluator::new(registry)?,
            aux,
            aux_fit_seconds,
            pool,
            source,
            engine,
            step: 0,
            lpn_cache: None,
        })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// Run one training step; returns the mean per-example loss. With
    /// overlap enabled this also advances the prefetched next step (see
    /// [`StepEngine`]); results are bit-identical either way.
    pub fn step_once(&mut self) -> Result<f64> {
        let loss = self.engine.step(
            self.step_exec.as_ref(),
            &mut self.params,
            &self.pool,
            &mut self.source,
        )?;
        self.step += 1;
        Ok(loss)
    }

    /// Execute + scatter one assembled batch through the strictly serial
    /// protocol (public for benches). Any prefetched overlapped step is
    /// invalidated first and transparently re-gathered on the next
    /// [`TrainRun::step_once`] — the caller's batch is applied with exact
    /// serial semantics, and the engine's own batch stream resumes where
    /// it left off (note the stream runs one batch ahead under overlap,
    /// so interleaving external batches reorders *between* the two
    /// streams, never within either).
    pub fn apply_batch(&mut self, batch: &RawBatch) -> Result<f64> {
        self.engine.apply_batch(
            self.step_exec.as_ref(),
            &mut self.params,
            &self.pool,
            batch,
        )
    }

    /// Engine introspection (overlap + patch counters; tests/benches).
    pub fn engine(&self) -> &StepEngine {
        &self.engine
    }

    /// Drop prefetched step state after mutating [`TrainRun::params`]
    /// directly (the engine re-gathers on the next step). Without this, an
    /// external parameter edit between overlapped steps would train the
    /// next step on pre-edit rows.
    pub fn invalidate_prefetch(&mut self) {
        self.engine.invalidate_prefetch(&mut self.params);
    }

    /// Immutable serving snapshot of the current parameters plus the
    /// frozen auxiliary model — classifier rows only, no Adagrad state —
    /// for the serve/predict pipeline (`repro train --save-model`).
    pub fn serving_model(&self) -> crate::serve::ServingModel {
        crate::serve::ServingModel::from_parts(
            &self.params,
            self.aux.as_deref(),
            self.cfg.method.corrects_bias(),
        )
    }

    /// Evaluate current parameters on the held-out eval subset, applying
    /// the Eq. 5 bias correction iff the method calls for it.
    pub fn evaluate_now(&mut self) -> Result<EvalResult> {
        self.evaluate_with(self.cfg.method.corrects_bias())
    }

    /// Evaluate with the Eq. 5 correction explicitly on/off (ablation A1).
    /// Requesting correction without a fitted tree evaluates uncorrected.
    pub fn evaluate_with(&mut self, bias_correction: bool) -> Result<EvalResult> {
        let cache = if bias_correction {
            match (&mut self.lpn_cache, &self.aux) {
                (slot @ None, Some(adv)) => {
                    *slot = Some(LpnCache::build_with(adv, &self.eval_set, &self.pool));
                    slot.as_ref()
                }
                (slot, _) => slot.as_ref(),
            }
        } else {
            None
        };
        self.evaluator
            .evaluate_cached_with(&self.params, &self.eval_set, cache, &self.pool)
    }

    /// Full training loop with the learning-curve protocol of Figure 1:
    /// train wallclock excludes evaluation, aux fit time preloads the
    /// clock, eval checkpoints are log-spaced (or every `eval_every`).
    pub fn train(&mut self) -> Result<LearningCurve> {
        let mut curve = LearningCurve::new(self.cfg.dataset, self.cfg.method, self.aux_fit_seconds);
        let mut watch = StopWatch::new();
        watch.preload(std::time::Duration::from_secs_f64(self.aux_fit_seconds));
        let mut next_eval = curve::next_eval_step(0, self.cfg.eval_every);
        let mut loss_sum = 0f64;
        let mut loss_n = 0usize;

        watch.resume();
        loop {
            let loss = self.step_once()?;
            loss_sum += loss;
            loss_n += 1;

            let done = self.step >= self.cfg.max_steps
                || watch.elapsed_secs() >= self.cfg.max_seconds + self.aux_fit_seconds;
            if self.step >= next_eval || done {
                watch.pause();
                let r = self.evaluate_now()?;
                curve.points.push(CurvePoint {
                    step: self.step,
                    wall_s: watch.elapsed_secs(),
                    train_loss: loss_sum / loss_n.max(1) as f64,
                    log_likelihood: r.log_likelihood,
                    accuracy: r.accuracy,
                });
                loss_sum = 0.0;
                loss_n = 0;
                next_eval = curve::next_eval_step(self.step, self.cfg.eval_every);
                watch.resume();
            }
            if done {
                break;
            }
        }
        Ok(curve)
    }
}

/// Minimal logging shim (keeps the library free of logger dependencies;
/// the CLI prints, tests stay quiet unless `REPRO_VERBOSE` is set).
mod log {
    pub fn info(msg: &str) {
        if std::env::var_os("REPRO_VERBOSE").is_some() {
            eprintln!("[repro] {msg}");
        }
    }
}
