//! Batch assembly: positives from the (shuffled) training stream, negatives
//! from the configured noise distribution.
//!
//! Negative generation is the paper's O(k log C) hot loop (tree descents),
//! and it depends only on features — never on the evolving parameters — so
//! the [`super::pipeline`] module can run it on a worker thread fully
//! overlapped with PJRT execution and the Adagrad scatter.

use crate::config::Method;
use crate::data::Dataset;
use crate::sampler::{AdversarialSampler, FrequencySampler, NoiseSampler, UniformSampler};
use crate::utils::Rng;
use std::sync::Arc;

/// One assembled raw batch (parameter rows are gathered later, on the
/// thread that owns the parameters).
#[derive(Clone, Debug)]
pub struct RawBatch {
    /// Features, [B, K] row-major.
    pub x: Vec<f32>,
    /// Positive labels, [B].
    pub pos: Vec<u32>,
    /// Negative labels, [B] (unused for softmax).
    pub neg: Vec<u32>,
    /// log p_n(y|x) for positives (NS/NCE) — zeros for pairwise/softmax.
    pub lpn_p: Vec<f32>,
    /// log p_n(y'|x) for negatives (NS/NCE) or the importance weight
    /// `scale` (OVE/A&R).
    pub lpn_n: Vec<f32>,
}

/// Which operand layout the method's HLO step consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    /// x, wp, bp, wn, bn, lpn_p, lpn_n (ns_grad / nce_grad artifacts).
    NsLike,
    /// x, wp, bp, wn, bn, scale (ove_grad artifact).
    Pairwise,
    /// x, y only (softmax_grad artifact).
    Softmax,
}

impl BatchMode {
    pub fn of(method: Method) -> BatchMode {
        match method {
            Method::Adversarial | Method::Uniform | Method::Frequency | Method::Nce => {
                BatchMode::NsLike
            }
            Method::AugmentReduce | Method::OneVsEach => BatchMode::Pairwise,
            Method::Softmax => BatchMode::Softmax,
        }
    }
}

/// Concrete sampler dispatch with cached PCA projections for the
/// adversarial tree (the projection of every training point is computed
/// once at prepare time instead of per draw).
pub enum SamplerKind {
    Uniform(UniformSampler),
    Frequency(FrequencySampler),
    Adversarial {
        sampler: Arc<AdversarialSampler>,
        /// Cached projections of the training features, [N, k].
        x_proj: Arc<Vec<f32>>,
    },
}

impl SamplerKind {
    /// Draw a negative for training point `i`; returns (label, log p_n).
    /// Unconditional samplers ignore `i`; the adversarial sampler looks up
    /// the cached projection of point `i`.
    #[inline]
    pub fn sample_for(&self, i: usize, rng: &mut Rng) -> (u32, f32) {
        match self {
            SamplerKind::Uniform(s) => s.sample(&[], rng),
            SamplerKind::Frequency(s) => s.sample(&[], rng),
            SamplerKind::Adversarial { sampler, x_proj } => {
                let k = sampler.aux_dim();
                sampler.tree.sample(&x_proj[i * k..(i + 1) * k], rng)
            }
        }
    }

    /// log p_n(y | x_i).
    #[inline]
    pub fn log_prob_for(&self, i: usize, y: u32) -> f32 {
        match self {
            SamplerKind::Uniform(s) => s.log_prob(&[], y),
            SamplerKind::Frequency(s) => s.log_prob(&[], y),
            SamplerKind::Adversarial { sampler, x_proj } => {
                let k = sampler.aux_dim();
                sampler.tree.log_prob(&x_proj[i * k..(i + 1) * k], y)
            }
        }
    }
}

/// Streaming batch generator: epoch-shuffled positives + sampled negatives.
pub struct BatchGen {
    data: Arc<Dataset>,
    sampler: SamplerKind,
    mode: BatchMode,
    batch_size: usize,
    /// Importance weight for Pairwise mode ((C-1)/S for A&R, 1 for OVE).
    pub scale: f32,
    rng: Rng,
    order: Vec<u32>,
    cursor: usize,
    pub epochs_completed: usize,
}

impl BatchGen {
    pub fn new(
        data: Arc<Dataset>,
        sampler: SamplerKind,
        mode: BatchMode,
        batch_size: usize,
        scale: f32,
        mut rng: Rng,
    ) -> Self {
        assert!(data.len() >= batch_size, "dataset smaller than one batch");
        let mut order: Vec<u32> = (0..data.len() as u32).collect();
        rng.shuffle(&mut order);
        Self {
            data,
            sampler,
            mode,
            batch_size,
            scale,
            rng,
            order,
            cursor: 0,
            epochs_completed: 0,
        }
    }

    /// Next training point index from the shuffled stream.
    #[inline]
    fn next_index(&mut self) -> usize {
        if self.cursor >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epochs_completed += 1;
        }
        let i = self.order[self.cursor] as usize;
        self.cursor += 1;
        i
    }

    /// Assemble the next batch.
    pub fn next_batch(&mut self) -> RawBatch {
        let b = self.batch_size;
        let k = self.data.feat_dim;
        let mut out = RawBatch {
            x: vec![0f32; b * k],
            pos: vec![0u32; b],
            neg: vec![0u32; b],
            lpn_p: vec![0f32; b],
            lpn_n: vec![0f32; b],
        };
        for j in 0..b {
            let i = self.next_index();
            out.x[j * k..(j + 1) * k].copy_from_slice(self.data.x(i));
            let y = self.data.y(i);
            out.pos[j] = y;
            match self.mode {
                BatchMode::NsLike => {
                    let (neg, lpn) = self.sampler.sample_for(i, &mut self.rng);
                    out.neg[j] = neg;
                    out.lpn_n[j] = lpn;
                    out.lpn_p[j] = self.sampler.log_prob_for(i, y);
                }
                BatchMode::Pairwise => {
                    // uniform y' != y
                    let c = self.data.num_classes;
                    let mut neg = self.rng.below(c) as u32;
                    while neg == y && c > 1 {
                        neg = self.rng.below(c) as u32;
                    }
                    out.neg[j] = neg;
                    out.lpn_n[j] = self.scale;
                }
                BatchMode::Softmax => {}
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetPreset, SyntheticConfig, TreeConfig};
    use crate::data::Splits;

    fn tiny_data() -> Arc<Dataset> {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        Arc::new(Splits::synthetic(&cfg).train)
    }

    #[test]
    fn batch_shapes() {
        let data = tiny_data();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::NsLike, 256, 1.0, Rng::new(1));
        let b = gen.next_batch();
        assert_eq!(b.x.len(), 256 * data.feat_dim);
        assert_eq!(b.pos.len(), 256);
        assert_eq!(b.neg.len(), 256);
        assert!(b.neg.iter().all(|&n| (n as usize) < data.num_classes));
    }

    #[test]
    fn epoch_covers_all_points() {
        let data = tiny_data();
        let n = data.len();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::Softmax, 256, 1.0, Rng::new(2));
        let mut seen = vec![0usize; data.num_classes];
        let batches = n / 256;
        let mut label_counts = data.label_counts();
        for _ in 0..batches {
            let b = gen.next_batch();
            for &y in &b.pos {
                seen[y as usize] += 1;
            }
        }
        // one epoch touches each point exactly once => label histograms match
        for (c, s) in label_counts.iter_mut().zip(seen.iter()) {
            assert_eq!(*c as usize, *s);
        }
        assert_eq!(gen.epochs_completed, 0);
        gen.next_batch();
        assert_eq!(gen.epochs_completed, 1);
    }

    #[test]
    fn pairwise_negative_never_equals_positive() {
        let data = tiny_data();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::Pairwise, 256, 42.0, Rng::new(3));
        for _ in 0..5 {
            let b = gen.next_batch();
            for j in 0..256 {
                assert_ne!(b.pos[j], b.neg[j]);
                assert_eq!(b.lpn_n[j], 42.0);
            }
        }
    }

    #[test]
    fn adversarial_batches_have_consistent_logprobs() {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        let data = Arc::new(Splits::synthetic(&cfg).train);
        let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
        let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 3);
        let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
        let s = SamplerKind::Adversarial { sampler: Arc::new(adv.clone()), x_proj };
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::NsLike, 256, 1.0, Rng::new(4));
        let b = gen.next_batch();
        // spot-check lpn against direct computation through the raw API
        for j in (0..256).step_by(37) {
            let x = &b.x[j * data.feat_dim..(j + 1) * data.feat_dim];
            let expect = adv.log_prob(x, b.neg[j]);
            assert!(
                (b.lpn_n[j] - expect).abs() < 1e-4,
                "j={j}: {} vs {expect}",
                b.lpn_n[j]
            );
            let expect_p = adv.log_prob(x, b.pos[j]);
            assert!((b.lpn_p[j] - expect_p).abs() < 1e-4);
        }
    }
}
