//! Batch assembly: positives from the (shuffled) training stream, negatives
//! from the configured noise distribution.
//!
//! Negative generation is the paper's O(k log C) hot loop (tree descents),
//! and it depends only on features — never on the evolving parameters — so
//! the pipeline in [`super`] can run it on worker threads fully overlapped
//! with PJRT execution and the Adagrad scatter.
//!
//! # Deterministic sequence-numbered stream
//!
//! The batch stream is defined as a **pure function of (seed, batch
//! sequence number `t`)**, never of generator call order:
//!
//! * positives: global position `p = t·B + j` maps to epoch `e = p / N` and
//!   slot `p % N` of a permutation derived from `seed.stream(EPOCH, e)`;
//! * negatives: draw `j` of batch `t` uses a private RNG split from
//!   `seed.stream(BATCH, t)`.
//!
//! Any worker can therefore produce batch `t` in isolation, and an
//! M-worker pipeline (worker `m` makes batches `t ≡ m (mod M)`) emits a
//! stream bit-identical to the inline single-thread path for every M. Each
//! generator caches only the permutation of the epoch it is currently in
//! (epochs advance monotonically), so the O(N) reshuffle is paid once per
//! epoch per worker.
//!
//! Negatives for NS-like modes run through the SIMD-width level-by-level
//! tree descents ([`crate::tree::TreeKernel::sample_batch`], 8 descents
//! per inner loop), which are bit-identical to per-draw scalar descents
//! under the same per-draw RNG streams.

use crate::config::Method;
use crate::data::Dataset;
use crate::sampler::{AdversarialSampler, FrequencySampler, NoiseSampler, UniformSampler};
use crate::utils::Rng;
use std::sync::Arc;

/// RNG stream domain for per-epoch permutations.
const STREAM_EPOCH: u64 = 1;
/// RNG stream domain for per-batch negative draws.
const STREAM_BATCH: u64 = 2;

/// One assembled raw batch (parameter rows are gathered later, on the
/// thread that owns the parameters). Buffers are reused across batches via
/// [`RawBatch::alloc`] + [`BatchGen::fill_next`] — the pipeline recycles
/// them through a return channel, so steady-state batch assembly is
/// allocation-free.
///
/// **Slot-aware recycling:** the double-buffered step engine
/// ([`crate::train::StepEngine`]) keeps two batches in flight and returns
/// batch *t* only after fetching *t+1*, so recycling runs one batch behind
/// fetching. That is safe by construction: recycles still happen in batch
/// order, so the recycle round-robin keeps pairing each buffer with the
/// worker that produced it, and the per-worker channel depth
/// (`PIPELINE_DEPTH_PER_WORKER` = 2) covers the extra outstanding buffer.
/// Even a dropped (never-recycled) batch — e.g. engine teardown with a
/// prefetched slot, or an aborted step — only degrades that worker to a
/// fresh allocation, never a stall.
#[derive(Clone, Debug)]
pub struct RawBatch {
    /// Features, [B, K] row-major.
    pub x: Vec<f32>,
    /// Positive labels, [B].
    pub pos: Vec<u32>,
    /// Negative labels, [B] (unused for softmax).
    pub neg: Vec<u32>,
    /// log p_n(y|x) for positives (NS/NCE) — zeros for pairwise/softmax.
    pub lpn_p: Vec<f32>,
    /// log p_n(y'|x) for negatives (NS/NCE) or the importance weight
    /// `scale` (OVE/A&R).
    pub lpn_n: Vec<f32>,
}

impl RawBatch {
    /// Zeroed buffers for a [B, K] batch.
    pub fn alloc(batch_size: usize, feat_dim: usize) -> Self {
        Self {
            x: vec![0f32; batch_size * feat_dim],
            pos: vec![0u32; batch_size],
            neg: vec![0u32; batch_size],
            lpn_p: vec![0f32; batch_size],
            lpn_n: vec![0f32; batch_size],
        }
    }
}

/// Which operand layout the method's HLO step consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchMode {
    /// x, wp, bp, wn, bn, lpn_p, lpn_n (ns_grad / nce_grad artifacts).
    NsLike,
    /// x, wp, bp, wn, bn, scale (ove_grad artifact).
    Pairwise,
    /// x, y only (softmax_grad artifact).
    Softmax,
}

impl BatchMode {
    pub fn of(method: Method) -> BatchMode {
        match method {
            Method::Adversarial | Method::Uniform | Method::Frequency | Method::Nce => {
                BatchMode::NsLike
            }
            Method::AugmentReduce | Method::OneVsEach => BatchMode::Pairwise,
            Method::Softmax => BatchMode::Softmax,
        }
    }
}

/// Concrete sampler dispatch with cached PCA projections for the
/// adversarial tree (the projection of every training point is computed
/// once at prepare time instead of per draw).
pub enum SamplerKind {
    Uniform(UniformSampler),
    Frequency(FrequencySampler),
    Adversarial {
        sampler: Arc<AdversarialSampler>,
        /// Cached projections of the training features, [N, k].
        x_proj: Arc<Vec<f32>>,
    },
}

impl SamplerKind {
    /// Draw a negative for training point `i`; returns (label, log p_n).
    /// Unconditional samplers ignore `i`; the adversarial sampler looks up
    /// the cached projection of point `i`.
    #[inline]
    pub fn sample_for(&self, i: usize, rng: &mut Rng) -> (u32, f32) {
        match self {
            SamplerKind::Uniform(s) => s.sample(&[], rng),
            SamplerKind::Frequency(s) => s.sample(&[], rng),
            SamplerKind::Adversarial { sampler, x_proj } => {
                let k = sampler.aux_dim();
                sampler.tree.sample(&x_proj[i * k..(i + 1) * k], rng)
            }
        }
    }

    /// log p_n(y | x_i).
    #[inline]
    pub fn log_prob_for(&self, i: usize, y: u32) -> f32 {
        match self {
            SamplerKind::Uniform(s) => s.log_prob(&[], y),
            SamplerKind::Frequency(s) => s.log_prob(&[], y),
            SamplerKind::Adversarial { sampler, x_proj } => {
                let k = sampler.aux_dim();
                sampler.tree.log_prob(&x_proj[i * k..(i + 1) * k], y)
            }
        }
    }

    /// Blocked NS-like draws for training points `idx` with positives
    /// `pos`: fills `neg[j]`/`lpn_n[j]` with a draw from `rngs[j]` and
    /// `lpn_p[j] = log p_n(pos[j] | x_idx[j])`. Bit-identical to calling
    /// [`SamplerKind::sample_for`] / [`SamplerKind::log_prob_for`] per row
    /// with the same streams; the adversarial sampler runs the block
    /// through the cache-friendly level-by-level tree descents.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_block(
        &self,
        idx: &[usize],
        pos: &[u32],
        rngs: &mut [Rng],
        neg: &mut [u32],
        lpn_n: &mut [f32],
        lpn_p: &mut [f32],
        proj_scratch: &mut Vec<f32>,
    ) {
        debug_assert_eq!(idx.len(), pos.len());
        match self {
            SamplerKind::Uniform(s) => {
                for j in 0..idx.len() {
                    let (y, lp) = s.sample(&[], &mut rngs[j]);
                    neg[j] = y;
                    lpn_n[j] = lp;
                    lpn_p[j] = s.log_prob(&[], pos[j]);
                }
            }
            SamplerKind::Frequency(s) => {
                for j in 0..idx.len() {
                    let (y, lp) = s.sample(&[], &mut rngs[j]);
                    neg[j] = y;
                    lpn_n[j] = lp;
                    lpn_p[j] = s.log_prob(&[], pos[j]);
                }
            }
            SamplerKind::Adversarial { sampler, x_proj } => {
                let k = sampler.aux_dim();
                proj_scratch.clear();
                proj_scratch.resize(idx.len() * k, 0.0);
                for (j, &i) in idx.iter().enumerate() {
                    proj_scratch[j * k..(j + 1) * k]
                        .copy_from_slice(&x_proj[i * k..(i + 1) * k]);
                }
                sampler.kernel.sample_batch(proj_scratch, rngs, neg, lpn_n);
                sampler.kernel.log_prob_batch(proj_scratch, pos, lpn_p);
            }
        }
    }
}

/// Everything that defines the batch stream, shared read-only between the
/// inline generator and all pipeline workers.
pub struct BatchSpec {
    pub data: Arc<Dataset>,
    pub sampler: SamplerKind,
    pub mode: BatchMode,
    pub batch_size: usize,
    /// Importance weight for Pairwise mode ((C-1)/S for A&R, 1 for OVE).
    pub scale: f32,
    /// Seed state for stream derivations; never advanced after
    /// construction, so every derived stream is a pure function of
    /// (seed, domain, index).
    root: Rng,
}

impl BatchSpec {
    /// Permutation RNG for epoch `e`.
    fn epoch_rng(&self, epoch: u64) -> Rng {
        self.root.stream(STREAM_EPOCH, epoch)
    }

    /// Negative-draw RNG for batch `t`.
    fn batch_rng(&self, t: u64) -> Rng {
        self.root.stream(STREAM_BATCH, t)
    }
}

/// Streaming batch generator: epoch-shuffled positives + sampled negatives.
///
/// `next_batch`/`fill_next` yield batches `start, start+stride, …` of the
/// deterministic sequence-numbered stream; the default generator
/// (`start = 0, stride = 1`) is the inline path, and [`BatchGen::worker`]
/// derives the pipeline workers' interleaved sub-streams.
pub struct BatchGen {
    spec: Arc<BatchSpec>,
    /// Next batch sequence number this generator will produce.
    next_seq: u64,
    /// Sequence-number increment (1 inline, M for pipeline worker m of M).
    stride: u64,
    /// Cached permutation for `epoch` (regenerated on epoch boundaries).
    order: Vec<u32>,
    epoch: u64,
    // scratch (reused across batches; no per-batch allocation)
    idx: Vec<usize>,
    rngs: Vec<Rng>,
    proj: Vec<f32>,
}

impl BatchGen {
    pub fn new(
        data: Arc<Dataset>,
        sampler: SamplerKind,
        mode: BatchMode,
        batch_size: usize,
        scale: f32,
        rng: Rng,
    ) -> Self {
        assert!(data.len() >= batch_size, "dataset smaller than one batch");
        let spec = Arc::new(BatchSpec { data, sampler, mode, batch_size, scale, root: rng });
        Self::with_stream(spec, 0, 1)
    }

    /// Generator over batches `start, start+stride, …` of `spec`'s stream.
    fn with_stream(spec: Arc<BatchSpec>, start: u64, stride: u64) -> Self {
        assert!(stride > 0);
        let n = spec.data.len();
        let b = spec.batch_size;
        Self {
            spec,
            next_seq: start,
            stride,
            order: vec![0u32; n],
            epoch: u64::MAX,
            idx: vec![0usize; b],
            rngs: vec![Rng::new(0); b],
            proj: Vec::new(),
        }
    }

    /// Derive pipeline worker `start` of `stride`: produces exactly the
    /// batches `t ≡ start (mod stride)` of the same stream as `self`.
    pub fn worker(&self, start: u64, stride: u64) -> BatchGen {
        Self::with_stream(self.spec.clone(), start, stride)
    }

    pub fn batch_size(&self) -> usize {
        self.spec.batch_size
    }

    pub fn feat_dim(&self) -> usize {
        self.spec.data.feat_dim
    }

    /// Epochs fully consumed by the global stream up to this generator's
    /// position (exact for the inline `stride = 1` generator).
    pub fn epochs_completed(&self) -> usize {
        let points = self.next_seq * self.spec.batch_size as u64;
        if points == 0 {
            0
        } else {
            ((points - 1) / self.spec.data.len() as u64) as usize
        }
    }

    /// Make sure `self.order` holds epoch `e`'s permutation.
    fn ensure_epoch(&mut self, e: u64) {
        if self.epoch == e {
            return;
        }
        let mut erng = self.spec.epoch_rng(e);
        for (i, o) in self.order.iter_mut().enumerate() {
            *o = i as u32;
        }
        erng.shuffle(&mut self.order);
        self.epoch = e;
    }

    /// Assemble the next batch into freshly allocated buffers.
    pub fn next_batch(&mut self) -> RawBatch {
        let mut out = RawBatch::alloc(self.spec.batch_size, self.spec.data.feat_dim);
        self.fill_next(&mut out);
        out
    }

    /// Assemble the next batch into `out` (buffers recycled by the caller).
    pub fn fill_next(&mut self, out: &mut RawBatch) {
        let t = self.next_seq;
        self.fill_batch(t, out);
        self.next_seq = t + self.stride;
    }

    /// Assemble batch `t` of the deterministic stream into `out`.
    fn fill_batch(&mut self, t: u64, out: &mut RawBatch) {
        let spec = self.spec.clone();
        let b = spec.batch_size;
        let k = spec.data.feat_dim;
        let n = spec.data.len() as u64;
        debug_assert_eq!(out.x.len(), b * k);
        debug_assert_eq!(out.pos.len(), b);

        // positives: global positions [t·B, (t+1)·B) of the epoch stream
        let base = t * b as u64;
        for j in 0..b {
            let p = base + j as u64;
            self.ensure_epoch(p / n);
            let i = self.order[(p % n) as usize] as usize;
            self.idx[j] = i;
            out.x[j * k..(j + 1) * k].copy_from_slice(spec.data.x(i));
            out.pos[j] = spec.data.y(i);
        }

        // negatives: all randomness below comes from batch t's own stream
        let mut brng = spec.batch_rng(t);
        match spec.mode {
            BatchMode::NsLike => {
                for j in 0..b {
                    self.rngs[j] = brng.split(j as u64);
                }
                spec.sampler.sample_block(
                    &self.idx,
                    &out.pos,
                    &mut self.rngs,
                    &mut out.neg,
                    &mut out.lpn_n,
                    &mut out.lpn_p,
                    &mut self.proj,
                );
            }
            BatchMode::Pairwise => {
                let c = spec.data.num_classes;
                for j in 0..b {
                    // uniform y' != y
                    let y = out.pos[j];
                    let mut neg = brng.below(c) as u32;
                    while neg == y && c > 1 {
                        neg = brng.below(c) as u32;
                    }
                    out.neg[j] = neg;
                    out.lpn_n[j] = spec.scale;
                    out.lpn_p[j] = 0.0;
                }
            }
            BatchMode::Softmax => {
                // recycled buffers: clear fields this mode does not define
                out.neg.iter_mut().for_each(|v| *v = 0);
                out.lpn_p.iter_mut().for_each(|v| *v = 0.0);
                out.lpn_n.iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetPreset, SyntheticConfig, TreeConfig};
    use crate::data::Splits;

    fn tiny_data() -> Arc<Dataset> {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        Arc::new(Splits::synthetic(&cfg).train)
    }

    #[test]
    fn batch_shapes() {
        let data = tiny_data();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::NsLike, 256, 1.0, Rng::new(1));
        let b = gen.next_batch();
        assert_eq!(b.x.len(), 256 * data.feat_dim);
        assert_eq!(b.pos.len(), 256);
        assert_eq!(b.neg.len(), 256);
        assert!(b.neg.iter().all(|&n| (n as usize) < data.num_classes));
    }

    #[test]
    fn epoch_covers_all_points() {
        let data = tiny_data();
        let n = data.len();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::Softmax, 256, 1.0, Rng::new(2));
        let mut seen = vec![0usize; data.num_classes];
        let batches = n / 256;
        let mut label_counts = data.label_counts();
        for _ in 0..batches {
            let b = gen.next_batch();
            for &y in &b.pos {
                seen[y as usize] += 1;
            }
        }
        // one epoch touches each point exactly once => label histograms match
        for (c, s) in label_counts.iter_mut().zip(seen.iter()) {
            assert_eq!(*c as usize, *s);
        }
        assert_eq!(gen.epochs_completed(), 0);
        gen.next_batch();
        assert_eq!(gen.epochs_completed(), 1);
    }

    #[test]
    fn pairwise_negative_never_equals_positive() {
        let data = tiny_data();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::Pairwise, 256, 42.0, Rng::new(3));
        for _ in 0..5 {
            let b = gen.next_batch();
            for j in 0..256 {
                assert_ne!(b.pos[j], b.neg[j]);
                assert_eq!(b.lpn_n[j], 42.0);
            }
        }
    }

    #[test]
    fn adversarial_batches_have_consistent_logprobs() {
        let mut cfg = SyntheticConfig::preset(DatasetPreset::Tiny);
        cfg.n_train = 2048;
        let data = Arc::new(Splits::synthetic(&cfg).train);
        let tcfg = TreeConfig { aux_dim: 8, ..Default::default() };
        let (adv, _) = AdversarialSampler::fit(&data, &tcfg, 3);
        let x_proj = Arc::new(adv.pca.project_all(&data.features, data.len()));
        let s = SamplerKind::Adversarial { sampler: Arc::new(adv.clone()), x_proj };
        let mut gen = BatchGen::new(data.clone(), s, BatchMode::NsLike, 256, 1.0, Rng::new(4));
        let b = gen.next_batch();
        // spot-check lpn against direct computation through the raw API
        for j in (0..256).step_by(37) {
            let x = &b.x[j * data.feat_dim..(j + 1) * data.feat_dim];
            let expect = adv.log_prob(x, b.neg[j]);
            assert!(
                (b.lpn_n[j] - expect).abs() < 1e-4,
                "j={j}: {} vs {expect}",
                b.lpn_n[j]
            );
            let expect_p = adv.log_prob(x, b.pos[j]);
            assert!((b.lpn_p[j] - expect_p).abs() < 1e-4);
        }
    }

    /// Worker sub-streams reassemble into exactly the inline stream — the
    /// invariant the whole pipeline design rests on.
    #[test]
    fn worker_streams_interleave_to_inline_stream() {
        let data = tiny_data();
        for stride in [2u64, 3, 4] {
            let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
            let mut inline =
                BatchGen::new(data.clone(), s, BatchMode::NsLike, 128, 1.0, Rng::new(9));
            let mut workers: Vec<BatchGen> =
                (0..stride).map(|m| inline.worker(m, stride)).collect();
            for t in 0..40u64 {
                let a = inline.next_batch();
                let b = workers[(t % stride) as usize].next_batch();
                assert_eq!(a.pos, b.pos, "t={t} stride={stride}");
                assert_eq!(a.neg, b.neg, "t={t} stride={stride}");
                assert_eq!(a.x, b.x, "t={t} stride={stride}");
                assert_eq!(a.lpn_p, b.lpn_p, "t={t} stride={stride}");
                assert_eq!(a.lpn_n, b.lpn_n, "t={t} stride={stride}");
            }
        }
    }

    /// Recycled buffers produce the same stream as fresh allocations.
    #[test]
    fn fill_next_recycling_matches_next_batch() {
        let data = tiny_data();
        let s = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut a = BatchGen::new(data.clone(), s, BatchMode::NsLike, 128, 1.0, Rng::new(5));
        let s2 = SamplerKind::Uniform(UniformSampler::new(data.num_classes));
        let mut b = BatchGen::new(data.clone(), s2, BatchMode::NsLike, 128, 1.0, Rng::new(5));
        let mut buf = RawBatch::alloc(128, data.feat_dim);
        for _ in 0..20 {
            let fresh = a.next_batch();
            b.fill_next(&mut buf);
            assert_eq!(fresh.pos, buf.pos);
            assert_eq!(fresh.neg, buf.neg);
            assert_eq!(fresh.lpn_n, buf.lpn_n);
        }
    }
}
