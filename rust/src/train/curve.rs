//! Learning-curve recording (the data behind Figure 1) and CSV export.

use crate::config::{DatasetPreset, Method};
use std::io::Write;
use std::path::Path;

/// One evaluation checkpoint during training.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub step: usize,
    /// Training wallclock in seconds, *excluding* evaluation time and
    /// *including* the auxiliary-model fit time (the paper shifts the
    /// adversarial/NCE curves right by the fit time).
    pub wall_s: f64,
    /// Mean training loss over the last window.
    pub train_loss: f64,
    /// Test predictive log-likelihood per point.
    pub log_likelihood: f64,
    /// Test top-1 accuracy.
    pub accuracy: f64,
}

/// A full training trajectory for one (dataset, method) cell of Figure 1.
#[derive(Clone, Debug)]
pub struct LearningCurve {
    pub dataset: String,
    pub method: Method,
    /// Auxiliary model fit time (0 for methods that need no tree).
    pub aux_fit_seconds: f64,
    pub points: Vec<CurvePoint>,
}

impl LearningCurve {
    pub fn new(dataset: DatasetPreset, method: Method, aux_fit_seconds: f64) -> Self {
        Self {
            dataset: dataset.to_string(),
            method,
            aux_fit_seconds,
            points: Vec::new(),
        }
    }

    /// Final (last-checkpoint) metrics, if any evaluation ran.
    pub fn last(&self) -> Option<&CurvePoint> {
        self.points.last()
    }

    /// Best accuracy seen along the curve.
    pub fn best_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(0.0, f64::max)
    }

    /// Best predictive log-likelihood seen along the curve.
    pub fn best_log_likelihood(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.log_likelihood)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// First wallclock (s) at which accuracy reached `target`, if ever —
    /// the "time to accuracy" statistic behind the paper's
    /// order-of-magnitude claim.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.accuracy >= target)
            .map(|p| p.wall_s)
    }

    /// Append rows to a CSV (writes header if the file is new/empty).
    pub fn append_csv(&self, path: &Path) -> anyhow::Result<()> {
        let new = !path.exists() || std::fs::metadata(path)?.len() == 0;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        if new {
            writeln!(f, "dataset,method,step,wall_s,train_loss,log_likelihood,accuracy")?;
        }
        for p in &self.points {
            writeln!(
                f,
                "{},{},{},{:.3},{:.6},{:.6},{:.6}",
                self.dataset, self.method, p.step, p.wall_s, p.train_loss,
                p.log_likelihood, p.accuracy
            )?;
        }
        Ok(())
    }
}

/// Log-spaced evaluation schedule: dense early (where Figure 1's x-axis is
/// log time), sparse late. Returns the next step at which to evaluate.
pub fn next_eval_step(current: usize, eval_every: usize) -> usize {
    if eval_every > 0 {
        current + eval_every
    } else {
        ((current as f64) * 1.5).ceil().max((current + 25) as f64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve() -> LearningCurve {
        let mut c = LearningCurve::new(DatasetPreset::Tiny, Method::Adversarial, 1.0);
        for (i, (ll, acc)) in [(-5.0, 0.1), (-3.0, 0.4), (-3.5, 0.35)].iter().enumerate() {
            c.points.push(CurvePoint {
                step: (i + 1) * 100,
                wall_s: (i + 1) as f64,
                train_loss: 1.0,
                log_likelihood: *ll,
                accuracy: *acc,
            });
        }
        c
    }

    #[test]
    fn best_metrics() {
        let c = curve();
        assert_eq!(c.best_accuracy(), 0.4);
        assert_eq!(c.best_log_likelihood(), -3.0);
        assert_eq!(c.last().unwrap().step, 300);
    }

    #[test]
    fn time_to_accuracy() {
        let c = curve();
        assert_eq!(c.time_to_accuracy(0.35), Some(2.0));
        assert_eq!(c.time_to_accuracy(0.9), None);
    }

    #[test]
    fn schedule_grows_geometrically() {
        let mut s = 0;
        let mut steps = vec![];
        for _ in 0..8 {
            s = next_eval_step(s, 0);
            steps.push(s);
        }
        for w in steps.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(steps[7] > 300, "{steps:?}");
    }

    #[test]
    fn fixed_schedule() {
        assert_eq!(next_eval_step(100, 50), 150);
    }

    #[test]
    fn csv_roundtrip() {
        let c = curve();
        let path = std::env::temp_dir().join("adv_softmax_curve_test.csv");
        std::fs::remove_file(&path).ok();
        c.append_csv(&path).unwrap();
        c.append_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1 + 2 * 3); // one header, 2x3 rows
        assert!(lines[0].starts_with("dataset,method"));
        std::fs::remove_file(&path).ok();
    }
}
