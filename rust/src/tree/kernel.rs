//! SIMD-width, level-blocked inference kernels for the fitted tree.
//!
//! [`TreeKernel`] is a derived structure built once from a fitted [`Tree`]
//! (and rebuilt whenever the tree is refitted or loaded): it re-lays the
//! heap-ordered model out **level by level**, each level carrying its own
//! packed weight rows, biases, forced flags, and a precomputed
//! `any_forced` mask. The batch entry points process **LANES = 8 descents
//! (or 8 examples) per inner loop**:
//!
//! * [`TreeKernel::sample_batch`] / [`TreeKernel::log_prob_batch`] walk a
//!   whole block of descents in lane groups of 8: the group's 8
//!   activations are gathered with the canonical [`crate::linalg::dot`]
//!   order, the fused sigmoid/log-sigmoid terms for all 8 lanes run
//!   through the vectorizable structure-of-arrays kernels
//!   ([`crate::linalg::sig_terms8`] / [`crate::linalg::log_sigmoid_pair8`]),
//!   and the per-lane uniforms come from the counter-mode
//!   [`crate::utils::rng::LaneRng`] — pure functions of stack-held
//!   (key, counter) pairs, so the draw stage is branch-free with no
//!   sequential RNG state (the stage that used to serialize the loop; the
//!   retained xoshiro-draw kernel [`TreeKernel::sample_batch_serial_rng`]
//!   is the `speedups_rng` bench reference). Levels whose `any_forced`
//!   mask is clear skip forced-flag handling entirely — the common case
//!   for every level above the padding fringe — instead of branching per
//!   draw.
//! * [`TreeKernel::beam_topk`] stages the whole frontier's activations and
//!   log-sigmoid terms lane-major in [`BeamScratch`] and runs them through
//!   the 8-lane kernels, 8 beam prefixes per inner loop; forced levels and
//!   ragged frontier tails take the scalar per-prefix path
//!   ([`TreeKernel::beam_topk_scalar`] keeps the one-prefix-at-a-time
//!   descent as the parity oracle and `speedups_beam8` bench reference).
//! * [`TreeKernel::node_activations_batch`] runs the O(kC) activation
//!   sweep as a tiled nodes×k · k×m kernel
//!   ([`crate::linalg::affine_dots_tile`]): the node-row loop sits outside
//!   an 8-example tile, so each weight row is streamed from memory once per
//!   tile instead of once per example.
//!
//! # Layout notes (measured, see `benches/hot_path.rs`)
//!
//! Weight rows stay **row-major** inside each level: the canonical 4-lane
//! accumulator dot over a contiguous row is the form the auto-vectorizer
//! compiles best, and it benchmarked ahead of feature-major transposed
//! panels (whose strided per-node columns defeat contiguous loads). The
//! lane-major aspect of the layout is the fixed 8-wide grouping of
//! descents/examples plus the staged 8-lane math, not a weight transpose.
//!
//! # Determinism contract
//!
//! Every floating-point result these kernels produce is **bit-identical**
//! to the retained scalar walkers ([`Tree::sample`], [`Tree::log_prob`],
//! [`Tree::node_activations`]): activations share the canonical
//! [`crate::linalg::dot`] reduction order, branch terms share the fused
//! sigmoid kernels (whose scalar and 8-lane shapes execute the same IEEE
//! operation sequence per lane), and each descent consumes its private RNG
//! stream exactly as the scalar walker would. The scalar walkers are kept
//! as the test oracle (`tests/proptest_invariants.rs` pins the parity
//! across depths, padding shapes, and k ∈ {1, 7, 8, 64}), and batch
//! results do not depend on how callers shard blocks across workers.

use super::{Forced, Tree, PADDING};
use crate::linalg::{
    affine_dots_tile, dot, log_sigmoid_pair, log_sigmoid_pair8, sig_terms, sig_terms8,
};
use crate::utils::rng::LaneRng;
use crate::utils::Rng;

/// Lane width of the blocked kernels: descents/examples per inner loop.
pub const LANES: usize = 8;

/// One tree level's packed slice of the model (see module docs).
#[derive(Clone, Debug)]
struct Level {
    /// Global heap index of the level's first node (2^d − 1 at depth d).
    first: usize,
    /// Node weights, row-major `[nodes, k]` (nodes = 2^d).
    w: Vec<f32>,
    /// Node biases, `[nodes]`.
    b: Vec<f32>,
    /// Forced-branch flags, `[nodes]`.
    forced: Vec<Forced>,
    /// Precomputed level mask: true iff any node here is forced. When
    /// clear, descents take the branch-free fast path.
    any_forced: bool,
}

/// Derived lane-major inference kernel over a fitted [`Tree`].
#[derive(Clone, Debug)]
pub struct TreeKernel {
    pub aux_dim: usize,
    pub num_classes: usize,
    pub num_leaves: usize,
    pub depth: usize,
    levels: Vec<Level>,
    label_of_leaf: Vec<u32>,
    leaf_of_label: Vec<u32>,
}

impl TreeKernel {
    /// Build the kernel from a fitted tree. O(C·k) copies; call once per
    /// fit/load, not per batch.
    pub fn build(tree: &Tree) -> Self {
        let k = tree.aux_dim;
        let mut levels = Vec::with_capacity(tree.depth);
        for d in 0..tree.depth {
            let first = (1usize << d) - 1;
            let nodes = 1usize << d;
            let forced = tree.forced[first..first + nodes].to_vec();
            let any_forced = forced.iter().any(|&f| f != 0);
            levels.push(Level {
                first,
                w: tree.w[first * k..(first + nodes) * k].to_vec(),
                b: tree.b[first..first + nodes].to_vec(),
                forced,
                any_forced,
            });
        }
        TreeKernel {
            aux_dim: k,
            num_classes: tree.num_classes,
            num_leaves: tree.num_leaves,
            depth: tree.depth,
            levels,
            label_of_leaf: tree.label_of_leaf.clone(),
            leaf_of_label: tree.leaf_of_label.clone(),
        }
    }

    /// Number of internal nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_leaves - 1
    }

    /// Blocked ancestral sampling, 8 descents per inner loop. `x_projs` is
    /// `[m, k]` row-major and `rngs[j]` is draw `j`'s private stream,
    /// consumed exactly as scalar [`Tree::sample`] would consume it (one
    /// `next_u64` per descent, keying a counter-mode [`LaneRng`]); results
    /// are bit-identical to per-draw scalar sampling under the same
    /// streams. `labels` doubles as the descent state, so the call is
    /// allocation-free.
    ///
    /// Structure: group-outer, level-inner — each lane group's 8 descent
    /// keys and draw counters live in stack arrays for the whole
    /// root→leaf walk, so the fast path's uniform stage is a branch-free
    /// loop over pure `(key, counter)` mixes with no sequential RNG state
    /// (the stage that serialized the xoshiro-draw kernel, retained as
    /// [`TreeKernel::sample_batch_serial_rng`]).
    pub fn sample_batch(
        &self,
        x_projs: &[f32],
        rngs: &mut [Rng],
        labels: &mut [u32],
        logps: &mut [f32],
    ) {
        let m = labels.len();
        let k = self.aux_dim;
        debug_assert_eq!(x_projs.len(), m * k);
        debug_assert_eq!(rngs.len(), m);
        debug_assert_eq!(logps.len(), m);
        labels.iter_mut().for_each(|n| *n = 0);
        logps.iter_mut().for_each(|v| *v = 0.0);
        let mut g = 0;
        while g < m {
            let hi = (g + LANES).min(m);
            let mut keys = [0u64; LANES];
            let mut ctrs = [0u64; LANES];
            for (l, r) in rngs[g..hi].iter_mut().enumerate() {
                keys[l] = LaneRng::from_rng(r).key();
            }
            let x = &x_projs[g * k..hi * k];
            let nodes = &mut labels[g..hi];
            let lps = &mut logps[g..hi];
            for level in &self.levels {
                if hi - g == LANES && !level.any_forced {
                    self.sample_level_fast(level, x, &keys, &mut ctrs, nodes, lps);
                } else {
                    self.sample_level_scalar(level, x, &keys, &mut ctrs, nodes, lps);
                }
            }
            g = hi;
        }
        for label in labels.iter_mut() {
            let leaf = *label as usize - (self.num_leaves - 1);
            *label = self.label_of_leaf[leaf];
            debug_assert_ne!(*label, PADDING, "sampled a padding leaf");
        }
    }

    /// Branch-free lane group for one level: 8 gathered canonical dots,
    /// staged 8-lane sigmoid terms, and 8 counter-mode uniforms computed
    /// in a dependency-free loop from the stack-held keys/counters.
    fn sample_level_fast(
        &self,
        level: &Level,
        x: &[f32],
        keys: &[u64; LANES],
        ctrs: &mut [u64; LANES],
        nodes: &mut [u32],
        logps: &mut [f32],
    ) {
        let k = self.aux_dim;
        let mut acts = [0f32; LANES];
        for l in 0..LANES {
            let local = nodes[l] as usize - level.first;
            acts[l] = dot(&level.w[local * k..(local + 1) * k], &x[l * k..(l + 1) * k])
                + level.b[local];
        }
        let (mut p, mut lsr, mut lsl) = ([0f32; LANES], [0f32; LANES], [0f32; LANES]);
        sig_terms8(&acts, &mut p, &mut lsr, &mut lsl);
        let mut u = [0f32; LANES];
        for l in 0..LANES {
            u[l] = LaneRng::uniform_at(keys[l], ctrs[l]);
        }
        for l in 0..LANES {
            ctrs[l] += 1;
            let right = u[l] < p[l];
            logps[l] += if right { lsr[l] } else { lsl[l] };
            nodes[l] = (2 * nodes[l] as usize + 1 + usize::from(right)) as u32;
        }
    }

    /// Per-lane fallback for levels with forced nodes and for the block's
    /// ragged tail group. Same canonical math and draw sequence, scalar
    /// shape: a lane's counter advances only on non-forced draws, exactly
    /// like [`Tree::sample`].
    fn sample_level_scalar(
        &self,
        level: &Level,
        x: &[f32],
        keys: &[u64; LANES],
        ctrs: &mut [u64; LANES],
        nodes: &mut [u32],
        logps: &mut [f32],
    ) {
        let k = self.aux_dim;
        for l in 0..nodes.len() {
            let node = nodes[l] as usize;
            let local = node - level.first;
            let go_right = match level.forced[local] {
                1 => true,
                -1 => false,
                _ => {
                    let a = dot(&level.w[local * k..(local + 1) * k], &x[l * k..(l + 1) * k])
                        + level.b[local];
                    let (p, lsr, lsl) = sig_terms(a);
                    let right = LaneRng::uniform_at(keys[l], ctrs[l]) < p;
                    ctrs[l] += 1;
                    logps[l] += if right { lsr } else { lsl };
                    right
                }
            };
            nodes[l] = (2 * node + 1 + usize::from(go_right)) as u32;
        }
    }

    /// The pre-lane-RNG blocked sampler: identical level-blocked structure,
    /// but each lane's uniform comes from a serial per-lane xoshiro draw
    /// (`rngs[l].next_f32()`), so the draw stage carries a sequential
    /// state dependency through every level. Retained **only** as the
    /// measured reference for the `speedups_rng` bench floor — its stream
    /// format predates [`LaneRng`] and is *not* bit-compatible with
    /// [`Tree::sample`] or [`TreeKernel::sample_batch`].
    pub fn sample_batch_serial_rng(
        &self,
        x_projs: &[f32],
        rngs: &mut [Rng],
        labels: &mut [u32],
        logps: &mut [f32],
    ) {
        let m = labels.len();
        let k = self.aux_dim;
        debug_assert_eq!(x_projs.len(), m * k);
        debug_assert_eq!(rngs.len(), m);
        debug_assert_eq!(logps.len(), m);
        labels.iter_mut().for_each(|n| *n = 0);
        logps.iter_mut().for_each(|v| *v = 0.0);
        for level in &self.levels {
            let mut g = 0;
            while g < m {
                let hi = (g + LANES).min(m);
                let x = &x_projs[g * k..hi * k];
                let nodes = &mut labels[g..hi];
                let lps = &mut logps[g..hi];
                let rs = &mut rngs[g..hi];
                if hi - g == LANES && !level.any_forced {
                    let mut acts = [0f32; LANES];
                    for l in 0..LANES {
                        let local = nodes[l] as usize - level.first;
                        acts[l] = dot(
                            &level.w[local * k..(local + 1) * k],
                            &x[l * k..(l + 1) * k],
                        ) + level.b[local];
                    }
                    let (mut p, mut lsr, mut lsl) =
                        ([0f32; LANES], [0f32; LANES], [0f32; LANES]);
                    sig_terms8(&acts, &mut p, &mut lsr, &mut lsl);
                    for l in 0..LANES {
                        let right = rs[l].next_f32() < p[l];
                        lps[l] += if right { lsr[l] } else { lsl[l] };
                        nodes[l] = (2 * nodes[l] as usize + 1 + usize::from(right)) as u32;
                    }
                } else {
                    for l in 0..nodes.len() {
                        let node = nodes[l] as usize;
                        let local = node - level.first;
                        let go_right = match level.forced[local] {
                            1 => true,
                            -1 => false,
                            _ => {
                                let a = dot(
                                    &level.w[local * k..(local + 1) * k],
                                    &x[l * k..(l + 1) * k],
                                ) + level.b[local];
                                let (p, lsr, lsl) = sig_terms(a);
                                let right = rs[l].next_f32() < p;
                                lps[l] += if right { lsr } else { lsl };
                                right
                            }
                        };
                        nodes[l] = (2 * node + 1 + usize::from(go_right)) as u32;
                    }
                }
                g = hi;
            }
        }
        for label in labels.iter_mut() {
            let leaf = *label as usize - (self.num_leaves - 1);
            *label = self.label_of_leaf[leaf];
            debug_assert_ne!(*label, PADDING, "sampled a padding leaf");
        }
    }

    /// Blocked root→leaf log-probability, 8 rows per inner loop:
    /// `out[j] = log p_n(ys[j] | x_j)`, bit-identical to scalar
    /// [`Tree::log_prob`] per row. A row that violates a forced branch
    /// pins to −∞; later levels only add finite terms to it, so the final
    /// value matches the scalar walker's early return exactly.
    pub fn log_prob_batch(&self, x_projs: &[f32], ys: &[u32], out: &mut [f32]) {
        let m = ys.len();
        let k = self.aux_dim;
        debug_assert_eq!(x_projs.len(), m * k);
        debug_assert_eq!(out.len(), m);
        out.iter_mut().for_each(|v| *v = 0.0);
        for (ld, level) in self.levels.iter().enumerate() {
            // distance of this level's nodes from the leaf row
            let d = self.depth - ld;
            let mut g = 0;
            while g < m {
                let hi = (g + LANES).min(m);
                let x = &x_projs[g * k..hi * k];
                let (ys_g, out_g) = (&ys[g..hi], &mut out[g..hi]);
                if hi - g == LANES && !level.any_forced {
                    self.log_prob_group_fast(level, d, x, ys_g, out_g);
                } else {
                    self.log_prob_group_scalar(level, d, x, ys_g, out_g);
                }
                g = hi;
            }
        }
    }

    fn log_prob_group_fast(
        &self,
        level: &Level,
        d: usize,
        x: &[f32],
        ys: &[u32],
        out: &mut [f32],
    ) {
        let k = self.aux_dim;
        let mut acts = [0f32; LANES];
        let mut went_right = [false; LANES];
        for l in 0..LANES {
            debug_assert!((ys[l] as usize) < self.num_classes);
            // 1-indexed heap position of the label's leaf (root = 1)
            let q = self.leaf_of_label[ys[l] as usize] as usize + self.num_leaves;
            let local = (q >> d) - 1 - level.first;
            went_right[l] = (q >> (d - 1)) & 1 == 1;
            acts[l] = dot(&level.w[local * k..(local + 1) * k], &x[l * k..(l + 1) * k])
                + level.b[local];
        }
        let (mut lsr, mut lsl) = ([0f32; LANES], [0f32; LANES]);
        log_sigmoid_pair8(&acts, &mut lsr, &mut lsl);
        for l in 0..LANES {
            out[l] += if went_right[l] { lsr[l] } else { lsl[l] };
        }
    }

    fn log_prob_group_scalar(
        &self,
        level: &Level,
        d: usize,
        x: &[f32],
        ys: &[u32],
        out: &mut [f32],
    ) {
        let k = self.aux_dim;
        for l in 0..ys.len() {
            debug_assert!((ys[l] as usize) < self.num_classes);
            let q = self.leaf_of_label[ys[l] as usize] as usize + self.num_leaves;
            let local = (q >> d) - 1 - level.first;
            let went_right = (q >> (d - 1)) & 1 == 1;
            match level.forced[local] {
                1 => {
                    if !went_right {
                        out[l] = f32::NEG_INFINITY;
                    }
                }
                -1 => {
                    if went_right {
                        out[l] = f32::NEG_INFINITY;
                    }
                }
                _ => {
                    let a = dot(&level.w[local * k..(local + 1) * k], &x[l * k..(l + 1) * k])
                        + level.b[local];
                    let (lsr, lsl) = log_sigmoid_pair(a);
                    out[l] += if went_right { lsr } else { lsl };
                }
            }
        }
    }

    /// Batched O(kC) activation sweep: fills `out[j * num_nodes + i]` with
    /// node `i`'s activation for example `j`, for an `[m, k]` block of
    /// projected features. Runs the tiled nodes×k · k×m kernel per level;
    /// bit-identical to per-example scalar [`Tree::node_activations`].
    pub fn node_activations_batch(&self, x_projs: &[f32], m: usize, out: &mut [f32]) {
        let k = self.aux_dim;
        let nn = self.num_nodes();
        debug_assert_eq!(x_projs.len(), m * k);
        debug_assert_eq!(out.len(), m * nn);
        for level in &self.levels {
            affine_dots_tile(&level.w, &level.b, k, x_projs, m, out, nn, level.first);
        }
    }

    /// Single-example activation sweep (the m = 1 tile).
    pub fn node_activations(&self, x_proj: &[f32], out: &mut [f32]) {
        self.node_activations_batch(x_proj, 1, out);
    }

    /// Tree-guided candidate generation for serving: a beam-search descent
    /// that keeps the `beam` highest-`log q(prefix|x)` frontier nodes per
    /// level and expands each to its two children (forced nodes contribute
    /// their single reachable child at unchanged log-probability), so the
    /// final level yields up to `2 · beam` leaf candidates. Fills `out`
    /// with `(label, log q(label|x))` pairs sorted by log-probability
    /// descending (ties toward the smaller label id); padding leaves are
    /// excluded. O(beam · aux_dim · log C) per query — the retrieval step
    /// of the serve path, re-ranked exactly by [`crate::score::Scorer`].
    ///
    /// Determinism: a pure function of `(x_proj, beam)` built from the
    /// canonical [`dot`] / [`log_sigmoid_pair`] kernels with a total
    /// tie-break, so results are bit-identical at any `parallelism` and
    /// for batched vs one-at-a-time submission. A candidate's log q is
    /// accumulated root→leaf exactly like scalar [`Tree::log_prob`], so
    /// the two agree bit for bit (pinned in tests).
    ///
    /// Structure: on forced-free levels the frontier's activations and
    /// log-sigmoid terms are staged lane-major in [`BeamScratch`] and run
    /// through the 8-lane kernels, 8 beam prefixes per inner loop; the
    /// staged ragged tail and forced levels take the per-prefix scalar
    /// body. Child push order matches the per-prefix descent
    /// ([`TreeKernel::beam_topk_scalar`], the retained oracle and
    /// `speedups_beam8` bench reference) exactly, so the two are
    /// bit-identical (pinned by proptest).
    pub fn beam_topk(
        &self,
        x_proj: &[f32],
        beam: usize,
        out: &mut Vec<(u32, f32)>,
        scratch: &mut BeamScratch,
    ) {
        let k = self.aux_dim;
        debug_assert_eq!(x_proj.len(), k);
        assert!(beam >= 1, "beam width must be at least 1");
        let frontier = &mut scratch.frontier;
        let next = &mut scratch.next;
        frontier.clear();
        frontier.push((0.0, 0u32)); // (log q prefix, heap node): the root
        for level in &self.levels {
            if frontier.len() > beam {
                // (log q desc, node asc): a total order, so the kept set is
                // a pure function of the prefix probabilities
                frontier.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                frontier.truncate(beam);
            }
            next.clear();
            if !level.any_forced && frontier.len() >= LANES {
                // lane-major staging: all frontier activations, then the
                // 8-lane fused log-sigmoid over full lane groups
                let n = frontier.len();
                scratch.acts.clear();
                scratch.acts.extend(frontier.iter().map(|&(_, node)| {
                    let local = node as usize - level.first;
                    dot(&level.w[local * k..(local + 1) * k], x_proj) + level.b[local]
                }));
                scratch.lsr.resize(n, 0.0);
                scratch.lsl.resize(n, 0.0);
                let mut i = 0;
                while i + LANES <= n {
                    let mut a8 = [0f32; LANES];
                    a8.copy_from_slice(&scratch.acts[i..i + LANES]);
                    let (mut lsr8, mut lsl8) = ([0f32; LANES], [0f32; LANES]);
                    log_sigmoid_pair8(&a8, &mut lsr8, &mut lsl8);
                    scratch.lsr[i..i + LANES].copy_from_slice(&lsr8);
                    scratch.lsl[i..i + LANES].copy_from_slice(&lsl8);
                    i += LANES;
                }
                for j in i..n {
                    let (lsr, lsl) = log_sigmoid_pair(scratch.acts[j]);
                    scratch.lsr[j] = lsr;
                    scratch.lsl[j] = lsl;
                }
                for (j, &(lp, node)) in frontier.iter().enumerate() {
                    next.push((lp + scratch.lsl[j], 2 * node + 1));
                    next.push((lp + scratch.lsr[j], 2 * node + 2));
                }
            } else {
                for &(lp, node) in frontier.iter() {
                    let local = node as usize - level.first;
                    match level.forced[local] {
                        1 => next.push((lp, 2 * node + 2)),
                        -1 => next.push((lp, 2 * node + 1)),
                        _ => {
                            let a = dot(&level.w[local * k..(local + 1) * k], x_proj)
                                + level.b[local];
                            let (lsr, lsl) = log_sigmoid_pair(a);
                            next.push((lp + lsl, 2 * node + 1));
                            next.push((lp + lsr, 2 * node + 2));
                        }
                    }
                }
            }
            std::mem::swap(frontier, next);
        }
        out.clear();
        let base = self.num_leaves - 1;
        for &(lp, node) in frontier.iter() {
            let label = self.label_of_leaf[node as usize - base];
            if label != PADDING {
                out.push((label, lp));
            }
        }
        out.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    }

    /// The one-prefix-at-a-time beam descent: same pruning, same child
    /// push order, no lane staging. Retained as the bit-parity oracle for
    /// the lane-group [`TreeKernel::beam_topk`] (pinned by proptest across
    /// beam widths × padding shapes) and as the measured reference for the
    /// `speedups_beam8` bench floor.
    pub fn beam_topk_scalar(
        &self,
        x_proj: &[f32],
        beam: usize,
        out: &mut Vec<(u32, f32)>,
        scratch: &mut BeamScratch,
    ) {
        let k = self.aux_dim;
        debug_assert_eq!(x_proj.len(), k);
        assert!(beam >= 1, "beam width must be at least 1");
        let frontier = &mut scratch.frontier;
        let next = &mut scratch.next;
        frontier.clear();
        frontier.push((0.0, 0u32));
        for level in &self.levels {
            if frontier.len() > beam {
                frontier.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                frontier.truncate(beam);
            }
            next.clear();
            for &(lp, node) in frontier.iter() {
                let local = node as usize - level.first;
                match level.forced[local] {
                    1 => next.push((lp, 2 * node + 2)),
                    -1 => next.push((lp, 2 * node + 1)),
                    _ => {
                        let a = dot(&level.w[local * k..(local + 1) * k], x_proj)
                            + level.b[local];
                        let (lsr, lsl) = log_sigmoid_pair(a);
                        next.push((lp + lsl, 2 * node + 1));
                        next.push((lp + lsr, 2 * node + 2));
                    }
                }
            }
            std::mem::swap(frontier, next);
        }
        out.clear();
        let base = self.num_leaves - 1;
        for &(lp, node) in frontier.iter() {
            let label = self.label_of_leaf[node as usize - base];
            if label != PADDING {
                out.push((label, lp));
            }
        }
        out.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    }
}

/// Reusable buffers for [`TreeKernel::beam_topk`] (grown once, fully
/// rewritten per query — per-query descents are allocation-free): the
/// frontier double buffer plus the lane-major activation / log-sigmoid
/// staging the 8-lane level body writes.
#[derive(Default)]
pub struct BeamScratch {
    frontier: Vec<(f32, u32)>,
    next: Vec<(f32, u32)>,
    acts: Vec<f32>,
    lsr: Vec<f32>,
    lsl: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built 4-leaf tree over 3 labels (1 padding leaf), mirroring
    /// the oracle tests in `tree/mod.rs`.
    fn toy_tree() -> Tree {
        Tree {
            aux_dim: 2,
            num_classes: 3,
            num_leaves: 4,
            depth: 2,
            w: vec![
                1.0, 0.0, // root
                0.0, 1.0, // node 1
                0.0, 0.0, // node 2 (forced)
            ],
            b: vec![0.0, 0.5, 0.0],
            forced: vec![0, 0, -1],
            label_of_leaf: vec![0, 1, 2, PADDING],
            leaf_of_label: vec![0, 1, 2],
        }
    }

    #[test]
    fn build_packs_every_level() {
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        assert_eq!(kern.depth, 2);
        assert_eq!(kern.num_nodes(), 3);
        assert_eq!(kern.levels.len(), 2);
        assert_eq!(kern.levels[0].first, 0);
        assert_eq!(kern.levels[1].first, 1);
        assert!(!kern.levels[0].any_forced);
        assert!(kern.levels[1].any_forced);
        assert_eq!(kern.levels[1].w, &t.w[2..6]);
    }

    #[test]
    fn sample_batch_matches_scalar_oracle() {
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        // 67: exercises both full lane groups and the ragged tail
        let m = 67;
        let mut rng = Rng::new(11);
        let x_projs: Vec<f32> = (0..m * 2).map(|_| rng.normal()).collect();
        let mut rngs_block: Vec<Rng> = (0..m).map(|j| rng.stream(7, j as u64)).collect();
        let mut rngs_scalar = rngs_block.clone();
        let mut labels = vec![0u32; m];
        let mut logps = vec![0f32; m];
        kern.sample_batch(&x_projs, &mut rngs_block, &mut labels, &mut logps);
        for j in 0..m {
            let (y, lp) = t.sample(&x_projs[j * 2..(j + 1) * 2], &mut rngs_scalar[j]);
            assert_eq!(labels[j], y, "draw {j}");
            assert_eq!(logps[j].to_bits(), lp.to_bits(), "draw {j}");
            // and the streams were consumed identically
            assert_eq!(rngs_block[j].next_u64(), rngs_scalar[j].next_u64());
        }
    }

    #[test]
    fn log_prob_batch_matches_scalar_oracle() {
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        let m = 43;
        let mut rng = Rng::new(12);
        let x_projs: Vec<f32> = (0..m * 2).map(|_| rng.normal()).collect();
        let ys: Vec<u32> = (0..m).map(|j| (j % 3) as u32).collect();
        let mut out = vec![0f32; m];
        kern.log_prob_batch(&x_projs, &ys, &mut out);
        for j in 0..m {
            let expect = t.log_prob(&x_projs[j * 2..(j + 1) * 2], ys[j]);
            assert_eq!(out[j].to_bits(), expect.to_bits(), "row {j}");
        }
    }

    #[test]
    fn activations_batch_matches_scalar_oracle() {
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        let m = 11;
        let mut rng = Rng::new(13);
        let x_projs: Vec<f32> = (0..m * 2).map(|_| rng.normal()).collect();
        let nn = t.num_nodes();
        let mut batch = vec![0f32; m * nn];
        kern.node_activations_batch(&x_projs, m, &mut batch);
        let mut single = vec![0f32; nn];
        for j in 0..m {
            t.node_activations(&x_projs[j * 2..(j + 1) * 2], &mut single);
            assert_eq!(&batch[j * nn..(j + 1) * nn], &single[..], "row {j}");
            kern.node_activations(&x_projs[j * 2..(j + 1) * 2], &mut single);
            assert_eq!(&batch[j * nn..(j + 1) * nn], &single[..], "row {j} (m=1 path)");
        }
    }

    #[test]
    fn full_beam_enumerates_every_label_with_exact_log_probs() {
        // beam >= num_leaves never prunes: candidates are exactly the real
        // labels, each with a log q bit-identical to the scalar walker
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        let x = [0.4f32, -0.9];
        let mut out = Vec::new();
        let mut scratch = BeamScratch::default();
        kern.beam_topk(&x, t.num_leaves, &mut out, &mut scratch);
        assert_eq!(out.len(), 3, "padding leaf must be excluded");
        for &(y, lp) in &out {
            let expect = t.log_prob(&x, y);
            assert_eq!(lp.to_bits(), expect.to_bits(), "label {y}");
        }
        // sorted by log q descending
        for w in out.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn beam_one_is_the_greedy_descent() {
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        let mut out = Vec::new();
        let mut scratch = BeamScratch::default();
        for x in [[2.0f32, 2.0], [-2.0, -2.0], [0.1, -3.0]] {
            kern.beam_topk(&x, 1, &mut out, &mut scratch);
            assert!(!out.is_empty() && out.len() <= 2);
            // the top candidate's log q must be the max over the candidates
            // and match the scalar log_prob of its own label
            let best = out[0];
            assert_eq!(best.1.to_bits(), t.log_prob(&x, best.0).to_bits());
        }
    }

    #[test]
    fn beam_candidates_cover_the_most_probable_label() {
        // with beam >= 2 on the toy tree, the argmax of the full
        // conditional must always appear among the candidates
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        let mut out = Vec::new();
        let mut scratch = BeamScratch::default();
        let mut all = vec![0f32; 3];
        for x in [[1.5f32, 0.3], [-1.0, 2.0], [0.0, 0.0], [3.0, -3.0]] {
            t.log_prob_all(&x, &mut all);
            let argmax = (0..3).max_by(|&a, &b| all[a].total_cmp(&all[b])).unwrap() as u32;
            kern.beam_topk(&x, 2, &mut out, &mut scratch);
            assert!(
                out.iter().any(|&(y, _)| y == argmax),
                "x {x:?}: argmax {argmax} missing from {out:?}"
            );
        }
    }

    #[test]
    fn padding_never_sampled_through_kernel() {
        let t = toy_tree();
        let kern = TreeKernel::build(&t);
        let m = 64;
        let x_projs = vec![5.0f32; m * 2];
        let base = Rng::new(3);
        let mut rngs: Vec<Rng> = (0..m).map(|j| base.stream(1, j as u64)).collect();
        let mut labels = vec![0u32; m];
        let mut logps = vec![0f32; m];
        for _ in 0..50 {
            kern.sample_batch(&x_projs, &mut rngs, &mut labels, &mut logps);
            assert!(labels.iter().all(|&y| y < 3));
            assert!(logps.iter().all(|l| l.is_finite()));
        }
    }
}
