//! The auxiliary adversarial model (paper Sec. 3): a balanced probabilistic
//! binary decision tree over the label set.
//!
//! * Structure: a perfect binary tree with `L = next_pow2(C)` leaves stored
//!   implicitly in heap order (node `i` has children `2i+1`, `2i+2`; leaf
//!   `j` sits at heap position `L-1+j`). Each internal node ν carries
//!   (w_ν ∈ R^k, b_ν); the probability of branching right given projected
//!   features x is σ(w_ν·x + b_ν).
//! * Padding: if C is not a power of two, the extra leaves are uninhabited
//!   padding labels. Nodes whose one child subtree contains only padding
//!   are `forced` toward the real side (the paper's "b_ν set to a very
//!   large value"), so p_n(padding|x) = 0 exactly and sampling never
//!   reaches a padding leaf.
//! * Inference costs: ancestral sampling and single-label log-probability
//!   are O(k log C); the full conditional vector log p_n(·|x) needed for
//!   bias-corrected evaluation is O(k C) via one activation sweep plus an
//!   O(C) prefix accumulation (`log_prob_all`), or O(C) if activations come
//!   precomputed from the `scores` HLO artifact
//!   (`log_prob_all_from_activations`).
//! * Hot-path kernels: the methods here are the **scalar walkers** — one
//!   draw / one label / one example at a time. They are the semantic
//!   reference (and the test oracle), while production batch work goes
//!   through the derived [`TreeKernel`] ([`kernel`]), which re-lays the
//!   model out level-by-level and processes [`LANES`] descents or examples
//!   per inner loop. Both sides evaluate activations in the canonical
//!   [`crate::linalg::dot`] reduction order and branch terms through the
//!   canonical fused sigmoid kernels ([`crate::linalg::sig_terms`] /
//!   [`crate::linalg::log_sigmoid_pair`]), so scalar and blocked results
//!   are bit-identical — the determinism contract that keeps learning
//!   curves reproducible at every `parallelism` setting.
//!
//! Fitting (greedy maximum likelihood, alternating Newton ascent and
//! balanced re-splits) lives in [`fit`].

pub mod fit;
pub mod kernel;

pub use fit::FitStats;
pub use kernel::{BeamScratch, TreeKernel, LANES};

use crate::linalg::{dot, log_sigmoid_pair, sig_terms};
use crate::utils::json::Json;
use crate::utils::rng::LaneRng;
use crate::utils::Rng;
use std::path::Path;

/// Sentinel for uninhabited padding label slots.
pub const PADDING: u32 = u32::MAX;

/// Forced-branch flag: 0 normal, +1 always-right, -1 always-left.
pub type Forced = i8;

/// A fitted probabilistic decision tree over `num_classes` labels.
#[derive(Clone, Debug)]
pub struct Tree {
    /// Projected feature dimension k.
    pub aux_dim: usize,
    /// Number of real labels C.
    pub num_classes: usize,
    /// next_pow2(C) leaves.
    pub num_leaves: usize,
    /// log2(num_leaves).
    pub depth: usize,
    /// Internal-node weights, `(num_leaves - 1) * aux_dim`, heap order.
    pub w: Vec<f32>,
    /// Internal-node biases, `num_leaves - 1`.
    pub b: Vec<f32>,
    /// Forced-branch flags, `num_leaves - 1`.
    pub forced: Vec<Forced>,
    /// Leaf -> label (PADDING for uninhabited leaves).
    pub label_of_leaf: Vec<u32>,
    /// Label -> leaf.
    pub leaf_of_label: Vec<u32>,
}

impl Tree {
    /// Number of internal nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.num_leaves - 1
    }

    #[inline]
    fn node_w(&self, i: usize) -> &[f32] {
        &self.w[i * self.aux_dim..(i + 1) * self.aux_dim]
    }

    /// Activation a_ν = w_ν·x + b_ν of one node.
    #[inline]
    pub fn activation(&self, node: usize, x_proj: &[f32]) -> f32 {
        dot(self.node_w(node), x_proj) + self.b[node]
    }

    /// Ancestral sampling: draw y' ~ p_n(·|x), returning (label, log p_n).
    /// O(k log C). Scalar walker; bit-identical to the blocked
    /// [`TreeKernel::sample_batch`] under the same RNG stream.
    ///
    /// Stream format: one `next_u64` is consumed from `rng` as the descent
    /// key of a counter-mode [`LaneRng`]; the per-level uniforms (one per
    /// non-forced node on the path) are pure functions of that key, which
    /// is what lets the kernel draw eight lanes branch-free.
    pub fn sample(&self, x_proj: &[f32], rng: &mut Rng) -> (u32, f32) {
        debug_assert_eq!(x_proj.len(), self.aux_dim);
        let mut lane = LaneRng::from_rng(rng);
        let mut node = 0usize;
        let mut logp = 0f32;
        for _ in 0..self.depth {
            let go_right = match self.forced[node] {
                1 => true,
                -1 => false,
                _ => {
                    let a = self.activation(node, x_proj);
                    let (p_right, lsr, lsl) = sig_terms(a);
                    let right = lane.next_f32() < p_right;
                    logp += if right { lsr } else { lsl };
                    right
                }
            };
            node = 2 * node + 1 + usize::from(go_right);
        }
        let leaf = node - (self.num_leaves - 1);
        let label = self.label_of_leaf[leaf];
        debug_assert_ne!(label, PADDING, "sampled a padding leaf");
        (label, logp)
    }

    /// log p_n(y|x) for one label. O(k log C).
    ///
    /// Walks root→leaf (the leaf's ancestor at distance `d` is `q >> d`
    /// for 1-indexed heap position `q`), so the accumulation order matches
    /// [`TreeKernel::log_prob_batch`] and [`Tree::log_prob_all`]
    /// bit-for-bit.
    pub fn log_prob(&self, x_proj: &[f32], y: u32) -> f32 {
        debug_assert!((y as usize) < self.num_classes);
        // 1-indexed heap position of the leaf (root = 1).
        let q = self.leaf_of_label[y as usize] as usize + self.num_leaves;
        let mut logp = 0f32;
        for d in (1..=self.depth).rev() {
            let node = (q >> d) - 1; // 0-indexed ancestor at distance d
            let went_right = (q >> (d - 1)) & 1 == 1;
            match self.forced[node] {
                1 => {
                    if !went_right {
                        return f32::NEG_INFINITY;
                    }
                }
                -1 => {
                    if went_right {
                        return f32::NEG_INFINITY;
                    }
                }
                _ => {
                    let a = self.activation(node, x_proj);
                    let (lsr, lsl) = log_sigmoid_pair(a);
                    logp += if went_right { lsr } else { lsl };
                }
            }
        }
        logp
    }

    /// All node activations for one x (heap order). O(k C). Scalar walker;
    /// [`TreeKernel::node_activations_batch`] is the blocked form.
    pub fn node_activations(&self, x_proj: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.num_nodes());
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.activation(i, x_proj);
        }
    }

    /// log p_n(y|x) for every real label y, given precomputed activations
    /// (e.g. from the `scores` HLO artifact or the kernel's batched
    /// activation sweep). O(C).
    pub fn log_prob_all_from_activations(&self, acts: &[f32], out: &mut [f32]) {
        self.log_prob_all_from_activations_with(acts, out, &mut Vec::new());
    }

    /// [`Tree::log_prob_all_from_activations`] with a caller-owned heap
    /// prefix buffer (grown once, fully overwritten), so per-example sweep
    /// loops pay no per-call O(C) allocation.
    pub fn log_prob_all_from_activations_with(
        &self,
        acts: &[f32],
        out: &mut [f32],
        lp: &mut Vec<f32>,
    ) {
        debug_assert_eq!(acts.len(), self.num_nodes());
        debug_assert_eq!(out.len(), self.num_classes);
        // prefix accumulation down the heap (every slot below the root is
        // written before it is read; the root's 0 is seeded here)
        if lp.len() < 2 * self.num_leaves - 1 {
            lp.resize(2 * self.num_leaves - 1, 0.0);
        }
        lp[0] = 0.0;
        for i in 0..self.num_nodes() {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            match self.forced[i] {
                1 => {
                    lp[l] = f32::NEG_INFINITY;
                    lp[r] = lp[i];
                }
                -1 => {
                    lp[l] = lp[i];
                    lp[r] = f32::NEG_INFINITY;
                }
                _ => {
                    let (lsr, lsl) = log_sigmoid_pair(acts[i]);
                    lp[l] = lp[i] + lsl;
                    lp[r] = lp[i] + lsr;
                }
            }
        }
        let base = self.num_leaves - 1;
        for leaf in 0..self.num_leaves {
            let label = self.label_of_leaf[leaf];
            if label != PADDING {
                out[label as usize] = lp[base + leaf];
            }
        }
    }

    /// log p_n(y|x) for every real label y. O(k C).
    pub fn log_prob_all(&self, x_proj: &[f32], out: &mut [f32]) {
        let mut acts = vec![0f32; self.num_nodes()];
        self.node_activations(x_proj, &mut acts);
        self.log_prob_all_from_activations(&acts, out);
    }

    /// Mean log-likelihood (Eq. 7, normalized) of projected data under p_n.
    pub fn mean_log_likelihood(&self, x_proj: &[f32], labels: &[u32]) -> f64 {
        let n = labels.len();
        assert_eq!(x_proj.len(), n * self.aux_dim);
        let mut total = 0f64;
        for (i, &y) in labels.iter().enumerate() {
            total += self.log_prob(&x_proj[i * self.aux_dim..(i + 1) * self.aux_dim], y) as f64;
        }
        total / n as f64
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("aux_dim", Json::Num(self.aux_dim as f64)),
            ("num_classes", Json::Num(self.num_classes as f64)),
            ("num_leaves", Json::Num(self.num_leaves as f64)),
            ("depth", Json::Num(self.depth as f64)),
            ("w", Json::arr_f32(&self.w)),
            ("b", Json::arr_f32(&self.b)),
            (
                "forced",
                Json::Arr(self.forced.iter().map(|&f| Json::Num(f as f64)).collect()),
            ),
            ("label_of_leaf", Json::arr_u32(&self.label_of_leaf)),
            ("leaf_of_label", Json::arr_u32(&self.leaf_of_label)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let forced: Vec<Forced> = v
            .get("forced")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_f64()? as Forced))
            .collect::<anyhow::Result<_>>()?;
        let t = Self {
            aux_dim: v.get("aux_dim")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            num_leaves: v.get("num_leaves")?.as_usize()?,
            depth: v.get("depth")?.as_usize()?,
            w: v.get("w")?.to_vec_f32()?,
            b: v.get("b")?.to_vec_f32()?,
            forced,
            label_of_leaf: v.get("label_of_leaf")?.to_vec_u32()?,
            leaf_of_label: v.get("leaf_of_label")?.to_vec_u32()?,
        };
        anyhow::ensure!(t.num_leaves.is_power_of_two(), "num_leaves not a power of two");
        anyhow::ensure!(t.w.len() == (t.num_leaves - 1) * t.aux_dim, "w size mismatch");
        anyhow::ensure!(t.b.len() == t.num_leaves - 1, "b size mismatch");
        anyhow::ensure!(t.label_of_leaf.len() == t.num_leaves, "leaf map size mismatch");
        anyhow::ensure!(t.leaf_of_label.len() == t.num_classes, "label map size mismatch");
        Ok(t)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        Ok(std::fs::write(path, self.to_json().to_string())?)
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TreeConfig;

    /// Hand-built 4-leaf tree over 3 labels (1 padding leaf).
    fn toy_tree() -> Tree {
        // leaves: [0 -> label 0, 1 -> label 1, 2 -> label 2, 3 -> PADDING]
        // node 2 (parent of leaves 2,3) is forced left.
        Tree {
            aux_dim: 2,
            num_classes: 3,
            num_leaves: 4,
            depth: 2,
            w: vec![
                1.0, 0.0, // root
                0.0, 1.0, // node 1
                0.0, 0.0, // node 2 (forced)
            ],
            b: vec![0.0, 0.5, 0.0],
            forced: vec![0, 0, -1],
            label_of_leaf: vec![0, 1, 2, PADDING],
            leaf_of_label: vec![0, 1, 2],
        }
    }

    #[test]
    fn log_prob_normalizes_over_real_labels() {
        let t = toy_tree();
        for x in [[0.3f32, -0.7], [2.0, 1.0], [-3.0, 0.1]] {
            let total: f64 = (0..3).map(|y| (t.log_prob(&x, y) as f64).exp()).sum();
            assert!((total - 1.0).abs() < 1e-6, "x {x:?} total {total}");
        }
    }

    #[test]
    fn log_prob_all_matches_single() {
        let t = toy_tree();
        let x = [0.8f32, -1.2];
        let mut all = vec![0f32; 3];
        t.log_prob_all(&x, &mut all);
        for y in 0..3u32 {
            assert!((all[y as usize] - t.log_prob(&x, y)).abs() < 1e-6);
        }
    }

    #[test]
    fn sampling_matches_log_prob() {
        let t = toy_tree();
        let x = [0.5f32, 0.5];
        let mut rng = Rng::new(42);
        let mut counts = [0usize; 3];
        let draws = 200_000;
        for _ in 0..draws {
            let (y, lp) = t.sample(&x, &mut rng);
            counts[y as usize] += 1;
            assert!((lp - t.log_prob(&x, y)).abs() < 1e-5);
        }
        for y in 0..3u32 {
            let expect = (t.log_prob(&x, y) as f64).exp();
            let got = counts[y as usize] as f64 / draws as f64;
            assert!(
                (got - expect).abs() < 0.006,
                "label {y}: got {got}, expect {expect}"
            );
        }
    }

    // (Blocked sample/log-prob parity tests live in `kernel::tests` and
    // the proptest parity suite, next to the TreeKernel they exercise.)

    #[test]
    fn padding_never_sampled() {
        let t = toy_tree();
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let (y, _) = t.sample(&[5.0, 5.0], &mut rng);
            assert!(y < 3);
        }
    }

    #[test]
    fn activations_roundtrip() {
        let t = toy_tree();
        let x = [1.0f32, 2.0];
        let mut acts = vec![0f32; t.num_nodes()];
        t.node_activations(&x, &mut acts);
        let mut a = vec![0f32; 3];
        let mut b = vec![0f32; 3];
        t.log_prob_all(&x, &mut a);
        t.log_prob_all_from_activations(&acts, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn json_roundtrip() {
        let t = toy_tree();
        let back = Tree::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.w, t.w);
        assert_eq!(back.b, t.b);
        assert_eq!(back.forced, t.forced);
        assert_eq!(back.label_of_leaf, t.label_of_leaf);
        assert_eq!(back.leaf_of_label, t.leaf_of_label);
    }

    /// End-to-end: fitted tree on separable clusters should put most mass
    /// on the right cluster. (More fit tests in fit.rs.)
    #[test]
    fn fitted_tree_is_conditional() {
        let k = 2;
        let c = 4;
        let n = 2000;
        let mut rng = Rng::new(9);
        // 4 well-separated clusters at (+-3, +-3)
        let centers = [[3.0f32, 3.0], [-3.0, 3.0], [3.0, -3.0], [-3.0, -3.0]];
        let mut x = vec![0f32; n * k];
        let mut y = vec![0u32; n];
        for i in 0..n {
            let lbl = rng.below(c);
            y[i] = lbl as u32;
            x[i * 2] = centers[lbl][0] + 0.3 * rng.normal();
            x[i * 2 + 1] = centers[lbl][1] + 0.3 * rng.normal();
        }
        let cfg = TreeConfig { aux_dim: k, ..TreeConfig::default() };
        let (tree, _stats) = fit::fit_tree(&x, &y, n, k, c, &cfg, &mut rng);
        // each training point's own label should have high conditional prob
        let mut correct = 0;
        for i in 0..200 {
            let xi = &x[i * 2..i * 2 + 2];
            let mut lps = vec![0f32; c];
            tree.log_prob_all(xi, &mut lps);
            let argmax = (0..c).max_by(|&a, &b| lps[a].total_cmp(&lps[b])).unwrap();
            if argmax as u32 == y[i] {
                correct += 1;
            }
        }
        assert!(correct > 180, "only {correct}/200 correct");
    }
}
