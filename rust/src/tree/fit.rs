//! Greedy maximum-likelihood fitting of the auxiliary tree (paper Sec. 3).
//!
//! Top-down over the balanced tree: each node ν alternates between
//!
//!  * **continuous** maximization of Eq. 8 over (w_ν, b_ν) by Newton ascent
//!    (the objective is concave; (k+1)-dim Hessian solved by Cholesky —
//!    hyperparameter-free, as the paper emphasizes), and
//!  * **discrete** re-splitting of the node's label set into equal halves
//!    by the score Δ_y = Σ_{x∈D_y}(w_ν·x + b_ν) = w_ν·S_y + n_y b_ν
//!    (Eq. 9) — note Δ_y only needs the per-label sufficient statistics
//!    (S_y = Σ x, n_y), gathered once per node.
//!
//! Initialization follows the paper: b_ν = 0 and w_ν set to the dominant
//! eigenvector of the covariance of the per-label sum vectors {S_y}.
//! Nodes whose subtree holds ≤1 real label become deterministic `forced`
//! chains with p = 1 (padding handling).
//!
//! # Parallel fitting
//!
//! Nodes at the same depth own disjoint label-slot ranges, disjoint point
//! ranges, and disjoint subtrees, so the tree is fitted **level by level**:
//! the whole frontier of one depth runs concurrently over a [`Pool`]
//! ([`fit_tree_with`]), then the next frontier is assembled in node order.
//! Each node draws its initialization from an RNG stream that is a pure
//! function of `(caller state, node index)` ([`Rng::stream`]), and all
//! shared buffers are written through range-disjoint [`SharedMut`] views,
//! so the fitted tree is **bit-identical at every worker count** —
//! including the serial wrapper [`fit_tree`].

use super::{Forced, Tree, TreeKernel, PADDING};
use crate::config::TreeConfig;
use crate::linalg::pca::dominant_eigenvector;
use crate::linalg::{dot_f64, dot_f64_f32, sigmoid64, solve_spd};
use crate::utils::{Pool, Rng, SharedMut, StopWatch};

/// RNG stream domain for per-node initialization draws: node `i` uses
/// `base.stream(STREAM_FIT_NODE, i)`, independent of fitting order.
const STREAM_FIT_NODE: u64 = 11;

/// Block size of the post-fit mean-log-likelihood sweep (rows per blocked
/// `TreeKernel::log_prob_batch` call).
const LOGLIK_BLOCK: usize = 256;

/// Diagnostics from one fitting run.
#[derive(Clone, Debug, Default)]
pub struct FitStats {
    pub nodes_fitted: usize,
    pub newton_iters_total: usize,
    pub alternations_total: usize,
    pub forced_nodes: usize,
    pub fit_seconds: f64,
    /// Wallclock per tree level of the level-synchronous frontier
    /// (index 0 = root level). Diagnostics only — not deterministic.
    pub level_seconds: Vec<f64>,
    /// Mean log-likelihood (Eq. 7 / N) on the data used for fitting.
    pub train_mean_loglik: f64,
}

struct NodeTask {
    node: usize,
    depth: usize,
    slot_lo: usize,
    slot_hi: usize,
    pt_lo: usize,
    pt_hi: usize,
}

/// Everything one frontier node produces; merged into [`FitStats`] and the
/// next frontier in node order, so aggregates are deterministic.
struct NodeOutcome {
    fitted: bool,
    newton_iters: usize,
    alternations: usize,
    forced_nodes: usize,
    children: [Option<NodeTask>; 2],
}

/// Fit a tree on projected features `x_proj` ([n, k] row-major), serially.
///
/// `rng` seeds the optional subsample shuffle and the per-node init
/// streams; it is advanced once per call (a stream split), not once per
/// node as in the old DFS fitter.
pub fn fit_tree(
    x_proj: &[f32],
    labels: &[u32],
    n: usize,
    k: usize,
    c: usize,
    cfg: &TreeConfig,
    rng: &mut Rng,
) -> (Tree, FitStats) {
    fit_tree_with(x_proj, labels, n, k, c, cfg, rng, &Pool::serial())
}

/// [`fit_tree`] with each tree level's node fits sharded over a worker
/// pool. The fitted tree is bit-identical at every worker count (see the
/// module docs for the determinism argument).
#[allow(clippy::too_many_arguments)]
pub fn fit_tree_with(
    x_proj: &[f32],
    labels: &[u32],
    n: usize,
    k: usize,
    c: usize,
    cfg: &TreeConfig,
    rng: &mut Rng,
    pool: &Pool,
) -> (Tree, FitStats) {
    assert!(c >= 2, "need at least two classes");
    assert_eq!(x_proj.len(), n * k);
    assert_eq!(labels.len(), n);
    let t0 = StopWatch::started();

    let num_leaves = c.next_power_of_two();
    let depth = num_leaves.trailing_zeros() as usize;
    let num_nodes = num_leaves - 1;

    let mut tree = Tree {
        aux_dim: k,
        num_classes: c,
        num_leaves,
        depth,
        w: vec![0f32; num_nodes * k],
        b: vec![0f32; num_nodes],
        forced: vec![0 as Forced; num_nodes],
        label_of_leaf: vec![PADDING; num_leaves],
        leaf_of_label: vec![0u32; c],
    };
    let mut stats = FitStats::default();

    // label slots: real labels packed as a prefix of each node's range.
    let mut label_order: Vec<u32> = (0..c as u32).chain((c..num_leaves).map(|_| PADDING)).collect();
    let mut slot_of_label: Vec<u32> = (0..c as u32).collect();

    // points used for fitting (optionally subsampled)
    let mut point_order: Vec<u32> = (0..n as u32).collect();
    if cfg.fit_subsample > 0 && cfg.fit_subsample < n {
        rng.shuffle(&mut point_order);
        point_order.truncate(cfg.fit_subsample);
    }
    let n_fit = point_order.len();

    // Per-node init streams derive from a split of the caller's RNG, so
    // node i's draws depend only on (caller state, i) — never on which
    // worker fits it or in what order. `split` also advances the caller's
    // generator, so back-to-back fits from one Rng stay independent.
    let base_rng = rng.split(STREAM_FIT_NODE);

    // scratch shared across nodes; each task uses its own point range
    let mut pt_scratch: Vec<u32> = vec![0; n_fit];
    let workers = pool.num_workers();

    let mut frontier: Vec<NodeTask> = vec![NodeTask {
        node: 0,
        depth: 0,
        slot_lo: 0,
        slot_hi: num_leaves,
        pt_lo: 0,
        pt_hi: n_fit,
    }];

    while !frontier.is_empty() {
        let lvl_t0 = StopWatch::started();
        let n_tasks = frontier.len();
        let mut outcomes: Vec<Option<NodeOutcome>> = Vec::with_capacity(n_tasks);
        outcomes.resize_with(n_tasks, || None);

        {
            let tasks = &frontier;
            let outcome_view = SharedMut::new(&mut outcomes);
            let w_view = SharedMut::new(&mut tree.w);
            let b_view = SharedMut::new(&mut tree.b);
            let forced_view = SharedMut::new(&mut tree.forced);
            let order_view = SharedMut::new(&mut label_order);
            let slot_view = SharedMut::new(&mut slot_of_label);
            let pts_view = SharedMut::new(&mut point_order);
            let scratch_view = SharedMut::new(&mut pt_scratch);
            let run_task = |i: usize| {
                let out = fit_node(
                    &tasks[i], x_proj, labels, k, depth, cfg, &base_rng, &w_view, &b_view,
                    &forced_view, &order_view, &slot_view, &pts_view, &scratch_view,
                );
                // SAFETY: outcome slot i has exactly one writer (this task).
                unsafe { *outcome_view.get_mut(i) = Some(out) };
            };
            if workers == 1 || n_tasks == 1 {
                for i in 0..n_tasks {
                    run_task(i);
                }
            } else {
                // Tasks shard round-robin; assignment is a pure function of
                // (task index, worker count) and tasks are independent, so
                // scheduling cannot affect the result.
                pool.run_sharded(|shard| {
                    let mut i = shard;
                    while i < n_tasks {
                        run_task(i);
                        i += workers;
                    }
                });
            }
        }

        // merge stats and assemble the next frontier in node order
        let mut next: Vec<NodeTask> = Vec::with_capacity(2 * n_tasks);
        for outcome in outcomes.into_iter().flatten() {
            stats.nodes_fitted += outcome.fitted as usize;
            stats.newton_iters_total += outcome.newton_iters;
            stats.alternations_total += outcome.alternations;
            stats.forced_nodes += outcome.forced_nodes;
            for child in outcome.children.into_iter().flatten() {
                next.push(child);
            }
        }
        stats.level_seconds.push(lvl_t0.elapsed_secs());
        frontier = next;
    }

    // ---- leaf mapping ----
    tree.label_of_leaf.copy_from_slice(&label_order);
    for (leaf, &lbl) in label_order.iter().enumerate() {
        if lbl != PADDING {
            tree.leaf_of_label[lbl as usize] = leaf as u32;
        }
    }

    stats.fit_seconds = t0.elapsed_secs();
    // Mean train log-likelihood over the fitted subsample, swept through
    // the freshly rebuilt blocked kernel. Each blocked row is bit-identical
    // to scalar `log_prob`, and the f64 accumulation runs in point order,
    // so the statistic equals a per-point scalar loop exactly (and
    // `Tree::mean_log_likelihood` on the full, unshuffled data).
    //
    // This kernel is local to the sweep; `AdversarialSampler::fit_with`
    // builds its own from the returned tree. The duplicate O(C·k) build is
    // deliberate — negligible next to the fit itself, and it keeps the
    // (Tree, FitStats) signature stable for the many fit_tree callers.
    let kernel = TreeKernel::build(&tree);
    let mut total = 0f64;
    let mut xb = vec![0f32; LOGLIK_BLOCK * k];
    let mut yb = vec![0u32; LOGLIK_BLOCK];
    let mut lp = vec![0f32; LOGLIK_BLOCK];
    let mut lo = 0;
    while lo < point_order.len() {
        let hi = (lo + LOGLIK_BLOCK).min(point_order.len());
        let mb = hi - lo;
        for (j, &p) in point_order[lo..hi].iter().enumerate() {
            let i = p as usize;
            xb[j * k..(j + 1) * k].copy_from_slice(&x_proj[i * k..(i + 1) * k]);
            yb[j] = labels[i];
        }
        kernel.log_prob_batch(&xb[..mb * k], &yb[..mb], &mut lp[..mb]);
        for &v in &lp[..mb] {
            total += v as f64;
        }
        lo = hi;
    }
    stats.train_mean_loglik = total / point_order.len().max(1) as f64;

    (tree, stats)
}

/// Fit one frontier node: gather sufficient statistics, alternate Newton
/// ascent with Δ-splits, commit parameters, and re-partition the node's
/// label slots and points for its children.
///
/// Shared-buffer contract (why the `SharedMut` accesses below are sound):
/// within one level, tasks own disjoint `[slot_lo, slot_hi)` label-slot
/// ranges, disjoint `[pt_lo, pt_hi)` point ranges (scratch included),
/// disjoint subtrees (`w`/`b`/`forced`), and each label belongs to exactly
/// one task's range — so every index touched here has a single owner.
#[allow(clippy::too_many_arguments)]
fn fit_node(
    task: &NodeTask,
    x_proj: &[f32],
    labels: &[u32],
    k: usize,
    depth: usize,
    cfg: &TreeConfig,
    base_rng: &Rng,
    w_view: &SharedMut<f32>,
    b_view: &SharedMut<f32>,
    forced_view: &SharedMut<Forced>,
    order_view: &SharedMut<u32>,
    slot_view: &SharedMut<u32>,
    pts_view: &SharedMut<u32>,
    scratch_view: &SharedMut<u32>,
) -> NodeOutcome {
    let mut out = NodeOutcome {
        fitted: false,
        newton_iters: 0,
        alternations: 0,
        forced_nodes: 0,
        children: [None, None],
    };
    let cap = task.slot_hi - task.slot_lo;
    debug_assert!(cap >= 2);
    let ccap = cap / 2;
    let n_pts = task.pt_hi - task.pt_lo;

    // SAFETY: this task exclusively owns slot range [slot_lo, slot_hi) and
    // point range [pt_lo, pt_hi) of all three buffers (see fn docs).
    let node_slots = unsafe { order_view.slice_mut(task.slot_lo, cap) };
    let pts = unsafe { pts_view.slice_mut(task.pt_lo, n_pts) };
    let scratch = unsafe { scratch_view.slice_mut(task.pt_lo, n_pts) };

    // real labels are a prefix of the slot range
    let n_r = node_slots.iter().take_while(|&&l| l != PADDING).count();

    if n_r == 0 {
        return out; // unreachable subtree; params stay zero
    }
    if n_r == 1 {
        // deterministic chain: the lone label sits at the leftmost leaf
        let mut cur = task.node;
        let mut d = task.depth;
        while d < depth {
            // SAFETY: `cur` stays strictly inside this task's subtree.
            unsafe { *forced_view.get_mut(cur) = -1 };
            out.forced_nodes += 1;
            cur = 2 * cur + 1;
            d += 1;
        }
        return out;
    }

    // ---- per-label sufficient statistics over the node's points ----
    let mut sums = vec![0f64; n_r * k]; // S_y
    let mut counts = vec![0u64; n_r];
    // local label index per point, reused by the Newton objective
    let mut pt_local = vec![0u32; n_pts];
    for (j, &p) in pts.iter().enumerate() {
        let y = labels[p as usize] as usize;
        // SAFETY: label y lies in this node's slot range; its slot entry
        // has no other reader or writer this level.
        let local = (unsafe { *slot_view.get_mut(y) } as usize) - task.slot_lo;
        debug_assert!(local < n_r);
        pt_local[j] = local as u32;
        let row = &x_proj[p as usize * k..(p as usize + 1) * k];
        let dst = &mut sums[local * k..(local + 1) * k];
        for (d, v) in dst.iter_mut().zip(row.iter()) {
            *d += *v as f64;
        }
        counts[local] += 1;
    }

    // ---- init: w = dominant eigenvector of Cov({S_y}), b = 0 ----
    let mut node_rng = base_rng.stream(STREAM_FIT_NODE, task.node as u64);
    let mut w = init_weight(&sums, n_r, k, &mut node_rng);
    let mut b = 0f64;

    // ---- alternate Newton ascent and balanced re-splits ----
    // right-child count r, clamped so both halves fit their capacity
    let r = (n_r + 1) / 2;
    let r = r.max(n_r.saturating_sub(ccap)).min(ccap);
    let mut zeta = split_by_delta(&sums, &counts, &w, b, n_r, k, r);
    let mut converged = false;
    for _alt in 0..cfg.max_alternations {
        out.alternations += 1;
        let iters = newton_ascent(
            x_proj, pts, &pt_local, &zeta, k, cfg.lambda_n, cfg.newton_iters, &mut w, &mut b,
        );
        out.newton_iters += iters;
        let new_zeta = split_by_delta(&sums, &counts, &w, b, n_r, k, r);
        if new_zeta == zeta {
            converged = true;
            break;
        }
        zeta = new_zeta;
    }
    let _ = converged;
    out.fitted = true;

    // ---- commit node parameters ----
    // SAFETY: node `task.node` belongs to this task alone.
    let w_dst = unsafe { w_view.slice_mut(task.node * k, k) };
    for (dst, src) in w_dst.iter_mut().zip(w.iter()) {
        *dst = *src as f32;
    }
    unsafe { *b_view.get_mut(task.node) = b as f32 };

    // ---- reorder label slots: left prefix | pad | right prefix | pad ----
    let slot_mid = task.slot_lo + ccap;
    {
        let mut left: Vec<u32> = Vec::with_capacity(ccap);
        let mut right: Vec<u32> = Vec::with_capacity(ccap);
        for (local, &z) in zeta.iter().enumerate() {
            let lbl = node_slots[local];
            if z {
                right.push(lbl);
            } else {
                left.push(lbl);
            }
        }
        debug_assert_eq!(right.len(), r);
        for s in node_slots.iter_mut() {
            *s = PADDING;
        }
        node_slots[..left.len()].copy_from_slice(&left);
        node_slots[ccap..ccap + right.len()].copy_from_slice(&right);
    }
    for (off, &lbl) in node_slots.iter().enumerate() {
        if lbl != PADDING {
            // SAFETY: each label belongs to exactly one frontier task.
            unsafe { *slot_view.get_mut(lbl as usize) = (task.slot_lo + off) as u32 };
        }
    }

    // ---- partition points by their label's side ----
    let mut nl = 0usize;
    let mut nr_pts = 0usize;
    for &p in pts.iter() {
        let y = labels[p as usize] as usize;
        // SAFETY: as above — this task's labels only.
        let slot = unsafe { *slot_view.get_mut(y) } as usize;
        if slot < slot_mid {
            scratch[nl] = p;
            nl += 1;
        } else {
            nr_pts += 1;
            scratch[n_pts - nr_pts] = p;
        }
    }
    // right side was written back-to-front; reverse for stability
    scratch[nl..].reverse();
    pts.copy_from_slice(scratch);
    let pt_mid = task.pt_lo + nl;

    // ---- children ----
    if task.depth + 1 < depth {
        out.children[0] = Some(NodeTask {
            node: 2 * task.node + 1,
            depth: task.depth + 1,
            slot_lo: task.slot_lo,
            slot_hi: slot_mid,
            pt_lo: task.pt_lo,
            pt_hi: pt_mid,
        });
        out.children[1] = Some(NodeTask {
            node: 2 * task.node + 2,
            depth: task.depth + 1,
            slot_lo: slot_mid,
            slot_hi: task.slot_hi,
            pt_lo: pt_mid,
            pt_hi: task.pt_hi,
        });
    }
    out
}

/// Paper's init: dominant eigenvector of the covariance of {S_y}.
fn init_weight(sums: &[f64], n_r: usize, k: usize, rng: &mut Rng) -> Vec<f64> {
    let mut mean = vec![0f64; k];
    for s in sums.chunks_exact(k) {
        for (m, v) in mean.iter_mut().zip(s.iter()) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= n_r as f64;
    }
    let mut cov = vec![0f64; k * k];
    for s in sums.chunks_exact(k) {
        for i in 0..k {
            let di = s[i] - mean[i];
            for j in 0..k {
                cov[i * k + j] += di * (s[j] - mean[j]);
            }
        }
    }
    for v in cov.iter_mut() {
        *v /= n_r as f64;
    }
    dominant_eigenvector(&cov, k, 40, rng)
        .into_iter()
        .map(|v| v as f64)
        .collect()
}

/// Δ_y = w·S_y + n_y·b for all labels; returns the balanced assignment
/// (true = right child) giving the top-`r` labels by Δ to the right.
fn split_by_delta(
    sums: &[f64],
    counts: &[u64],
    w: &[f64],
    b: f64,
    n_r: usize,
    k: usize,
    r: usize,
) -> Vec<bool> {
    let mut delta: Vec<(f64, usize)> = (0..n_r)
        .map(|local| {
            let s = &sums[local * k..(local + 1) * k];
            let d: f64 = dot_f64(w, s) + counts[local] as f64 * b;
            (d, local)
        })
        .collect();
    // sort desc by Δ, ties by label slot for determinism
    delta.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut zeta = vec![false; n_r];
    for &(_, local) in delta.iter().take(r) {
        zeta[local] = true;
    }
    zeta
}

/// Newton ascent on the concave node objective (Eq. 8 with L2 term):
///   L_ν(w, b) = Σ_pts log σ(ζ_y (w·x + b)) − λ_n (‖w‖² + b²).
///
/// Damped with Armijo backtracking: plain Newton is only locally
/// convergent for logistic likelihoods — on an *unfittable* split (two
/// statistically identical label halves, common deep in the tree) the
/// curvature flattens while the gradient stays finite and raw Newton
/// steps diverge. Backtracking on the true objective restores the global
/// convergence the concavity guarantees. Returns iterations performed.
///
/// The sigmoid feeding the gradient/Hessian must be evaluated in f64: the
/// Armijo objective is full f64, so an f32-rounded σ(a) near the optimum
/// yields a step inconsistent with the objective and stalls backtracking.
///
/// `pt_local[j]` is the ζ index of point `pts[j]` (precomputed by the
/// caller during the sufficient-statistics gather).
#[allow(clippy::too_many_arguments)]
fn newton_ascent(
    x_proj: &[f32],
    pts: &[u32],
    pt_local: &[u32],
    zeta: &[bool],
    k: usize,
    lambda_n: f64,
    max_iters: usize,
    w: &mut Vec<f64>,
    b: &mut f64,
) -> usize {
    debug_assert_eq!(pts.len(), pt_local.len());
    let dim = k + 1;
    let mut grad = vec![0f64; dim];
    let mut hess = vec![0f64; dim * dim];

    let zeta_of = |j: usize| -> f64 {
        if zeta[pt_local[j] as usize] {
            1.0
        } else {
            -1.0
        }
    };
    // objective value at (w, b)
    let objective = |w: &[f64], b: f64| -> f64 {
        let mut obj = 0f64;
        for (j, &p) in pts.iter().enumerate() {
            let i = p as usize;
            let x = &x_proj[i * k..(i + 1) * k];
            let a: f64 = dot_f64_f32(w, x) + b;
            let za = zeta_of(j) * a;
            // log sigma(za), stable
            obj += za.min(0.0) - (-za.abs()).exp().ln_1p();
        }
        obj - lambda_n * (dot_f64(w, w) + b * b)
    };

    let mut obj = objective(w, *b);
    let mut iters = 0;
    for _ in 0..max_iters {
        iters += 1;
        grad.iter_mut().for_each(|g| *g = 0.0);
        hess.iter_mut().for_each(|h| *h = 0.0);
        for (jp, &p) in pts.iter().enumerate() {
            let i = p as usize;
            let z = zeta_of(jp);
            let x = &x_proj[i * k..(i + 1) * k];
            let a: f64 = dot_f64_f32(w, x) + *b;
            let s = sigmoid64(a);
            // ∇ log σ(ζa) = ζ σ(−ζa) x̃ ;  σ(−ζa) = if ζ>0 {1−s} else {s}
            let gcoef = z * if z > 0.0 { 1.0 - s } else { s };
            let hcoef = s * (1.0 - s); // −∂² is σσ′ x̃x̃ᵀ
            for j in 0..k {
                grad[j] += gcoef * x[j] as f64;
            }
            grad[k] += gcoef;
            // accumulate upper triangle of H
            for j in 0..k {
                let xj = x[j] as f64 * hcoef;
                let row = &mut hess[j * dim..];
                for l in j..k {
                    row[l] += xj * x[l] as f64;
                }
                row[k] += xj;
            }
            hess[k * dim + k] += hcoef;
        }
        // regularizer: −λ_n(‖w‖²+b²) → grad −= 2λ_n θ ; H += 2λ_n I
        for j in 0..k {
            grad[j] -= 2.0 * lambda_n * w[j];
        }
        grad[k] -= 2.0 * lambda_n * *b;
        for j in 0..dim {
            hess[j * dim + j] += 2.0 * lambda_n;
            for l in 0..j {
                hess[j * dim + l] = hess[l * dim + j]; // mirror
            }
        }

        let gnorm: f64 = dot_f64(&grad, &grad).sqrt();
        if gnorm < 1e-8 * (pts.len() as f64).max(1.0) {
            break;
        }
        let Some(step) = solve_spd(&hess, &grad, dim) else { break };

        // Armijo backtracking: accept the largest t in {1, 1/2, ...} with
        // obj(θ + tδ) ≥ obj(θ) + c t ∇L·δ  (c = 1e-4; ∇L·δ > 0 by SPD).
        let gdotd: f64 = dot_f64(&grad, &step);
        let mut t = 1.0f64;
        let mut accepted = false;
        for _ in 0..30 {
            let wt: Vec<f64> = w.iter().zip(step.iter()).map(|(wv, d)| wv + t * d).collect();
            let bt = *b + t * step[k];
            let new_obj = objective(&wt, bt);
            if new_obj >= obj + 1e-4 * t * gdotd {
                *w = wt;
                *b = bt;
                obj = new_obj;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            break; // numerically flat — we're done
        }
        let snorm: f64 = dot_f64(&step, &step).sqrt();
        if t * snorm < 1e-10 {
            break;
        }
    }
    iters
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_cluster_data(n: usize, k: usize, rng: &mut Rng) -> (Vec<f32>, Vec<u32>) {
        // labels 0,1 at x ~ N(-2,0.5), labels 2,3 at x ~ N(+2,0.5) in dim 0
        let mut x = vec![0f32; n * k];
        let mut y = vec![0u32; n];
        for i in 0..n {
            let lbl = rng.below(4) as u32;
            y[i] = lbl;
            let center = if lbl < 2 { -2.0 } else { 2.0 };
            x[i * k] = center + 0.5 * rng.normal();
            for j in 1..k {
                x[i * k + j] = 0.1 * rng.normal();
            }
        }
        (x, y)
    }

    #[test]
    fn root_split_separates_clusters() {
        let mut rng = Rng::new(1);
        let (x, y) = two_cluster_data(4000, 4, &mut rng);
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let (tree, stats) = fit_tree(&x, &y, 4000, 4, 4, &cfg, &mut rng);
        assert_eq!(tree.depth, 2);
        assert!(stats.nodes_fitted >= 3);
        // root must separate {0,1} from {2,3}
        let side = |lbl: u32| tree.leaf_of_label[lbl as usize] / 2;
        assert_eq!(side(0), side(1));
        assert_eq!(side(2), side(3));
        assert_ne!(side(0), side(2));
    }

    #[test]
    fn fitted_loglik_beats_uniform() {
        let mut rng = Rng::new(2);
        let (x, y) = two_cluster_data(4000, 4, &mut rng);
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let (tree, stats) = fit_tree(&x, &y, 4000, 4, 4, &cfg, &mut rng);
        let uniform = -(4f64).ln();
        assert!(
            stats.train_mean_loglik > uniform + 0.4,
            "loglik {} vs uniform {}",
            stats.train_mean_loglik,
            uniform
        );
        let full = tree.mean_log_likelihood(&x, &y);
        assert!((full - stats.train_mean_loglik).abs() < 1e-9);
    }

    #[test]
    fn non_power_of_two_labels_get_padding() {
        let mut rng = Rng::new(3);
        let c = 5; // -> 8 leaves, 3 padding
        let n = 2000;
        let k = 3;
        let mut x = vec![0f32; n * k];
        let mut y = vec![0u32; n];
        for i in 0..n {
            y[i] = rng.below(c) as u32;
            for j in 0..k {
                x[i * k + j] = y[i] as f32 + 0.3 * rng.normal();
            }
        }
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, &mut rng);
        assert_eq!(tree.num_leaves, 8);
        let pad_leaves = tree.label_of_leaf.iter().filter(|&&l| l == PADDING).count();
        assert_eq!(pad_leaves, 3);
        // normalization over real labels only
        let mut lps = vec![0f32; c];
        tree.log_prob_all(&x[..k], &mut lps);
        let total: f64 = lps.iter().map(|&l| (l as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5, "total {total}");
        // sampling never yields padding and matches probabilities
        for _ in 0..5000 {
            let (s, lp) = tree.sample(&x[..k], &mut rng);
            assert!((s as usize) < c);
            assert!(lp.is_finite());
        }
    }

    #[test]
    fn subsample_cap_respected() {
        let mut rng = Rng::new(4);
        let (x, y) = two_cluster_data(3000, 4, &mut rng);
        let cfg = TreeConfig { aux_dim: 4, fit_subsample: 500, ..Default::default() };
        let (tree, stats) = fit_tree(&x, &y, 3000, 4, 4, &cfg, &mut rng);
        assert!(stats.train_mean_loglik.is_finite());
        assert!(tree.mean_log_likelihood(&x, &y) > -(4f64).ln() - 0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng1 = Rng::new(5);
        let (x, y) = two_cluster_data(1000, 4, &mut rng1);
        let mut ra = Rng::new(9);
        let mut rb = Rng::new(9);
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let (ta, _) = fit_tree(&x, &y, 1000, 4, 4, &cfg, &mut ra);
        let (tb, _) = fit_tree(&x, &y, 1000, 4, 4, &cfg, &mut rb);
        assert_eq!(ta.w, tb.w);
        assert_eq!(ta.label_of_leaf, tb.label_of_leaf);
    }

    #[test]
    fn parallel_fit_bit_identical_small() {
        let mut rng = Rng::new(8);
        let (x, y) = two_cluster_data(2000, 4, &mut rng);
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let mut r0 = Rng::new(3);
        let (reference, rstats) = fit_tree(&x, &y, 2000, 4, 4, &cfg, &mut r0);
        for workers in [2, 3, 7] {
            let mut r = Rng::new(3);
            let (t, s) = fit_tree_with(&x, &y, 2000, 4, 4, &cfg, &mut r, &Pool::new(workers));
            assert_eq!(t.w, reference.w, "workers={workers}");
            assert_eq!(t.b, reference.b, "workers={workers}");
            assert_eq!(t.label_of_leaf, reference.label_of_leaf, "workers={workers}");
            assert_eq!(s.nodes_fitted, rstats.nodes_fitted);
            assert_eq!(s.newton_iters_total, rstats.newton_iters_total);
        }
    }

    #[test]
    fn fit_advances_caller_rng() {
        // back-to-back fits from one Rng must not reuse the same per-node
        // streams: the split inside fit_tree advances the caller state
        let mut data_rng = Rng::new(8);
        let (x, y) = two_cluster_data(1000, 4, &mut data_rng);
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let mut rng = Rng::new(77);
        let mut untouched = rng.clone();
        let _ = fit_tree(&x, &y, 1000, 4, 4, &cfg, &mut rng);
        assert_ne!(
            rng.next_u64(),
            untouched.next_u64(),
            "fit_tree must advance the caller rng"
        );
    }

    #[test]
    fn level_timings_cover_every_level() {
        let mut rng = Rng::new(12);
        let (x, y) = two_cluster_data(1000, 4, &mut rng);
        let cfg = TreeConfig { aux_dim: 4, ..Default::default() };
        let (tree, stats) = fit_tree(&x, &y, 1000, 4, 4, &cfg, &mut rng);
        assert_eq!(stats.level_seconds.len(), tree.depth);
        assert!(stats.level_seconds.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn larger_c_all_labels_mapped() {
        let mut rng = Rng::new(6);
        let c = 100;
        let n = 4000;
        let k = 6;
        let mut x = vec![0f32; n * k];
        let mut y = vec![0u32; n];
        for i in 0..n {
            let lbl = rng.below(c) as u32;
            y[i] = lbl;
            for j in 0..k {
                x[i * k + j] = ((lbl as usize >> (j % 7)) & 1) as f32 * 2.0 - 1.0
                    + 0.4 * rng.normal();
            }
        }
        let cfg = TreeConfig { aux_dim: k, ..Default::default() };
        let (tree, _) = fit_tree(&x, &y, n, k, c, &cfg, &mut rng);
        // bijection between real labels and leaves
        let mut seen = vec![false; c];
        for &lbl in tree.label_of_leaf.iter().filter(|&&l| l != PADDING) {
            assert!(!seen[lbl as usize], "label {lbl} mapped twice");
            seen[lbl as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        for lbl in 0..c as u32 {
            assert_eq!(tree.label_of_leaf[tree.leaf_of_label[lbl as usize] as usize], lbl);
        }
    }
}
