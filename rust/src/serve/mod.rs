//! Serving subsystem: immutable model snapshots, tree-guided top-k
//! prediction, and a batched predict pipeline over the worker pool.
//!
//! The paper's auxiliary tree answers class-probability queries in
//! O(log C) — exactly the structure serving needs. Prediction is the
//! retrieve-then-rank recipe used by production extreme-classification
//! systems: a beam-search descent of the tree
//! ([`crate::tree::TreeKernel::beam_topk`]) proposes the `2·beam` most
//! probable labels under `q(·|x)` in O(beam · d · log C), and the trained
//! classifier rows re-rank the candidates **exactly** through the shared
//! [`Scorer`] core — each candidate's score is bit-identical to the same
//! label's entry in the exact O(C) sweep, so beam + re-rank reproduces
//! the oracle's ranking whenever the candidate set covers it. The exact
//! sweep stays available as the oracle ([`ServeConfig::exact`]).
//!
//! # Determinism contract
//!
//! Prediction is a pure per-query function: rows shard over the [`Pool`]
//! in contiguous spans with one writer per row and no cross-row reduction,
//! so results are **bit-identical** at every `parallelism` setting and for
//! batched vs one-at-a-time submission — the same discipline as the
//! training hot path (PR 1–4). The [`RequestBatcher`] coalesces
//! individually submitted queries into one block (lane-width tiles inside
//! the scorer) and returns results in submission order.
//!
//! # Pieces
//!
//! * [`ServingModel`] — an immutable checkpoint: classifier rows (no
//!   Adagrad state) + the auxiliary sampler (PCA + tree + kernel),
//!   JSON-serializable (`repro train --save-model` writes one).
//! * [`Predictor`] — top-k prediction over a model under a
//!   [`ServeConfig`]; [`Predictor::predict_batch_with`] is the batched
//!   pool-sharded entry point.
//! * [`RequestBatcher`] — request coalescing for one-at-a-time callers.
//! * [`evaluate_serving`] — P@1 / recall@k on held-out data
//!   (`repro serve --eval`).
//! * [`daemon`] — the fault-tolerant long-lived request loop
//!   (`repro serve --daemon`): bounded admission, deadline-aware
//!   micro-batching, graceful beam degradation, supervised workers.
//!
//! # Quantized serving
//!
//! [`ServeConfig::quantize`] (`repro serve --quantize`, `REPRO_QUANTIZE`)
//! stores the classifier rows as f16 (or i8 + per-row scale) inside the
//! predictor, halving (quartering) the bytes the re-rank sweep streams.
//! Quantization happens **once at [`Predictor::new`]** — prediction
//! decodes rows inline and accumulates in f32 through the same canonical
//! [`Scorer`] kernels, so quantized serving is bit-identical to
//! quantize-then-score with f32 rows, at every worker count. The f32
//! checkpoint itself is never modified.

pub mod daemon;

use crate::config::{QuantMode, ServeConfig};
use crate::data::Dataset;
use crate::linalg::{f16_from_f32, quantize_row_i8};
use crate::model::ParamStore;
use crate::sampler::AdversarialSampler;
use crate::score::{self, RowStore, ScoreScratch, Scorer};
use crate::tree::{BeamScratch, LANES};
use crate::utils::json::Json;
use crate::utils::{Pool, SharedMut, PAR_MIN_MERGE_ROWS};
use anyhow::Result;
use std::path::Path;

/// Label slot left unfilled when a query yields fewer than k candidates
/// (possible only when `2·beam < k`).
const PAD_LABEL: u32 = u32::MAX;

/// An immutable serving checkpoint: the trained classifier rows plus the
/// frozen auxiliary model, with no optimizer state. Loaded once, shared
/// read-only across every worker of the predict pipeline.
#[derive(Clone, Debug)]
pub struct ServingModel {
    pub num_classes: usize,
    pub feat_dim: usize,
    /// Row-major `[C, K]` classifier weights.
    pub w: Vec<f32>,
    /// `[C]` classifier biases.
    pub b: Vec<f32>,
    /// Auxiliary model (PCA + tree + kernel): candidate retrieval for the
    /// beam path, Eq. 5 correction when `correct_bias` is set.
    pub aux: Option<AdversarialSampler>,
    /// Score with the Eq. 5 correction `ξ + log p_n` (true for models
    /// trained with the adversarial method — `Method::corrects_bias`).
    pub correct_bias: bool,
}

impl ServingModel {
    /// Snapshot a training run's parameters + auxiliary model.
    pub fn from_parts(
        params: &ParamStore,
        aux: Option<&AdversarialSampler>,
        correct_bias: bool,
    ) -> Self {
        assert!(
            !correct_bias || aux.is_some(),
            "bias correction needs the auxiliary model"
        );
        Self {
            num_classes: params.num_classes,
            feat_dim: params.feat_dim,
            w: params.w.clone(),
            b: params.b.clone(),
            aux: aux.cloned(),
            correct_bias,
        }
    }

    /// The model's canonical scorer (corrected iff `correct_bias`).
    pub fn scorer(&self) -> Scorer<'_> {
        let corrector = if self.correct_bias { self.aux.as_ref() } else { None };
        Scorer::new(&self.w, &self.b, self.feat_dim, corrector)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_classes", Json::Num(self.num_classes as f64)),
            ("feat_dim", Json::Num(self.feat_dim as f64)),
            ("w", Json::arr_f32(&self.w)),
            ("b", Json::arr_f32(&self.b)),
            ("correct_bias", Json::Bool(self.correct_bias)),
            (
                "aux",
                match &self.aux {
                    Some(adv) => adv.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let num_classes = v.get("num_classes")?.as_usize()?;
        let feat_dim = v.get("feat_dim")?.as_usize()?;
        let aux = match v.opt("aux") {
            None | Some(Json::Null) => None,
            Some(a) => Some(AdversarialSampler::from_json(a)?),
        };
        let m = Self {
            num_classes,
            feat_dim,
            w: v.get("w")?.to_vec_f32()?,
            b: v.get("b")?.to_vec_f32()?,
            correct_bias: v.get("correct_bias")?.as_bool()?,
            aux,
        };
        anyhow::ensure!(m.num_classes >= 1 && m.feat_dim >= 1, "empty model shape");
        anyhow::ensure!(
            m.w.len() == m.num_classes * m.feat_dim,
            "w size {} != C*K = {}",
            m.w.len(),
            m.num_classes * m.feat_dim
        );
        anyhow::ensure!(
            m.b.len() == m.num_classes,
            "b size {} != C = {}",
            m.b.len(),
            m.num_classes
        );
        if let Some(adv) = &m.aux {
            anyhow::ensure!(
                adv.pca.input_dim == m.feat_dim,
                "aux PCA input dim {} != model feat dim {}",
                adv.pca.input_dim,
                m.feat_dim
            );
            anyhow::ensure!(
                adv.tree.num_classes == m.num_classes,
                "aux tree C {} != model C {}",
                adv.tree.num_classes,
                m.num_classes
            );
        }
        anyhow::ensure!(
            !m.correct_bias || m.aux.is_some(),
            "correct_bias set but checkpoint has no auxiliary model"
        );
        Ok(m)
    }

    /// Crash-safe checkpoint write: the payload goes to a temp file in the
    /// target directory (same filesystem, so the rename is atomic) and
    /// replaces `path` only once fully written — a crash mid-save leaves
    /// any previous checkpoint intact, never a truncated one.
    pub fn save(&self, path: &Path) -> Result<()> {
        use anyhow::Context;
        let dir = match path.parent() {
            Some(d) if !d.as_os_str().is_empty() => d,
            _ => Path::new("."),
        };
        let stem = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("serving_model.json");
        let tmp = dir.join(format!(".{stem}.tmp.{}", std::process::id()));
        if let Err(e) = std::fs::write(&tmp, self.to_json().to_string()) {
            std::fs::remove_file(&tmp).ok();
            return Err(e).with_context(|| format!("write checkpoint temp file {}", tmp.display()));
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            std::fs::remove_file(&tmp).ok();
            return Err(e)
                .with_context(|| format!("atomically replace checkpoint {}", path.display()));
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        use anyhow::Context;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read serving model {}", path.display()))?;
        let json = Json::parse(&text)
            .with_context(|| format!("parse serving model {}", path.display()))?;
        Self::from_json(&json).with_context(|| format!("invalid serving model {}", path.display()))
    }
}

/// Top-k predictions for one query: labels with their scores, best first
/// (ties toward the smaller label id).
#[derive(Clone, Debug, PartialEq)]
pub struct TopK {
    pub labels: Vec<u32>,
    pub scores: Vec<f32>,
}

/// Per-worker prediction scratch (grown once per span, reused per row).
struct PredictScratch {
    score: ScoreScratch,
    beam: BeamScratch,
    dense: Vec<f32>,
    cands: Vec<(u32, f32)>,
    cand_labels: Vec<u32>,
    cand_scores: Vec<f32>,
    topk: Vec<(u32, f32)>,
}

impl PredictScratch {
    fn new() -> Self {
        Self {
            score: ScoreScratch::default(),
            beam: BeamScratch::default(),
            dense: Vec::new(),
            cands: Vec::new(),
            cand_labels: Vec::new(),
            cand_scores: Vec::new(),
            topk: Vec::new(),
        }
    }
}

/// Owned quantized copies of the classifier rows, built once per
/// predictor when [`ServeConfig::quantize`] asks for them.
enum QuantRows {
    None,
    F16(Vec<u16>),
    I8 { q: Vec<i8>, scales: Vec<f32> },
}

/// Top-k predictor over an immutable [`ServingModel`] under a
/// [`ServeConfig`]. Cheap to construct (quantized modes pay one encode
/// pass over the rows); holds no mutable state, so one predictor is
/// shared read-only by every pool worker.
pub struct Predictor<'a> {
    model: &'a ServingModel,
    cfg: ServeConfig,
    /// Effective k (requested k clamped to C).
    k: usize,
    quant: QuantRows,
}

impl<'a> Predictor<'a> {
    pub fn new(model: &'a ServingModel, cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        if !cfg.exact {
            anyhow::ensure!(
                model.aux.is_some(),
                "beam prediction needs the auxiliary tree; use exact=true \
                 for models without one"
            );
        }
        let quant = match cfg.quantize {
            QuantMode::Off => QuantRows::None,
            QuantMode::F16 => QuantRows::F16(model.w.iter().map(|&v| f16_from_f32(v)).collect()),
            QuantMode::I8 => {
                let k = model.feat_dim;
                let mut q = vec![0i8; model.w.len()];
                let scales = model
                    .w
                    .chunks_exact(k)
                    .zip(q.chunks_exact_mut(k))
                    .map(|(row, qrow)| quantize_row_i8(row, qrow))
                    .collect();
                QuantRows::I8 { q, scales }
            }
        };
        Ok(Self { model, cfg, k: cfg.k.min(model.num_classes), quant })
    }

    /// The predictor's scorer: the model's rows in the configured storage
    /// format (corrected iff the model corrects bias). `QuantMode::Off`
    /// is exactly [`ServingModel::scorer`].
    fn scorer(&self) -> Scorer<'_> {
        let rows = match &self.quant {
            QuantRows::None => return self.model.scorer(),
            QuantRows::F16(w) => RowStore::F16(w),
            QuantRows::I8 { q, scales } => RowStore::I8 { q, scales },
        };
        let corrector = if self.model.correct_bias { self.model.aux.as_ref() } else { None };
        Scorer::over_rows(rows, &self.model.b, self.model.feat_dim, corrector)
    }

    /// Predictions per query (requested k clamped to C).
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Top-k for a single query (the m = 1 batch; bit-identical to the
    /// same row inside any batch).
    pub fn predict_one(&self, x: &[f32]) -> TopK {
        self.predict_batch_with(x, 1, &Pool::serial())
            .pop()
            .expect("one query in, one prediction out")
    }

    /// Batched top-k over an `[m, K]` block of query rows, sharded over
    /// the pool in contiguous row spans (one writer per row, results in
    /// row order) — bit-identical at every worker count.
    pub fn predict_batch_with(&self, xs: &[f32], m: usize, pool: &Pool) -> Vec<TopK> {
        let kf = self.model.feat_dim;
        assert_eq!(xs.len(), m * kf, "query block must be [m, K] row-major");
        let kk = self.k;
        let mut labels = vec![PAD_LABEL; m * kk];
        let mut scores = vec![f32::NEG_INFINITY; m * kk];
        if pool.is_serial() || m <= 1 {
            self.fill_span(xs, m, &mut labels, &mut scores);
        } else {
            let shards = pool.num_workers();
            let per = m.div_ceil(shards);
            let l_view = SharedMut::new(&mut labels);
            let s_view = SharedMut::new(&mut scores);
            let l_ref = &l_view;
            let s_ref = &s_view;
            pool.run_sharded(move |shard| {
                let lo = (shard * per).min(m);
                let hi = ((shard + 1) * per).min(m);
                if lo >= hi {
                    return;
                }
                // SAFETY: row spans [lo, hi) are disjoint across shards by
                // construction; each output slot has exactly one writer.
                let (l, s) = unsafe {
                    (
                        l_ref.slice_mut(lo * kk, (hi - lo) * kk),
                        s_ref.slice_mut(lo * kk, (hi - lo) * kk),
                    )
                };
                self.fill_span(&xs[lo * kf..hi * kf], hi - lo, l, s);
            });
        }
        (0..m)
            .map(|j| {
                let row_l = &labels[j * kk..(j + 1) * kk];
                let row_s = &scores[j * kk..(j + 1) * kk];
                let filled = row_l.iter().position(|&y| y == PAD_LABEL).unwrap_or(kk);
                TopK {
                    labels: row_l[..filled].to_vec(),
                    scores: row_s[..filled].to_vec(),
                }
            })
            .collect()
    }

    /// Score `rows` query rows into per-row (label, score) slots of width
    /// `self.k`. Pure per-row function — the unit both the sharded batch
    /// path and the serial path run.
    fn fill_span(&self, xs: &[f32], rows: usize, labels: &mut [u32], scores: &mut [f32]) {
        let kf = self.model.feat_dim;
        let kk = self.k;
        debug_assert_eq!(xs.len(), rows * kf);
        debug_assert_eq!(labels.len(), rows * kk);
        debug_assert_eq!(scores.len(), rows * kk);
        let scorer = self.scorer();
        let mut scratch = PredictScratch::new();
        if self.cfg.exact {
            self.fill_span_exact(&scorer, xs, rows, labels, scores, &mut scratch);
        } else {
            self.fill_span_beam(&scorer, xs, rows, labels, scores, &mut scratch);
        }
    }

    /// The O(C) oracle: dense sweep in lane-width tiles, then top-k.
    fn fill_span_exact(
        &self,
        scorer: &Scorer<'_>,
        xs: &[f32],
        rows: usize,
        labels: &mut [u32],
        scores: &mut [f32],
        scratch: &mut PredictScratch,
    ) {
        let kf = self.model.feat_dim;
        let c = self.model.num_classes;
        let kk = self.k;
        if scratch.dense.len() < LANES * c {
            scratch.dense.resize(LANES * c, 0.0);
        }
        let mut j = 0;
        while j < rows {
            let hi = (j + LANES).min(rows);
            let mb = hi - j;
            scorer.score_block_with(
                &xs[j * kf..hi * kf],
                mb,
                &mut scratch.dense[..mb * c],
                &mut scratch.score,
            );
            for t in 0..mb {
                score::topk_from_scores(&scratch.dense[t * c..(t + 1) * c], kk, &mut scratch.topk);
                write_row(
                    &scratch.topk,
                    &mut labels[(j + t) * kk..(j + t + 1) * kk],
                    &mut scores[(j + t) * kk..(j + t + 1) * kk],
                );
            }
            j = hi;
        }
    }

    /// Retrieve-then-rank: beam descent proposes candidates, the scorer
    /// re-ranks them exactly.
    fn fill_span_beam(
        &self,
        scorer: &Scorer<'_>,
        xs: &[f32],
        rows: usize,
        labels: &mut [u32],
        scores: &mut [f32],
        scratch: &mut PredictScratch,
    ) {
        let kf = self.model.feat_dim;
        let kk = self.k;
        let aux = self.model.aux.as_ref().expect("checked at Predictor::new");
        let ka = aux.aux_dim();
        let mut proj = vec![0f32; ka];
        for t in 0..rows {
            let x = &xs[t * kf..(t + 1) * kf];
            aux.project(x, &mut proj);
            aux.kernel
                .beam_topk(&proj, self.cfg.beam, &mut scratch.cands, &mut scratch.beam);
            scratch.cand_labels.clear();
            scratch
                .cand_labels
                .extend(scratch.cands.iter().map(|&(y, _)| y));
            scratch.cand_scores.clear();
            scratch.cand_scores.resize(scratch.cand_labels.len(), 0.0);
            // the descent's projection doubles as the correction input —
            // one PCA projection per query, not two
            scorer.score_candidates_projected(
                x,
                &proj,
                &scratch.cand_labels,
                &mut scratch.cand_scores,
            );
            score::topk_from_pairs(
                scratch
                    .cand_labels
                    .iter()
                    .copied()
                    .zip(scratch.cand_scores.iter().copied()),
                kk,
                &mut scratch.topk,
            );
            write_row(
                &scratch.topk,
                &mut labels[t * kk..(t + 1) * kk],
                &mut scores[t * kk..(t + 1) * kk],
            );
        }
    }
}

/// Copy a top-k list into one row's output slots (unfilled slots keep
/// their PAD_LABEL / −∞ initialization).
fn write_row(topk: &[(u32, f32)], labels: &mut [u32], scores: &mut [f32]) {
    for (i, &(y, s)) in topk.iter().enumerate() {
        labels[i] = y;
        scores[i] = s;
    }
}

/// Coalesces individually submitted queries into one batch for the
/// pool-sharded predict path (which tiles rows at lane width internally).
/// Results come back in submission order regardless of pool width — the
/// deterministic merge order of the serving pipeline.
pub struct RequestBatcher<'a> {
    pred: &'a Predictor<'a>,
    xs: Vec<f32>,
    pending: usize,
}

impl<'a> RequestBatcher<'a> {
    pub fn new(pred: &'a Predictor<'a>) -> Self {
        Self { pred, xs: Vec::new(), pending: 0 }
    }

    /// Queue one query; returns its slot in the next flush's result order.
    pub fn submit(&mut self, x: &[f32]) -> usize {
        assert_eq!(x.len(), self.pred.model.feat_dim, "query feature dim mismatch");
        self.xs.extend_from_slice(x);
        self.pending += 1;
        self.pending - 1
    }

    /// Queued-but-unflushed query count.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Run every queued query as one batch over the pool; results are in
    /// submission order. Buffers are retained for the next fill.
    pub fn flush_with(&mut self, pool: &Pool) -> Vec<TopK> {
        let m = self.pending;
        if m == 0 {
            return Vec::new();
        }
        let out = self.pred.predict_batch_with(&self.xs, m, pool);
        self.xs.clear();
        self.pending = 0;
        out
    }
}

/// Serving quality metrics over a labeled held-out set.
#[derive(Clone, Copy, Debug)]
pub struct ServeMetrics {
    /// Fraction of queries whose top-1 prediction is the true label.
    pub p_at_1: f64,
    /// Fraction of queries whose true label appears in the top-k.
    pub recall_at_k: f64,
    /// The k the recall was measured at.
    pub k: usize,
    /// Queries evaluated.
    pub n: usize,
}

/// P@1 / recall@k of a predictor on held-out data (`repro serve --eval`).
/// The heavy per-row prediction shards over the pool; the ~10-flop per-row
/// hit merge stays serial below the shared [`PAR_MIN_MERGE_ROWS`] floor,
/// exactly like the chunked evaluator's streaming merge.
pub fn evaluate_serving(pred: &Predictor<'_>, data: &Dataset, pool: &Pool) -> ServeMetrics {
    assert!(!data.is_empty(), "empty evaluation set");
    assert_eq!(data.feat_dim, pred.model.feat_dim, "eval set feature dim mismatch");
    let n = data.len();
    let preds = pred.predict_batch_with(&data.features, n, pool);
    // bit 0: top-1 hit, bit 1: top-k hit — one writer per row
    let mut flags = vec![0u8; n];
    let merge = |first: usize, span: &mut [u8]| {
        for (t, f) in span.iter_mut().enumerate() {
            let i = first + t;
            let truth = data.y(i);
            let p = &preds[i];
            let mut v = 0u8;
            if p.labels.first() == Some(&truth) {
                v |= 1;
            }
            if p.labels.contains(&truth) {
                v |= 2;
            }
            *f = v;
        }
    };
    if pool.is_serial() || n < PAR_MIN_MERGE_ROWS {
        merge(0, &mut flags);
    } else {
        pool.for_each_span(&mut flags, 1, merge);
    }
    let hits1 = flags.iter().filter(|&&f| f & 1 != 0).count();
    let hitsk = flags.iter().filter(|&&f| f & 2 != 0).count();
    ServeMetrics {
        p_at_1: hits1 as f64 / n as f64,
        recall_at_k: hitsk as f64 / n as f64,
        k: pred.k(),
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::Rng;

    /// A hand-built model over C=8, K=4 whose scores are trivially
    /// predictable (w = scaled one-hot rows), without an auxiliary tree.
    fn onehot_model() -> ServingModel {
        let (c, k) = (8usize, 4usize);
        let mut w = vec![0f32; c * k];
        for y in 0..c {
            w[y * k + y % k] = (y + 1) as f32;
        }
        ServingModel {
            num_classes: c,
            feat_dim: k,
            w,
            b: vec![0f32; c],
            aux: None,
            correct_bias: false,
        }
    }

    #[test]
    fn exact_predictor_ranks_by_score() {
        let m = onehot_model();
        let cfg = ServeConfig { exact: true, k: 3, ..Default::default() };
        let pred = Predictor::new(&m, cfg).unwrap();
        // x = e0: scores are w[y][0]: labels 0 and 4 score 1.0 and 5.0,
        // everything else 0 ⇒ top-3 = [4, 0, then smallest zero label 1]
        let top = pred.predict_one(&[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(top.labels, vec![4, 0, 1]);
        assert_eq!(top.scores[0], 5.0);
        assert_eq!(top.scores[1], 1.0);
        assert_eq!(top.scores[2], 0.0);
    }

    #[test]
    fn beam_predictor_requires_aux() {
        let m = onehot_model();
        assert!(Predictor::new(&m, ServeConfig::default()).is_err());
        assert!(Predictor::new(&m, ServeConfig { exact: true, ..Default::default() }).is_ok());
    }

    #[test]
    fn k_clamps_to_num_classes() {
        let m = onehot_model();
        let cfg = ServeConfig { exact: true, k: 100, ..Default::default() };
        let pred = Predictor::new(&m, cfg).unwrap();
        assert_eq!(pred.k(), 8);
        let top = pred.predict_one(&[0.0, 1.0, 0.0, 0.0]);
        assert_eq!(top.labels.len(), 8);
    }

    #[test]
    fn batcher_returns_results_in_submission_order() {
        let m = onehot_model();
        let cfg = ServeConfig { exact: true, k: 1, ..Default::default() };
        let pred = Predictor::new(&m, cfg).unwrap();
        let mut batcher = RequestBatcher::new(&pred);
        let queries: Vec<Vec<f32>> = (0..5)
            .map(|i| {
                let mut x = vec![0f32; 4];
                x[i % 4] = 1.0;
                x
            })
            .collect();
        for (i, q) in queries.iter().enumerate() {
            assert_eq!(batcher.submit(q), i);
        }
        assert_eq!(batcher.pending(), 5);
        let out = batcher.flush_with(&Pool::serial());
        assert_eq!(batcher.pending(), 0);
        assert_eq!(out.len(), 5);
        for (q, top) in queries.iter().zip(out.iter()) {
            assert_eq!(top, &pred.predict_one(q));
        }
        assert!(batcher.flush_with(&Pool::serial()).is_empty());
    }

    #[test]
    fn serving_eval_counts_hits() {
        let m = onehot_model();
        let cfg = ServeConfig { exact: true, k: 2, ..Default::default() };
        let pred = Predictor::new(&m, cfg).unwrap();
        // queries = e_{y%4} scaled; the top-scoring label for e_j is the
        // largest y with y % 4 == j, i.e. y ∈ {4,5,6,7}
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        for y in 4..8u32 {
            let mut x = vec![0f32; 4];
            x[(y % 4) as usize] = 1.0;
            feats.extend_from_slice(&x);
            labels.push(y);
        }
        let data = Dataset::new(feats, labels, 4, 8);
        let metrics = evaluate_serving(&pred, &data, &Pool::serial());
        assert_eq!(metrics.n, 4);
        assert_eq!(metrics.k, 2);
        assert_eq!(metrics.p_at_1, 1.0);
        assert_eq!(metrics.recall_at_k, 1.0);
    }

    #[test]
    fn batcher_empty_flush_consecutive_flushes_and_reuse() {
        let m = onehot_model();
        let cfg = ServeConfig { exact: true, k: 2, ..Default::default() };
        let pred = Predictor::new(&m, cfg).unwrap();
        let pool = Pool::serial();
        let mut batcher = RequestBatcher::new(&pred);
        // empty flush is a no-op, repeatedly
        assert!(batcher.flush_with(&pool).is_empty());
        assert!(batcher.flush_with(&pool).is_empty());
        assert_eq!(batcher.pending(), 0);
        // first fill
        let qs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                let mut x = vec![0f32; 4];
                x[i] = 1.0;
                x
            })
            .collect();
        for q in &qs {
            batcher.submit(q);
        }
        let first = batcher.flush_with(&pool);
        assert_eq!(first.len(), 3);
        for (q, top) in qs.iter().zip(first.iter()) {
            assert_eq!(top, &pred.predict_one(q), "pinned to predict_one");
        }
        // consecutive flush right after: empty again, state fully reset
        assert!(batcher.flush_with(&pool).is_empty());
        // reuse after flush: slots restart at 0 and results still match
        let q = vec![0.0, 0.0, 0.0, 1.0];
        assert_eq!(batcher.submit(&q), 0);
        let second = batcher.flush_with(&pool);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0], pred.predict_one(&q));
    }

    #[test]
    fn truncated_checkpoint_rejected_with_path_in_error() {
        let m = onehot_model();
        let dir = std::env::temp_dir();
        let path = dir.join(format!("adv_softmax_trunc_ckpt_{}.json", std::process::id()));
        m.save(&path).unwrap();
        // simulate a torn write from a non-atomic saver: keep half the bytes
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let err = ServingModel::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains(path.display().to_string().as_str()),
            "error names the offending path: {msg}"
        );
        std::fs::remove_file(&path).ok();
        // and a missing file also names the path
        let err = ServingModel::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains(path.display().to_string().as_str()));
    }

    #[test]
    fn save_is_atomic_replace_leaving_no_temp_files() {
        let m = onehot_model();
        let dir = std::env::temp_dir().join(format!("adv_softmax_save_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        // overwrite an existing checkpoint in place
        let mut m2 = m.clone();
        m2.b[0] = 42.0;
        m2.save(&path).unwrap();
        let back = ServingModel::load(&path).unwrap();
        assert_eq!(back.b[0], 42.0);
        // the temp file never survives a successful save
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "model.json")
            .collect();
        assert!(leftovers.is_empty(), "stray files after save: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn model_json_rejects_shape_mismatches() {
        let m = onehot_model();
        let good = m.to_json();
        assert!(ServingModel::from_json(&good).is_ok());
        let mut bad = m.clone();
        bad.w.pop();
        assert!(ServingModel::from_json(&bad.to_json()).is_err());
        let mut bad = m.clone();
        bad.b.push(0.0);
        assert!(ServingModel::from_json(&bad.to_json()).is_err());
    }

    #[test]
    fn quantized_predictor_matches_dequantized_oracle_bitwise() {
        // random (not exactly representable) weights: the quantized
        // predictor must predict exactly like an f32 model holding the
        // dequantized rows — decode-inline scoring is quantize-then-score
        let mut m = onehot_model();
        let mut rng = Rng::new(11);
        for v in m.w.iter_mut() {
            *v = rng.normal();
        }
        let n = 9;
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.normal()).collect();
        for mode in [QuantMode::F16, QuantMode::I8] {
            let cfg = ServeConfig { exact: true, k: 3, quantize: mode, ..Default::default() };
            let pred = Predictor::new(&m, cfg).unwrap();
            // dequantize through the same codec, then serve in plain f32
            let mut deq = m.clone();
            match &pred.quant {
                QuantRows::F16(w) => {
                    deq.w = w.iter().map(|&h| crate::linalg::f16_to_f32(h)).collect();
                }
                QuantRows::I8 { q, scales } => {
                    deq.w = q
                        .iter()
                        .enumerate()
                        .map(|(t, &qv)| qv as f32 * scales[t / 4])
                        .collect();
                }
                QuantRows::None => unreachable!("quantized cfg built no rows"),
            }
            let off =
                ServeConfig { exact: true, k: 3, quantize: QuantMode::Off, ..Default::default() };
            let oracle = Predictor::new(&deq, off).unwrap();
            assert_eq!(
                pred.predict_batch_with(&xs, n, &Pool::serial()),
                oracle.predict_batch_with(&xs, n, &Pool::serial()),
                "{mode:?} must match its dequantized oracle bitwise"
            );
        }
    }

    #[test]
    fn predictions_invariant_to_worker_count_on_toy_model() {
        let m = onehot_model();
        let cfg = ServeConfig { exact: true, k: 3, ..Default::default() };
        let pred = Predictor::new(&m, cfg).unwrap();
        let mut rng = Rng::new(4);
        let n = 37;
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.normal()).collect();
        let base = pred.predict_batch_with(&xs, n, &Pool::serial());
        for workers in [2usize, 3, 5] {
            let par = pred.predict_batch_with(&xs, n, &Pool::new(workers));
            assert_eq!(par, base, "workers={workers}");
        }
    }
}
