//! Fault-tolerant serving daemon: bounded admission, deadline-aware
//! micro-batching, graceful degradation, and a supervised predict worker.
//!
//! `repro serve --daemon` turns the one-shot predict pipeline into a
//! long-lived request loop (stdin line protocol or a Unix socket). The
//! robustness contract, enforced by `tests/daemon_chaos.rs`:
//!
//! * **No silent drops.** Every submitted request gets exactly one typed
//!   response: `ok`, `degraded`, `rejected` (load shed or deadline), or
//!   `error` (malformed request / worker crash).
//! * **Bounded admission.** The queue never grows past
//!   [`DaemonConfig::queue_capacity`]; overflow is shed with a typed
//!   `rejected queue-full` response at submit time.
//! * **Deadline-aware batching.** Requests coalesce into micro-batches for
//!   up to a quarter of the latency budget ([`DaemonConfig::coalesce_ms`])
//!   or until [`DaemonConfig::max_batch`]; requests still queued past
//!   their deadline are cancelled with `rejected deadline`, never served
//!   stale.
//! * **Graceful degradation.** Sustained overload (queue at least half
//!   full for [`DaemonConfig::overload_trip`] consecutive flushes) steps
//!   the beam width down [`DaemonConfig::degrade_beams`]; responses are
//!   tagged `degraded beam=B` and remain **bit-exact for that beam width**
//!   — degradation shrinks the candidate set, it never corrupts the Eq. 5
//!   score. The full beam is restored as the queue drains.
//! * **Panic isolation.** Prediction runs on a supervised worker thread;
//!   a panicking (or wedged) worker yields `error` responses for its batch
//!   and is respawned — the daemon itself never crashes.
//!
//! Time is injected through the [`Clock`] trait so batching and deadline
//! decisions are testable with a [`ManualClock`]; combined with the seeded
//! [`FaultPlan`] (a pure function of the request id), a chaos run's
//! fault/response trace is reproducible.
//!
//! # Line protocol
//!
//! One request per line: `feat_dim` whitespace-separated floats. One
//! response line per request, in per-client submission order:
//!
//! ```text
//! <idx> ok <label:score> ...
//! <idx> degraded beam=<B> <label:score> ...
//! <idx> rejected <queue-full|deadline>
//! <idx> error <message>
//! ```
//!
//! where `<idx>` counts the client's requests from 0. Blank lines are
//! ignored; the line `shutdown` drains the queue and exits the loop.

use crate::config::{DaemonConfig, ServeConfig};
use crate::utils::faults::FaultPlan;
use crate::serve::{Predictor, ServingModel, TopK};
use crate::utils::Pool;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::utils::pool::spawn_named;
#[cfg(unix)]
use crate::utils::transport::LineServer;

// The daemon never reads the wall clock directly: all deadline and
// coalescing decisions go through the injectable `Clock` from the
// sanctioned clock layer (re-exported here for existing importers).
pub use crate::utils::timer::{Clock, ManualClock, RealClock};

/// Receiver wait while the queue is empty (new input interrupts it).
const IDLE_POLL_MS: u64 = 200;

/// Why a request was rejected (typed — shedding is never a silent drop).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Admission queue at capacity when the request arrived.
    QueueFull,
    /// Still queued when its latency budget ran out.
    DeadlineExceeded,
}

impl RejectReason {
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue-full",
            RejectReason::DeadlineExceeded => "deadline",
        }
    }
}

/// The four response shapes of the line protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseKind {
    Ok(TopK),
    /// Served under overload at a reduced beam width; still bit-exact for
    /// that width.
    Degraded { beam: usize, topk: TopK },
    Rejected(RejectReason),
    /// Malformed request, or the worker crashed under this batch.
    Error(String),
}

/// One response, addressed by the daemon-global request id.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub id: u64,
    pub kind: ResponseKind,
}

/// Daemon counters. Every submitted request is accounted for exactly once:
/// `submitted = malformed + shed_queue_full + admitted` and
/// `admitted = ok + degraded + rejected_deadline + errored + still-queued`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DaemonStats {
    pub submitted: u64,
    pub admitted: u64,
    pub ok: u64,
    pub degraded: u64,
    pub shed_queue_full: u64,
    pub rejected_deadline: u64,
    pub malformed: u64,
    /// Worker-crash error responses (panic or timeout), per request.
    pub errored: u64,
    pub batches: u64,
    pub worker_panics: u64,
    pub worker_timeouts: u64,
    pub respawns: u64,
    pub tier_changes: u64,
}

impl DaemonStats {
    /// The exactly-one-response invariant, given the current queue depth.
    pub fn accounted(&self, queued: usize) -> bool {
        self.submitted == self.malformed + self.shed_queue_full + self.admitted
            && self.admitted
                == self.ok + self.degraded + self.rejected_deadline + self.errored + queued as u64
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} ok={} degraded={} shed={} deadline={} malformed={} \
             errors={} batches={} respawns={}",
            self.submitted,
            self.ok,
            self.degraded,
            self.shed_queue_full,
            self.rejected_deadline,
            self.malformed,
            self.errored,
            self.batches,
            self.respawns
        )
    }
}

/// An admitted request waiting for a micro-batch slot.
struct Pending {
    id: u64,
    x: Vec<f32>,
    /// Past this instant the request is cancelled, not served.
    deadline_ms: u64,
    /// Past this instant the request stops waiting for co-batchable
    /// arrivals and forces a flush.
    coalesce_due_ms: u64,
}

/// A predict batch shipped to the supervised worker.
struct BatchJob {
    m: usize,
    xs: Vec<f32>,
    cfg: ServeConfig,
    /// Injected slow stage (milliseconds of sleep before predicting).
    slow_ms: u64,
    /// Injected panic: the poisoned request id, if any.
    panic_on: Option<u64>,
}

enum WorkerOutcome {
    Done(Vec<TopK>),
    /// The worker died under this batch: `panicked` distinguishes a panic
    /// (channel closed) from a supervisor timeout (worker abandoned).
    Crashed { panicked: bool },
}

/// The supervised predict worker: prediction runs on a dedicated thread
/// so a panicking request kills that thread, not the daemon. The
/// supervisor detects the death (reply channel disconnect) or a wedge
/// (reply timeout), respawns the worker, and reports the batch as crashed
/// so the daemon can answer every affected request with a typed error.
struct PredictWorker {
    model: Arc<ServingModel>,
    parallelism: usize,
    job_tx: Option<Sender<BatchJob>>,
    reply_rx: Receiver<Vec<TopK>>,
    handle: Option<JoinHandle<()>>,
    respawns: u64,
}

impl PredictWorker {
    fn new(model: Arc<ServingModel>, parallelism: usize) -> Self {
        let (job_tx, reply_rx, handle) = Self::spawn(model.clone(), parallelism);
        Self {
            model,
            parallelism,
            job_tx: Some(job_tx),
            reply_rx,
            handle: Some(handle),
            respawns: 0,
        }
    }

    fn spawn(
        model: Arc<ServingModel>,
        parallelism: usize,
    ) -> (Sender<BatchJob>, Receiver<Vec<TopK>>, JoinHandle<()>) {
        let (job_tx, job_rx) = mpsc::channel::<BatchJob>();
        let (reply_tx, reply_rx) = mpsc::channel::<Vec<TopK>>();
        let handle = spawn_named("predict-worker", move || {
            let pool = if parallelism == 0 { Pool::auto() } else { Pool::new(parallelism) };
            while let Ok(job) = job_rx.recv() {
                if job.slow_ms > 0 {
                    thread::sleep(Duration::from_millis(job.slow_ms));
                }
                if let Some(id) = job.panic_on {
                    panic!("injected fault: worker panic on request {id}");
                }
                let pred = Predictor::new(&model, job.cfg)
                    .expect("batch config pre-validated by Daemon::new");
                let out = pred.predict_batch_with(&job.xs, job.m, &pool);
                if reply_tx.send(out).is_err() {
                    break; // supervisor abandoned us after a timeout
                }
            }
        })
        .expect("spawn predict worker thread");
        (job_tx, reply_rx, handle)
    }

    /// Replace the worker. `join_old` when the old thread already died
    /// (panic unwound — reap it, swallowing the payload); a wedged thread
    /// is abandoned instead, and exits on its next reply send.
    fn respawn(&mut self, join_old: bool) {
        self.job_tx = None;
        if let Some(h) = self.handle.take() {
            if join_old {
                let _ = h.join();
            }
        }
        let (tx, rx, handle) = Self::spawn(self.model.clone(), self.parallelism);
        self.job_tx = Some(tx);
        self.reply_rx = rx;
        self.handle = Some(handle);
        self.respawns += 1;
    }

    fn run_batch(&mut self, job: BatchJob, timeout: Duration) -> WorkerOutcome {
        let sent = match &self.job_tx {
            Some(tx) => tx.send(job).is_ok(),
            None => false,
        };
        if !sent {
            // the worker died between batches; reap and replace it
            self.respawn(true);
            return WorkerOutcome::Crashed { panicked: true };
        }
        match self.reply_rx.recv_timeout(timeout) {
            Ok(out) => WorkerOutcome::Done(out),
            Err(RecvTimeoutError::Disconnected) => {
                self.respawn(true);
                WorkerOutcome::Crashed { panicked: true }
            }
            Err(RecvTimeoutError::Timeout) => {
                self.respawn(false);
                WorkerOutcome::Crashed { panicked: false }
            }
        }
    }
}

impl Drop for PredictWorker {
    fn drop(&mut self) {
        // hang up the job channel so the worker loop exits, then reap it
        self.job_tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The serving daemon core: single-threaded admission/batching/degradation
/// state machine in front of the supervised predict worker. Transports
/// ([`run_stdin_daemon`], [`run_socket_daemon`]) feed it lines and write
/// its responses; tests drive [`Daemon::submit_line`] / [`Daemon::pump`]
/// directly against a [`ManualClock`].
pub struct Daemon {
    model: Arc<ServingModel>,
    serve: ServeConfig,
    cfg: DaemonConfig,
    faults: Option<FaultPlan>,
    clock: Box<dyn Clock>,
    queue: VecDeque<Pending>,
    next_id: u64,
    /// Current degradation tier: 0 = full beam, t > 0 = degrade_beams[t-1].
    tier: usize,
    overload_streak: usize,
    worker: PredictWorker,
    stats: DaemonStats,
}

impl Daemon {
    pub fn new(
        model: Arc<ServingModel>,
        serve: ServeConfig,
        cfg: DaemonConfig,
        parallelism: usize,
        faults: Option<FaultPlan>,
        clock: Box<dyn Clock>,
    ) -> Result<Self> {
        cfg.validate()?;
        // validate the serving config and every degradation tier against
        // the model now — the worker must never see an invalid batch config
        let _ = Predictor::new(&model, serve)?;
        if !serve.exact {
            for (i, &b) in cfg.degrade_beams.iter().enumerate() {
                anyhow::ensure!(
                    b < serve.beam,
                    "degradation tier {i} beam {b} not below the serving beam {}",
                    serve.beam
                );
                let _ = Predictor::new(&model, ServeConfig { beam: b, ..serve })?;
            }
        }
        let worker = PredictWorker::new(model.clone(), parallelism);
        Ok(Self {
            model,
            serve,
            cfg,
            faults,
            clock,
            queue: VecDeque::new(),
            next_id: 0,
            tier: 0,
            overload_streak: 0,
            worker,
            stats: DaemonStats::default(),
        })
    }

    pub fn stats(&self) -> DaemonStats {
        self.stats
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Current degradation tier (0 = full beam).
    pub fn tier(&self) -> usize {
        self.tier
    }

    /// Swap the fault plan mid-run (chaos tests inject and then clear
    /// faults to check recovery).
    pub fn set_faults(&mut self, faults: Option<FaultPlan>) {
        self.faults = faults;
    }

    fn alloc_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        id
    }

    /// Submit one protocol line. Returns the assigned request id and, for
    /// requests answered at admission (malformed or shed), the immediate
    /// response; admitted requests answer later through [`Daemon::pump`].
    pub fn submit_line(&mut self, line: &str) -> (u64, Option<ResponseKind>) {
        let id = self.alloc_id();
        let corrupted;
        let effective = match &self.faults {
            Some(f) if f.malform(id) => {
                corrupted = f.corrupt_line(line);
                corrupted.as_str()
            }
            _ => line,
        };
        match self.parse_query(effective) {
            Ok(x) => (id, self.admit(id, x)),
            Err(msg) => {
                self.stats.malformed += 1;
                (id, Some(ResponseKind::Error(msg)))
            }
        }
    }

    /// Submit one pre-parsed query (the load-generator path).
    pub fn submit_features(&mut self, x: &[f32]) -> (u64, Option<ResponseKind>) {
        let id = self.alloc_id();
        if x.len() != self.model.feat_dim {
            self.stats.malformed += 1;
            let msg = format!(
                "malformed request: got {} features, model expects {}",
                x.len(),
                self.model.feat_dim
            );
            return (id, Some(ResponseKind::Error(msg)));
        }
        (id, self.admit(id, x.to_vec()))
    }

    fn parse_query(&self, line: &str) -> std::result::Result<Vec<f32>, String> {
        let mut x = Vec::with_capacity(self.model.feat_dim);
        for tok in line.split_whitespace() {
            let v: f32 = tok
                .parse()
                .map_err(|_| format!("malformed request: {tok:?} is not a number"))?;
            if !v.is_finite() {
                return Err(format!("malformed request: non-finite feature {tok:?}"));
            }
            x.push(v);
        }
        if x.len() != self.model.feat_dim {
            return Err(format!(
                "malformed request: got {} features, model expects {}",
                x.len(),
                self.model.feat_dim
            ));
        }
        Ok(x)
    }

    fn admit(&mut self, id: u64, x: Vec<f32>) -> Option<ResponseKind> {
        if self.queue.len() >= self.cfg.queue_capacity {
            self.stats.shed_queue_full += 1;
            return Some(ResponseKind::Rejected(RejectReason::QueueFull));
        }
        let now = self.clock.now_ms();
        self.queue.push_back(Pending {
            id,
            x,
            deadline_ms: now + self.cfg.deadline_ms,
            coalesce_due_ms: now + self.cfg.coalesce_ms(),
        });
        self.stats.admitted += 1;
        None
    }

    /// Advance the batching state machine: cancel requests past their
    /// deadline, then flush micro-batches while a flush condition holds —
    /// queue at [`DaemonConfig::max_batch`], the oldest request's
    /// coalescing window expired, or `idle` (the input went quiet, so
    /// waiting longer buys nothing). Returns the responses produced.
    pub fn pump(&mut self, idle: bool) -> Vec<Response> {
        let mut out = Vec::new();
        loop {
            // FIFO queue + uniform budget ⇒ expired requests are at the
            // front; cancel with a typed rejection, never serve stale
            let now = self.clock.now_ms();
            while let Some(p) = self.queue.front() {
                if now < p.deadline_ms {
                    break;
                }
                let p = self.queue.pop_front().expect("front exists");
                self.stats.rejected_deadline += 1;
                out.push(Response {
                    id: p.id,
                    kind: ResponseKind::Rejected(RejectReason::DeadlineExceeded),
                });
            }
            let due = match self.queue.front() {
                None => break,
                Some(p) => now >= p.coalesce_due_ms,
            };
            if !(idle || due || self.queue.len() >= self.cfg.max_batch) {
                break;
            }
            self.flush_batch(&mut out);
        }
        debug_assert!(self.stats.accounted(self.queue.len()), "response accounting broke");
        out
    }

    /// Flush everything regardless of coalescing windows (shutdown path).
    pub fn drain(&mut self) -> Vec<Response> {
        let out = self.pump(true);
        debug_assert!(self.queue.is_empty());
        out
    }

    /// How long until the oldest queued request forces action (its
    /// coalescing window or deadline, whichever is sooner); `None` when
    /// the queue is empty. Transports use this as their receive timeout.
    pub fn next_due_in(&self) -> Option<Duration> {
        let now = self.clock.now_ms();
        self.queue.front().map(|p| {
            let due = p.coalesce_due_ms.min(p.deadline_ms);
            Duration::from_millis(due.saturating_sub(now).max(1))
        })
    }

    /// The beam the next batch runs at, and whether that is degraded.
    fn effective_beam(&self) -> (usize, bool) {
        if self.serve.exact || self.tier == 0 {
            (self.serve.beam, false)
        } else {
            (self.cfg.degrade_beams[self.tier - 1], true)
        }
    }

    fn flush_batch(&mut self, out: &mut Vec<Response>) {
        let take = self.queue.len().min(self.cfg.max_batch);
        debug_assert!(take > 0);
        let kf = self.model.feat_dim;
        let mut ids = Vec::with_capacity(take);
        let mut xs = Vec::with_capacity(take * kf);
        let mut slow_ms = 0u64;
        let mut panic_on = None;
        for _ in 0..take {
            let p = self.queue.pop_front().expect("take <= queue len");
            if let Some(f) = &self.faults {
                if let Some(ms) = f.slow_stage(p.id) {
                    slow_ms = slow_ms.max(ms);
                }
                if panic_on.is_none() && f.worker_panic(p.id) {
                    panic_on = Some(p.id);
                }
            }
            xs.extend_from_slice(&p.x);
            ids.push(p.id);
        }
        let (beam, degraded) = self.effective_beam();
        let job = BatchJob {
            m: ids.len(),
            xs,
            cfg: ServeConfig { beam, ..self.serve },
            slow_ms,
            panic_on,
        };
        self.stats.batches += 1;
        let timeout = Duration::from_millis(self.cfg.worker_timeout_ms);
        match self.worker.run_batch(job, timeout) {
            WorkerOutcome::Done(topks) => {
                debug_assert_eq!(topks.len(), ids.len());
                for (id, topk) in ids.into_iter().zip(topks) {
                    let kind = if degraded {
                        self.stats.degraded += 1;
                        ResponseKind::Degraded { beam, topk }
                    } else {
                        self.stats.ok += 1;
                        ResponseKind::Ok(topk)
                    };
                    out.push(Response { id, kind });
                }
            }
            WorkerOutcome::Crashed { panicked } => {
                let what = if panicked {
                    self.stats.worker_panics += 1;
                    "predict worker panicked under this batch"
                } else {
                    self.stats.worker_timeouts += 1;
                    "predict worker timed out under this batch"
                };
                for id in ids {
                    self.stats.errored += 1;
                    out.push(Response { id, kind: ResponseKind::Error(what.to_string()) });
                }
            }
        }
        self.stats.respawns = self.worker.respawns;
        self.update_degradation();
    }

    /// Post-flush degradation controller: a sustained half-full queue
    /// steps one tier down the beam ladder; a drained queue steps back up.
    fn update_degradation(&mut self) {
        if self.serve.exact || self.cfg.degrade_beams.is_empty() {
            return;
        }
        if self.queue.len() >= self.cfg.shed_highwater() {
            self.overload_streak += 1;
            if self.overload_streak >= self.cfg.overload_trip
                && self.tier < self.cfg.degrade_beams.len()
            {
                self.tier += 1;
                self.overload_streak = 0;
                self.stats.tier_changes += 1;
            }
        } else {
            self.overload_streak = 0;
            if self.queue.is_empty() && self.tier > 0 {
                self.tier -= 1;
                self.stats.tier_changes += 1;
            }
        }
    }
}

// One unit of transport input for [`run_loop`] — shared with the dist
// coordinator's socket glue, so it lives in the transport layer now
// (re-exported here for existing importers).
pub use crate::utils::transport::Inbound;

/// Render a response in the line protocol (`idx` is the per-client
/// request index).
pub fn format_line(idx: u64, kind: &ResponseKind) -> String {
    match kind {
        ResponseKind::Ok(topk) => format!("{idx} ok {}", format_pairs(topk)),
        ResponseKind::Degraded { beam, topk } => {
            format!("{idx} degraded beam={beam} {}", format_pairs(topk))
        }
        ResponseKind::Rejected(r) => format!("{idx} rejected {}", r.name()),
        ResponseKind::Error(msg) => format!("{idx} error {msg}"),
    }
}

fn format_pairs(topk: &TopK) -> String {
    topk.labels
        .iter()
        .zip(topk.scores.iter())
        .map(|(y, s)| format!("{y}:{s:.6}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn dispatch<F: FnMut(usize, u64, &ResponseKind)>(
    route: &mut HashMap<u64, (usize, u64)>,
    responses: Vec<Response>,
    emit: &mut F,
) {
    for r in responses {
        if let Some((client, idx)) = route.remove(&r.id) {
            emit(client, idx, &r.kind);
        }
    }
}

/// The transport-agnostic daemon loop: pull [`Inbound`] lines from `rx`,
/// feed the daemon, and emit `(client, idx, response)` triples in
/// per-client submission order. Exits on [`Inbound::Shutdown`], a
/// `shutdown` line, or a disconnected channel — draining the queue first
/// so every admitted request is answered.
pub fn run_loop<F: FnMut(usize, u64, &ResponseKind)>(
    daemon: &mut Daemon,
    rx: &Receiver<Inbound>,
    mut emit: F,
) -> DaemonStats {
    let mut route: HashMap<u64, (usize, u64)> = HashMap::new();
    let mut counters: HashMap<usize, u64> = HashMap::new();
    let mut open = true;
    while open {
        let wait = daemon
            .next_due_in()
            .unwrap_or(Duration::from_millis(IDLE_POLL_MS));
        match rx.recv_timeout(wait) {
            Ok(first) => {
                let mut burst = vec![first];
                while let Ok(more) = rx.try_recv() {
                    burst.push(more);
                }
                for msg in burst {
                    match msg {
                        Inbound::Shutdown => open = false,
                        Inbound::Line { client, line } => {
                            let text = line.trim();
                            if text.is_empty() {
                                continue;
                            }
                            if text == "shutdown" {
                                open = false;
                                continue;
                            }
                            let counter = counters.entry(client).or_insert(0);
                            let idx = *counter;
                            *counter += 1;
                            let (id, immediate) = daemon.submit_line(text);
                            match immediate {
                                Some(kind) => emit(client, idx, &kind),
                                None => {
                                    route.insert(id, (client, idx));
                                }
                            }
                        }
                    }
                }
                dispatch(&mut route, daemon.pump(false), &mut emit);
            }
            Err(RecvTimeoutError::Timeout) => {
                dispatch(&mut route, daemon.pump(true), &mut emit);
            }
            Err(RecvTimeoutError::Disconnected) => open = false,
        }
    }
    dispatch(&mut route, daemon.drain(), &mut emit);
    daemon.stats()
}

/// Serve the line protocol over stdin/stdout until EOF or `shutdown`.
pub fn run_stdin_daemon(daemon: &mut Daemon) -> Result<DaemonStats> {
    let (tx, rx) = mpsc::channel();
    // detached on purpose: the reader parks on stdin and exits on EOF or
    // when the loop side hangs up the channel
    spawn_named("stdin-reader", move || {
        for line in std::io::stdin().lock().lines() {
            let Ok(line) = line else { break };
            if tx.send(Inbound::Line { client: 0, line }).is_err() {
                return;
            }
        }
        let _ = tx.send(Inbound::Shutdown);
    })
    .context("spawn stdin reader")?;
    let mut out = std::io::stdout().lock();
    let stats = run_loop(daemon, &rx, |_, idx, kind| {
        let _ = writeln!(out, "{}", format_line(idx, kind));
        let _ = out.flush();
    });
    Ok(stats)
}

/// Serve the line protocol on a Unix socket until a client sends
/// `shutdown`. Each connection is an independent client with its own
/// request indices; responses go back on the connection that asked.
#[cfg(unix)]
pub fn run_socket_daemon(daemon: &mut Daemon, path: &Path) -> Result<DaemonStats> {
    let server = LineServer::bind(path)?;
    let stats = run_loop(daemon, server.rx(), |client, idx, kind| {
        server.send(client, &format_line(idx, kind));
    });
    server.shutdown();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The hand-built C=8, K=4 one-hot model (no aux tree ⇒ exact path),
    /// mirroring the fixture in `serve::tests`.
    fn onehot_model() -> Arc<ServingModel> {
        let (c, k) = (8usize, 4usize);
        let mut w = vec![0f32; c * k];
        for y in 0..c {
            w[y * k + y % k] = (y + 1) as f32;
        }
        Arc::new(ServingModel {
            num_classes: c,
            feat_dim: k,
            w,
            b: vec![0f32; c],
            aux: None,
            correct_bias: false,
        })
    }

    fn exact_cfg() -> ServeConfig {
        ServeConfig { exact: true, k: 3, ..Default::default() }
    }

    fn manual_daemon(cfg: DaemonConfig, faults: Option<FaultPlan>) -> (Daemon, ManualClock) {
        let clock = ManualClock::new();
        let daemon = Daemon::new(
            onehot_model(),
            exact_cfg(),
            cfg,
            1,
            faults,
            Box::new(clock.clone()),
        )
        .unwrap();
        (daemon, clock)
    }

    fn query(hot: usize) -> Vec<f32> {
        let mut x = vec![0f32; 4];
        x[hot % 4] = 1.0;
        x
    }

    fn line(hot: usize) -> String {
        query(hot)
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(" ")
    }

    #[test]
    fn admission_sheds_past_capacity_with_typed_rejections() {
        let cfg = DaemonConfig { queue_capacity: 2, ..Default::default() };
        let (mut daemon, _clock) = manual_daemon(cfg, None);
        assert_eq!(daemon.submit_line(&line(0)), (0, None));
        assert_eq!(daemon.submit_line(&line(1)), (1, None));
        let (id, kind) = daemon.submit_line(&line(2));
        assert_eq!(id, 2);
        assert_eq!(kind, Some(ResponseKind::Rejected(RejectReason::QueueFull)));
        let out = daemon.pump(true);
        assert_eq!(out.len(), 2, "both admitted requests answered");
        assert!(out.iter().all(|r| matches!(r.kind, ResponseKind::Ok(_))));
        let stats = daemon.stats();
        assert_eq!(stats.shed_queue_full, 1);
        assert_eq!(stats.ok, 2);
        assert!(stats.accounted(daemon.queue_len()));
    }

    #[test]
    fn queued_requests_past_deadline_are_cancelled_not_served() {
        let cfg = DaemonConfig { deadline_ms: 20, ..Default::default() };
        let (mut daemon, clock) = manual_daemon(cfg, None);
        let (id0, none) = daemon.submit_line(&line(0));
        assert!(none.is_none());
        clock.advance(21);
        let (id1, _) = daemon.submit_line(&line(1));
        let out = daemon.pump(true);
        assert_eq!(out.len(), 2);
        assert_eq!(
            out[0],
            Response {
                id: id0,
                kind: ResponseKind::Rejected(RejectReason::DeadlineExceeded)
            }
        );
        assert!(matches!(&out[1], Response { id, kind: ResponseKind::Ok(_) } if *id == id1));
        assert_eq!(daemon.stats().rejected_deadline, 1);
        assert!(daemon.stats().accounted(daemon.queue_len()));
    }

    #[test]
    fn coalescing_waits_for_the_window_and_max_batch_flushes_early() {
        let cfg = DaemonConfig { deadline_ms: 40, max_batch: 2, ..Default::default() };
        let coalesce = cfg.coalesce_ms();
        let (mut daemon, clock) = manual_daemon(cfg, None);
        // one queued request inside its window: nothing flushes
        daemon.submit_line(&line(0));
        assert!(daemon.pump(false).is_empty());
        assert_eq!(daemon.next_due_in(), Some(Duration::from_millis(coalesce)));
        // a second request hits max_batch: flush without waiting
        daemon.submit_line(&line(1));
        let out = daemon.pump(false);
        assert_eq!(out.len(), 2);
        assert_eq!(daemon.stats().batches, 1, "coalesced into one batch");
        // a lone request flushes once its window expires
        daemon.submit_line(&line(2));
        assert!(daemon.pump(false).is_empty());
        clock.advance(coalesce);
        assert_eq!(daemon.pump(false).len(), 1);
        assert!(daemon.stats().accounted(daemon.queue_len()));
    }

    #[test]
    fn malformed_lines_get_typed_errors_and_never_queue() {
        let (mut daemon, _clock) = manual_daemon(DaemonConfig::default(), None);
        for bad in ["1 2 x 4", "1 2 3", "1 2 3 4 5", "nan 0 0 0"] {
            let (_, kind) = daemon.submit_line(bad);
            match kind {
                Some(ResponseKind::Error(msg)) => {
                    assert!(msg.contains("malformed request"), "line {bad:?}: {msg}");
                }
                other => panic!("line {bad:?} should be a typed error, got {other:?}"),
            }
        }
        assert_eq!(daemon.queue_len(), 0);
        assert_eq!(daemon.stats().malformed, 4);
        assert!(daemon.stats().accounted(0));
    }

    #[test]
    fn worker_panic_is_isolated_and_recovery_is_bit_exact() {
        let plan = FaultPlan { panic_rate: 1.0, ..FaultPlan::disabled(1) };
        let (mut daemon, _clock) = manual_daemon(DaemonConfig::default(), Some(plan));
        daemon.submit_line(&line(0));
        let out = daemon.drain();
        assert_eq!(out.len(), 1);
        assert!(
            matches!(&out[0].kind, ResponseKind::Error(msg) if msg.contains("panicked")),
            "got {:?}",
            out[0].kind
        );
        assert_eq!(daemon.stats().worker_panics, 1);
        assert_eq!(daemon.stats().respawns, 1);
        // faults cleared: the respawned worker serves bit-identically to a
        // plain predictor
        daemon.set_faults(None);
        daemon.submit_line(&line(0));
        let out = daemon.drain();
        let model = onehot_model();
        let expect = Predictor::new(&model, exact_cfg()).unwrap().predict_one(&query(0));
        match &out[0].kind {
            ResponseKind::Ok(topk) => assert_eq!(topk, &expect),
            other => panic!("expected ok after recovery, got {other:?}"),
        }
        assert!(daemon.stats().accounted(daemon.queue_len()));
    }

    #[test]
    fn wedged_worker_times_out_and_is_replaced() {
        // a slow stage far past the supervisor's patience models a wedged
        // worker: the batch gets typed errors, the worker is abandoned and
        // respawned, and the daemon keeps serving
        let plan = FaultPlan { slow_rate: 1.0, slow_ms: 300, ..FaultPlan::disabled(2) };
        let cfg = DaemonConfig { deadline_ms: 40, worker_timeout_ms: 40, ..Default::default() };
        let (mut daemon, _clock) = manual_daemon(cfg, Some(plan));
        daemon.submit_line(&line(0));
        let out = daemon.drain();
        assert_eq!(out.len(), 1);
        assert!(
            matches!(&out[0].kind, ResponseKind::Error(msg) if msg.contains("timed out")),
            "got {:?}",
            out[0].kind
        );
        assert_eq!(daemon.stats().worker_timeouts, 1);
        assert_eq!(daemon.stats().respawns, 1);
        // the replacement worker serves normally once faults stop
        daemon.set_faults(None);
        daemon.submit_line(&line(1));
        let out = daemon.drain();
        assert!(matches!(&out[0].kind, ResponseKind::Ok(_)), "got {:?}", out[0].kind);
        assert!(daemon.stats().accounted(daemon.queue_len()));
    }

    #[test]
    fn declared_slow_stage_within_patience_completes_ok() {
        let plan = FaultPlan { slow_rate: 1.0, slow_ms: 5, ..FaultPlan::disabled(3) };
        let (mut daemon, _clock) = manual_daemon(DaemonConfig::default(), Some(plan));
        daemon.submit_line(&line(0));
        let out = daemon.drain();
        assert!(matches!(&out[0].kind, ResponseKind::Ok(_)), "got {:?}", out[0].kind);
        assert_eq!(daemon.stats().worker_timeouts, 0);
        assert_eq!(daemon.stats().respawns, 0);
    }

    #[test]
    fn exact_mode_never_degrades() {
        let cfg = DaemonConfig {
            queue_capacity: 8,
            max_batch: 1,
            overload_trip: 1,
            ..Default::default()
        };
        let (mut daemon, _clock) = manual_daemon(cfg, None);
        for i in 0..8 {
            daemon.submit_line(&line(i));
        }
        let out = daemon.drain();
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|r| matches!(r.kind, ResponseKind::Ok(_))));
        assert_eq!(daemon.tier(), 0);
        assert_eq!(daemon.stats().degraded, 0);
    }

    #[test]
    fn run_loop_answers_in_submission_order_and_drains_on_shutdown() {
        let model = onehot_model();
        let daemon = Daemon::new(
            model.clone(),
            exact_cfg(),
            DaemonConfig { deadline_ms: 1000, ..Default::default() },
            1,
            None,
            Box::new(RealClock::new()),
        );
        let mut daemon = daemon.unwrap();
        let (tx, rx) = mpsc::channel();
        for i in 0..3 {
            tx.send(Inbound::Line { client: 0, line: line(i) }).unwrap();
        }
        tx.send(Inbound::Line { client: 0, line: "not a number".into() })
            .unwrap();
        tx.send(Inbound::Line { client: 0, line: "shutdown".into() })
            .unwrap();
        let mut got = Vec::new();
        let stats = run_loop(&mut daemon, &rx, |client, idx, kind| {
            got.push((client, idx, kind.clone()));
        });
        assert_eq!(got.len(), 4, "three queries + one typed error");
        let idxs: Vec<u64> = got.iter().map(|(_, idx, _)| *idx).collect();
        let mut sorted = idxs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        for (_, idx, kind) in &got {
            match kind {
                ResponseKind::Ok(topk) => {
                    let expect = Predictor::new(&model, exact_cfg())
                        .unwrap()
                        .predict_one(&query(*idx as usize));
                    assert_eq!(topk, &expect, "request {idx}");
                }
                ResponseKind::Error(msg) => {
                    assert_eq!(*idx, 3);
                    assert!(msg.contains("malformed request"));
                }
                other => panic!("unexpected response {other:?}"),
            }
        }
        assert_eq!(stats.ok, 3);
        assert_eq!(stats.malformed, 1);
        assert!(stats.accounted(0));
    }

    #[test]
    fn format_line_covers_every_tag() {
        let topk = TopK { labels: vec![4, 0], scores: vec![5.0, 1.0] };
        assert_eq!(format_line(0, &ResponseKind::Ok(topk.clone())), "0 ok 4:5.000000 0:1.000000");
        assert_eq!(
            format_line(1, &ResponseKind::Degraded { beam: 16, topk }),
            "1 degraded beam=16 4:5.000000 0:1.000000"
        );
        assert_eq!(
            format_line(2, &ResponseKind::Rejected(RejectReason::QueueFull)),
            "2 rejected queue-full"
        );
        assert_eq!(
            format_line(3, &ResponseKind::Rejected(RejectReason::DeadlineExceeded)),
            "3 rejected deadline"
        );
        assert_eq!(format_line(4, &ResponseKind::Error("boom".into())), "4 error boom");
    }
}
