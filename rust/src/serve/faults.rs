//! Re-export shim: the fault-injection plan moved to [`crate::utils::faults`]
//! when the distributed-training layer started sharing it (one
//! `REPRO_FAULTS` spec drives both the daemon's request faults and the
//! dist protocol's frame faults). Existing `serve::faults::FaultPlan`
//! importers keep working through this path.

pub use crate::utils::faults::*;
